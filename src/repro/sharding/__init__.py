from repro.sharding.partitioning import NO_SHARDING, ShardingPolicy
