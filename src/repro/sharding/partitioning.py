"""Sharding policy: logical placement rules -> NamedSharding constraints.

One object carries every distribution decision (DESIGN.md §4):

  * mesh axes: optional 'pod' (pure DP, crosses DCN), 'data' (FSDP batch +
    parameter shard), 'model' (TP/EP).
  * parameters: 2-D sharded per the specs each module emits (FSDP on 'data',
    TP on 'model'); the 'pod' axis never shards parameters.
  * activations: batch on (pod, data); attention heads on 'model' when the
    head count divides, else head_dim, else replicated — this fallback chain
    is what lets whisper-tiny (6 heads) and gemma (8 heads / MQA) compile on
    a 16-way TP axis.
  * KV cache: kv heads optionally *repeated* up to the TP degree so the cache
    shards instead of replicating ("repeat-to-TP", factor tp/n_kv).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Optional[Mesh]
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    seq_shard: bool = False       # sequence parallelism on the residual stream
    cp_layout: bool = False       # context-parallel prefill: activations
    # sequence-sharded over 'model' end-to-end; flash q-blocks stay local
    # against gathered K/V (EXPERIMENTS.md §Perf iC.3)
    serve_layout: bool = False    # DP-heavy inference layout: layer weights
    # FSDP-sharded over (data x model), activations replicated over 'model',
    # KV cache sequence-sharded — removes the per-layer TP all-reduces that
    # dominate the prefill roofline (EXPERIMENTS.md §Perf iC.2)

    # ------------------------------------------------------------- helpers
    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape.get(a, 1)
        return n

    def _constrain(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def kv_repeat(self, n_kv: int, n_heads: int) -> int:
        """Repeat factor R/n_kv for the stored KV heads (repeat-to-TP)."""
        if self.serve_layout:
            return 1              # cache shards on sequence, not heads
        tp = self.tp_size
        if (n_kv < tp <= n_heads and n_heads % tp == 0 and tp % n_kv == 0):
            return tp // n_kv
        return 1

    def _heads_spec(self, n_heads: int, head_dim: int) -> P:
        """Attention ACTIVATIONS (B,S,N,H): shard heads if they divide, else
        replicate — sharding head_dim here would split RoPE's rotation pairs
        and forces involuntary resharding around the merge-heads reshape."""
        dp = self.dp_axes
        tp = self.tp_size
        if not self.serve_layout and tp > 1 and n_heads % tp == 0:
            return P(dp, None, self.tp_axis, None)
        return P(dp, None, None, None)

    def _cache_spec(self, n_heads: int, head_dim: int) -> P:
        """KV-cache STORAGE: persistent and large, so fall back to sharding
        head_dim when the (repeated) kv-head count does not divide TP."""
        dp = self.dp_axes
        tp = self.tp_size
        if tp > 1 and n_heads % tp == 0:
            return P(dp, None, self.tp_axis, None)
        if tp > 1 and head_dim % tp == 0:
            return P(dp, None, None, self.tp_axis)
        return P(dp, None, None, None)

    # ------------------------------------------------------------ act hooks
    def shard_activations(self, x):
        """Residual stream (B, S, D): batch over DP axes; with seq_shard the
        sequence dim also shards over the TP axis (Megatron-style SP — the
        norms are pointwise over D, attention/FFN gather what they need).
        This divides the remat-saved per-layer residuals by tp_size."""
        if (self.seq_shard and self.tp_size > 1 and x.ndim == 3
                and x.shape[1] % self.tp_size == 0 and x.shape[1] > 1):
            return self._constrain(x, P(self.dp_axes, self.tp_axis, None))
        return self._constrain(x, P(self.dp_axes, None, None))

    def sp_gather(self, x):
        """Megatron-SP all-gather point: norm outputs enter the matmuls with
        the FULL sequence (replicated over TP).  Placing the constraint here
        makes GSPMD gather the (B,S,D) activations (~300 MB) instead of the
        fp32-upcast weights (5.4 GB on nemotron — measured) and positions
        the seq all-gather exactly once per block input."""
        if self.seq_shard and self.tp_size > 1 and x.ndim == 3:
            return self._constrain(x, P(self.dp_axes, None, None))
        return x

    def sp_scatter(self, y):
        """Megatron-SP reduce-scatter point: block outputs return to the
        seq-sharded layout immediately, so the TP partial-sum lowers to a
        reduce-scatter instead of a full all-reduce (16x less wire)."""
        if (self.seq_shard and self.tp_size > 1 and y.ndim == 3
                and y.shape[1] % self.tp_size == 0 and y.shape[1] > 1):
            return self._constrain(y, P(self.dp_axes, self.tp_axis, None))
        return y

    def shard_logits(self, x):
        """(B, S, V): vocab over the TP axis (the unembedding is
        model-sharded, so this keeps logits where they are produced)."""
        if self.tp_size > 1 and x.shape[-1] % self.tp_size == 0:
            return self._constrain(x, P(self.dp_axes, None, self.tp_axis))
        return self._constrain(x, P(self.dp_axes, None, None))

    def shard_heads(self, x):
        """(B, S, N, H) attention activations."""
        return self._constrain(x, self._heads_spec(x.shape[2], x.shape[3]))

    def shard_cache(self, x):
        return self._constrain(x, self._cache_spec(x.shape[2], x.shape[3]))

    def shard_scores(self, x):
        """Attention scores (B, R, G, S_q, S_k) fp32: pin batch to DP and the
        kv-head axis (leading head factor, blocked grouping) to TP.  Without
        this constraint GSPMD is free to pick a sequence sharding for the
        backward score gradients and then all-gathers the full-batch fp32
        tensor (measured: 12.9 GB/device on nemotron-340b train)."""
        tp = self.tp_size
        r = x.shape[1]
        if tp > 1 and r % tp == 0:
            return self._constrain(x, P(self.dp_axes, self.tp_axis, None,
                                        None, None))
        return self._constrain(x, P(self.dp_axes, None, None, None, None))

    def batch_spec(self, ndim: int = 2) -> P:
        return P(self.dp_axes, *([None] * (ndim - 1)))

    def replicated(self) -> P:
        return P()

    def _sanitize(self, spec: P, shape) -> P:
        out = []
        for i, entry in enumerate(tuple(spec)):
            if entry is None or i >= len(shape):
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            axes = tuple(a for a in axes if a in self.mesh.shape)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            ok = axes and shape[i] % size == 0
            out.append((axes if len(axes) > 1 else axes[0]) if ok else None)
        return P(*out)

    def run_sharded_flash(self, q, k, v, *, causal: bool = True,
                          window: int = 0):
        if self.cp_layout and self.mesh is not None:
            return self._run_cp_flash(q, k, v, causal=causal, window=window)
        """Flash attention under a full-manual shard_map: each device runs
        the Pallas kernel on its local (batch, head) shard — GSPMD never
        sees the kernel, so it cannot replicate its inputs.  Forward-only
        (prefill / serving)."""
        from repro.kernels.flash_attention import flash_attention
        if self.mesh is None:
            return flash_attention(q, k, v, causal=causal, window=window)
        qspec = self._sanitize(self._heads_spec(q.shape[2], q.shape[3]),
                               q.shape)
        kspec = self._sanitize(self._heads_spec(k.shape[2], k.shape[3]),
                               k.shape)
        # heads must shard consistently: if q shards on heads but k cannot
        # (r < tp), fall back to replicated heads for both
        if qspec[2] != kspec[2]:
            qspec = self._sanitize(P(self.dp_axes, None, None, None), q.shape)
            kspec = self._sanitize(P(self.dp_axes, None, None, None), k.shape)
        fn = jax.shard_map(
            lambda a, b, c: flash_attention(a, b, c, causal=causal,
                                            window=window),
            mesh=self.mesh, in_specs=(qspec, kspec, kspec),
            out_specs=qspec, check_vma=False)
        return fn(q, k, v)

    def _run_cp_flash(self, q, k, v, *, causal: bool, window: int):
        """Context-parallel flash: q stays SEQUENCE-sharded over the TP
        axis (each shard owns a contiguous q block, passing its global
        origin to the kernel's causal mask); K/V are replicated.  Balances
        attention flops across the model axis without head sharding."""
        from repro.kernels.flash_attention import flash_attention
        dp, tp = self.dp_axes, self.tp_axis
        local_s = q.shape[1] // self.tp_size

        def inner(a, b_, c):
            off = jax.lax.axis_index(tp) * local_s
            return flash_attention(a, b_, c, causal=causal, window=window,
                                   q_offset=off)

        qspec = self._sanitize(P(dp, tp, None, None), q.shape)
        kspec = self._sanitize(P(dp, None, None, None), k.shape)
        fn = jax.shard_map(inner, mesh=self.mesh,
                           in_specs=(qspec, kspec, kspec),
                           out_specs=qspec, check_vma=False)
        return fn(q, k, v)

    # ----------------------------------------------------- param spec tools
    def serve_param_specs(self, specs_tree, keep_data: bool = False):
        """Transform per-layer weight specs for the DP-heavy serve layout:
        'model' is removed and 'data' becomes ('data','model') — every layer
        weight is FSDP-sharded across ALL chips and streamed (one gather per
        layer), so no matmul produces TP partial sums.  Embedding/unembed
        specs (which carry 'model' on the vocab dim by design) are preserved
        by the caller passing only the layer subtrees."""
        def tx(spec):
            if not isinstance(spec, P):
                return spec
            out = []
            for entry in tuple(spec):
                if entry is None:
                    out.append(None)
                elif entry == "data" or entry == ("data",):
                    out.append("data" if keep_data else ("data", "model"))
                elif entry == "model":
                    out.append(None)
                elif isinstance(entry, tuple):
                    out.append(entry)   # already combined
                else:
                    out.append(entry)
            return P(*out)

        return jax.tree.map(tx, specs_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def param_sharding(self, specs_tree):
        """Pytree of PartitionSpec -> pytree of NamedSharding."""
        if self.mesh is None:
            return None
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs_tree,
            is_leaf=lambda x: isinstance(x, P))


NO_SHARDING = ShardingPolicy(mesh=None, dp_axes=())
