"""Post-SPMD HLO cost analyzer with loop trip-count correction.

``compiled.cost_analysis()`` counts every while-loop body ONCE (measured in
this environment: a 10-iteration scan reports the flops of one iteration),
so any scan-over-layers / grad-accumulation / q-chunk graph is undercounted
by large factors.  This module parses ``compiled.as_text()`` into
computations, builds the call graph (while bodies weighted by their
``known_trip_count``, fusions/calls by call-site count), and propagates
execution multipliers from ENTRY.  It then reports:

  * flops        — 2*M*N*K summed over `dot` ops x multiplier
  * hbm_bytes    — sum of (operands + result) bytes over non-fused op sites
                   x multiplier (CPU-fusion granularity; a pessimistic but
                   consistent HBM-traffic model, see EXPERIMENTS.md §Roofline)
  * collectives  — per-kind op counts and wire bytes x multiplier
                   (all-reduce counted 2x: reduce-scatter + all-gather ring
                   phases; ring (g-1)/g factor ~1 dropped)

All numbers are PER-DEVICE (the HLO is the per-partition SPMD module).
"""
from __future__ import annotations

import collections
import json
import re
from typing import Dict, List

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "token": 0, "u1": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
_CALLEE_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(
    r'known_trip_count"?\s*[:=]\s*\{\s*"?n"?\s*[:=]\s*"?(\d+)"?\s*\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: List[dict] = []
        self.symbols: Dict[str, str] = {}   # op/param name -> type string
        self.callees: List[tuple] = []      # (callee, weight, kind)
        self.fusion_callees: set = set()


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            # header params: "p1: f32[2,3], p2: (f32[], s32[])"
            hdr = mc.group(2)
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\],]+)",
                                  hdr):
                cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, type_str, opcode, rest = mo.groups()
        cur.symbols[name] = type_str
        # operands: up to the closing paren at depth 0
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = rest[:end]
        attrs = rest[end:]
        operands = _OPERAND_RE.findall(operand_str)
        op = {"name": name, "type": type_str, "opcode": opcode,
              "operands": operands, "attrs": attrs, "line": line}
        cur.ops.append(op)
        # call edges
        trip = 1
        mt = _TRIP_RE.search(attrs)
        if opcode == "while":
            trip = int(mt.group(1)) if mt else 1
        for cm in _CALLEE_RE.finditer(attrs):
            w = trip if opcode == "while" else 1
            cur.callees.append((cm.group(1), w, opcode))
            if opcode == "fusion":
                cur.fusion_callees.add(cm.group(1))
        mb = _BRANCH_RE.search(attrs)
        if mb:
            for b in _OPERAND_RE.findall(mb.group(1)):
                cur.callees.append((b, 1, "conditional"))
    return comps, entry


def _dot_flops(comp: Computation, op: dict) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    _, rdims = _shape_dims(op["type"])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op["attrs"] +
                  op["line"])
    if not op["operands"]:
        return 0.0
    lhs_type = comp.symbols.get(op["operands"][0], "")
    _, ldims = _shape_dims(lhs_type)
    contract = 1
    if m and ldims:
        for d in m.group(1).split(","):
            if d and int(d) < len(ldims):
                contract *= ldims[int(d)]
    rsize = 1
    for d in rdims:
        rsize *= d
    return 2.0 * rsize * contract


def analyze(text: str) -> dict:
    comps, entry = parse_hlo(text)
    if entry is None:
        return {"error": "no entry computation"}

    # propagate execution multipliers (fixpoint over the call DAG)
    mult = collections.defaultdict(float)
    mult[entry] = 1.0
    # iterate: call graphs are DAGs; a few passes suffice
    for _ in range(64):
        changed = False
        new = collections.defaultdict(float)
        new[entry] = 1.0
        for name, comp in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for callee, w, _kind in comp.callees:
                new[callee] += m * w
        for k, v in new.items():
            if abs(mult.get(k, 0.0) - v) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break

    fusion_bodies = set()
    reduce_bodies = set()
    for comp in comps.values():
        fusion_bodies |= comp.fusion_callees
        for callee, _w, kind in comp.callees:
            if kind not in ("while", "conditional", "call"):
                if callee not in fusion_bodies:
                    reduce_bodies.add(callee)

    flops = 0.0
    hbm = 0.0
    coll_counts = collections.Counter()
    coll_bytes = collections.Counter()
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in fusion_bodies or name in reduce_bodies
        for op in comp.ops:
            oc = op["opcode"]
            if oc == "dot":
                flops += m * _dot_flops(comp, op)
            if oc in COLLECTIVE_OPS or oc.rstrip("-start") in COLLECTIVE_OPS:
                base = oc.replace("-start", "")
                if base in COLLECTIVE_OPS:
                    b = shape_bytes(op["type"])
                    wire = 2 * b if base == "all-reduce" else b
                    coll_counts[base] += int(m)
                    coll_bytes[base] += m * wire
            if not in_fusion and oc not in _SKIP_BYTES_OPS \
                    and not oc.endswith("-done"):
                b = shape_bytes(op["type"])
                for o in op["operands"]:
                    b += shape_bytes(comp.symbols.get(o, ""))
                hbm += m * b

    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_counts": dict(coll_counts),
        "collective_bytes": {k: float(v) for k, v in coll_bytes.items()},
        "collective_total_bytes": float(sum(coll_bytes.values())),
        "n_computations": len(comps),
    }


def top_tensors(text: str, n: int = 20):
    """Largest result tensors with their op + computation (memory triage)."""
    comps, entry = parse_hlo(text)
    rows = []
    for name, comp in comps.items():
        for op in comp.ops:
            b = shape_bytes(op["type"])
            if b > (8 << 20):
                rows.append((b, comp.name, op["opcode"], op["name"],
                             op["type"][:60]))
    rows.sort(reverse=True)
    return rows[:n]


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze(open(sys.argv[1]).read()), indent=2))
