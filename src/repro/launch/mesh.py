"""Production mesh construction (dry-run contract, system-prompt §Multi-pod).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (v5e-256) or 2x16x16 two-pod mesh.

    Axes: 'pod' (pure DP across DCN), 'data' (FSDP + batch), 'model' (TP/EP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers, as a 1-D data mesh (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
