"""Serving driver: prefill + decode with a length-sorted batch scheduler.

The scheduler is the third place the paper's technique lands in the
framework (after MoE routing and sampling): incoming requests are sorted by
prompt length (any registered ``repro.sort`` backend) so each prefill batch is
length-homogeneous — padding waste drops from worst-case to
max-within-bucket, exactly the data-movement argument of the paper applied
to request scheduling.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --requests 16 --decode-steps 32
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import pathlib
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sort as sorting
from repro.configs.base import get_config, get_smoke_config
from repro.core import topology as _topology, tuning as _tuning
from repro.obs import metrics as _metrics, report as _obs_report, \
    trace as _obs
from repro.launch import steps as steps_lib
from repro.launch.mesh import dp_axes_of, make_host_mesh
from repro.models.model_zoo import build
from repro.sharding.partitioning import ShardingPolicy

# where serve persists its tuning/topology snapshot between runs (the
# --state-dir flag overrides; unset means no persistence)
SERVE_STATE_ENV = "REPRO_SERVE_STATE_DIR"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (len,) int32
    max_new: int = 32
    out: Optional[np.ndarray] = None
    submit_t: float = 0.0       # monotonic clock at submit()


class LengthSortedScheduler:
    """Batch requests by sorted prompt length (paper technique #3).

    Each batch is **anchored at the oldest queued request** and filled with
    its adjacent-length neighbours from the sorted order (the window with
    the smallest length spread that contains the anchor).  Pure
    shortest-k scheduling starved long prompts forever under sustained
    load — a long request could sit at the tail of the sorted order while
    fresh short requests kept overtaking it; anchoring bounds every
    request's wait at its arrival backlog while keeping batches
    length-homogeneous (the padding-waste argument survives intact).

    ``method`` takes any registered backend name; the default ``"auto"`` lets
    the engine's cost-model planner pick per queue size, so the scheduler
    scales from a handful of requests to engine-sized backlogs unchanged.

    With a ``mesh`` (any multi-device host or pod slice) the backlog sort
    itself goes distributed: a (length, position) composite key is sorted
    globally over the mesh by the sample-sort (``axis_name`` follows
    ``distributed_sort`` — one axis, a tuple, or ``None`` for the whole
    mesh; on a two-axis ``(hosts, devices)`` mesh the planner picks the
    flat or hierarchical schedule from the topology tier rates), so a
    fleet-scale queue never funnels through one device.  Single-device
    meshes and backlogs under ``distributed_min`` keep the local argsort
    path — per-queue-length shard_map programs only pay off once the
    backlog reaches engine scale.
    """

    def __init__(self, batch_size: int, method: str = "auto", *,
                 mesh=None, axis_name=None,
                 distributed_min: int = 4096):
        self.batch_size = batch_size
        self.method = method
        self.mesh = mesh
        self.axis_name = axis_name
        self.distributed_min = distributed_min
        self.queue: List[Request] = []

    def submit(self, req: Request) -> None:
        req.submit_t = time.monotonic()
        self.queue.append(req)

    def _n_dev(self) -> int:
        if self.mesh is None:
            return 1
        from repro.engine import samplesort
        axes = samplesort._axes_tuple(self.mesh, self.axis_name)
        return samplesort._n_dev(self.mesh, axes)

    def _order(self, lens: jnp.ndarray) -> np.ndarray:
        n = lens.shape[0]
        idx_bits = max(1, (n - 1).bit_length())
        distributed = (self._n_dev() > 1
                       and n >= self.distributed_min
                       and int(jnp.max(lens)) < (1 << (31 - idx_bits)))
        if not distributed:
            return np.array(sorting.argsort(lens, method=self.method))
        # mesh path: value-sort a packed (length, position) composite —
        # the distributed path has no argsort, but the composite is one
        comp = (lens.astype(jnp.int32) << idx_bits) \
            | jnp.arange(n, dtype=jnp.int32)
        out = sorting.sort(comp, mesh=self.mesh, axis_name=self.axis_name)
        return np.array(out) & ((1 << idx_bits) - 1)

    def next_batch(self) -> List[Request]:
        if not self.queue:
            return []
        lens_np = np.asarray([len(r.prompt) for r in self.queue],
                             dtype=np.int32)
        order = self._order(jnp.asarray(lens_np))
        n, b = len(self.queue), min(self.batch_size, len(self.queue))
        # anchor: the oldest queued request (the queue is submission
        # order, so position 0 is it) — every batch serves the current
        # oldest, which bounds any request's wait at its arrival backlog
        order = np.asarray(order)
        anchor = int(np.nonzero(order == 0)[0][0])
        # lengths in schedule order are ascending, so a window's spread is
        # just last-minus-first — O(b) over the candidate starts
        sl = lens_np[order]
        best_start, best_spread = None, None
        for start in range(max(0, anchor - b + 1), min(anchor, n - b) + 1):
            spread = int(sl[start + b - 1] - sl[start])
            if best_spread is None or spread < best_spread:
                best_start, best_spread = start, spread
        window = order[best_start:best_start + b]
        batch = [self.queue[i] for i in window]
        picked = set(int(i) for i in window)
        self.queue = [r for i, r in enumerate(self.queue)
                      if i not in picked]
        return batch

    def padding_waste(self, batch: List[Request]) -> float:
        if not batch:
            return 0.0
        lens = [len(r.prompt) for r in batch]
        return 1.0 - sum(lens) / (len(lens) * max(lens))


def resolve_state_dir(explicit: Optional[str] = None
                      ) -> Optional[pathlib.Path]:
    """The serve state directory: the explicit argument, else the
    ``REPRO_SERVE_STATE_DIR`` environment variable, else None (no
    persistence)."""
    d = explicit if explicit is not None \
        else os.environ.get(SERVE_STATE_ENV)
    return pathlib.Path(d) if d else None


def restore_state(state_dir: os.PathLike, mesh=None) -> List[str]:
    """Restore a previous run's snapshot from ``state_dir`` into the
    ambient tuning/topology state.  Both restores are identity-gated: a
    profile whose device fingerprint differs (snapshot copied from another
    machine) or a topology whose (fingerprint, mesh signature) does not
    match the serving mesh is skipped, never trusted.  Returns the names
    of what was restored (for the startup log line)."""
    restored: List[str] = []
    d = pathlib.Path(state_dir)
    pp = _tuning.profile_path(directory=d)
    if pp.is_file():
        try:
            prof = _tuning.load(pp)
            if prof.fingerprint == _tuning.device_fingerprint():
                _tuning.set_active(dataclasses.replace(
                    prof, source="persisted"))
                restored.append("tuning profile")
        except _tuning.ProfileError:
            pass
    if mesh is not None:
        want = _topology.from_mesh(mesh)
        tp = _topology.topology_path(want, directory=d)
        if tp.is_file():
            try:
                topo = _topology.load(tp)
                if (topo.fingerprint == want.fingerprint
                        and topo.signature() == want.signature()):
                    _topology.set_active(dataclasses.replace(
                        topo, source="persisted"))
                    restored.append("topology")
            except _topology.TopologyError:
                pass
    return restored


def snapshot_state(state_dir: os.PathLike, mesh=None) -> List[pathlib.Path]:
    """Snapshot the ACTIVE TuningProfile (and, given the serving mesh, the
    resolved Topology) into ``state_dir`` so the next run starts from this
    run's calibration instead of the platform defaults.  Returns the
    written paths."""
    paths: List[pathlib.Path] = []
    d = pathlib.Path(state_dir)
    prof = _tuning.active()
    paths.append(_tuning.save(
        prof, _tuning.profile_path(prof.fingerprint, directory=d)))
    if mesh is not None:
        topo = _topology.for_mesh(mesh)
        paths.append(_topology.save(
            topo, _topology.topology_path(topo, directory=d)))
    return paths


def batch_accounting(done: List[Request]):
    """Per-prompt-length accounting of the completed requests — ONE
    ``relational.group_by`` (prompt length -> generated-token count) with
    ``agg=("count", "mean")``, i.e. the serving ledger expressed as the
    sort subsystem's group-by aggregate.  Returns ascending
    ``[(prompt_len, n_requests, mean_new_tokens), ...]``."""
    from repro import relational
    if not done:
        return []
    lens = jnp.asarray([len(r.prompt) for r in done], dtype=jnp.int32)
    gen = jnp.asarray([0 if r.out is None else len(r.out) for r in done],
                      dtype=jnp.int32)
    gb = relational.group_by(lens, gen, agg=("count", "mean"))
    g = int(gb.n_groups)
    keys = np.asarray(gb.keys[:g])
    cnt = np.asarray(gb.aggregates[0][:g])
    mean = np.asarray(gb.aggregates[1][:g])
    return [(int(k), int(c), float(m)) for k, c, m in zip(keys, cnt, mean)]


def serve(arch: str, smoke: bool = True, n_requests: int = 16,
          batch_size: int = 8, decode_steps: int = 32, topk: int = 50,
          seed: int = 0, max_len: int = 256,
          distributed_queue: Optional[bool] = None,
          state_dir: Optional[str] = None):
    """``distributed_queue`` routes the scheduler's backlog sort over the
    host mesh (defaults to on whenever the host offers >1 device).

    ``state_dir`` (or ``REPRO_SERVE_STATE_DIR``) makes the server
    stateful across restarts: on startup it restores the snapshotted
    TuningProfile + Topology (identity-gated), on shutdown it snapshots
    whatever is active — so a calibration paid once keeps pricing plans
    across process restarts."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh()
    sdir = resolve_state_dir(state_dir)
    if sdir is not None:
        got = restore_state(sdir, mesh)
        if got:
            print(f"[serve] restored {' + '.join(got)} from {sdir}")
    if distributed_queue is None:
        distributed_queue = mesh.shape["data"] > 1
    policy = ShardingPolicy(mesh=mesh, dp_axes=dp_axes_of(mesh))
    model = build(cfg, policy=policy)
    key = jax.random.PRNGKey(seed)
    params, _ = model.init(key)

    from repro.configs.base import ShapeSpec
    shape = ShapeSpec("serve", max_len, batch_size, "decode")
    serve_step = jax.jit(steps_lib.make_serve_step(model, shape,
                                                   sample_topk=topk))

    rng = np.random.default_rng(seed)
    sched = LengthSortedScheduler(
        batch_size, method=cfg.sort_method,
        mesh=mesh if distributed_queue else None)
    for rid in range(n_requests):
        plen = int(rng.integers(4, max_len // 4))
        sched.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32),
            max_new=decode_steps))

    done: List[Request] = []
    stats = {"batches": 0, "padding_waste": [], "decode_tps": []}
    try:
        _serve_loop(sched, model, params, serve_step, cfg, rng, key,
                    decode_steps, max_len, done, stats)
    finally:
        # shutdown snapshot — also on an exception mid-run, so a
        # calibration paid this run is never lost
        if sdir is not None:
            for p in snapshot_state(sdir, mesh):
                print(f"[serve] state snapshot -> {p}")
    waste = float(np.mean(stats["padding_waste"]))
    print(f"[serve] {len(done)} requests in {stats['batches']} batches; "
          f"mean padding waste {waste:.3f}; "
          f"decode {np.mean(stats['decode_tps']):.1f} tok/s")
    acct = batch_accounting(done)
    stats["length_groups"] = acct
    if acct:
        head = ", ".join(f"len={k}: {c} req x {m:.0f} tok"
                         for k, c, m in acct[:8])
        more = "" if len(acct) <= 8 else f" (+{len(acct) - 8} more)"
        print(f"[serve] length accounting: {head}{more}")
    if _obs.enabled():
        print(_obs_report.slo_report())
    return done, stats


def _serve_loop(sched, model, params, serve_step, cfg, rng, key,
                decode_steps, max_len, done, stats):
    while True:
        batch = sched.next_batch()
        if not batch:
            break
        stats["batches"] += 1
        stats["padding_waste"].append(sched.padding_waste(batch))
        if _obs.enabled():
            now = time.monotonic()
            for r in batch:
                _metrics.histogram("serve.queue_wait_ms").observe(
                    (now - r.submit_t) * 1e3)
            _metrics.histogram("serve.padding_waste").observe(
                stats["padding_waste"][-1])
            _metrics.counter("serve.requests").inc(len(batch))
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((len(batch), plen), np.int32)
        for i, r in enumerate(batch):   # left-pad to common length
            toks[i, plen - len(r.prompt):] = r.prompt
        feed = {"tokens": jnp.asarray(toks)}
        if model.is_encdec:
            feed["frames"] = jnp.asarray(rng.standard_normal(
                (len(batch), cfg.enc_seq, cfg.d_model)) * 0.1,
                dtype=jnp.float32)
        logits, state = model.prefill(params, feed, max_len=max_len)
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [nxt]
        t0 = time.monotonic()
        for i in range(decode_steps - 1):
            nxt, state = serve_step(params, nxt, state,
                                    jax.random.fold_in(key, i))
            outs.append(nxt)
        dt = time.monotonic() - t0
        stats["decode_tps"].append(
            (decode_steps - 1) * len(batch) / max(dt, 1e-9))
        gen = np.concatenate([np.array(o) for o in outs], axis=1)
        fin = time.monotonic()
        for i, r in enumerate(batch):
            r.out = gen[i]
            done.append(r)
            if _obs.enabled():
                _metrics.histogram("serve.e2e_ms").observe(
                    (fin - r.submit_t) * 1e3)
        if _obs.enabled():
            _metrics.gauge("serve.decode_tps").set(stats["decode_tps"][-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--topk", type=int, default=50)
    ap.add_argument("--distributed-queue", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="sort the request backlog over the host mesh "
                         "(--no-distributed-queue forces the local path; "
                         "default: on when the host has >1 device)")
    ap.add_argument("--state-dir", default=None,
                    help="directory for the tuning/topology snapshot "
                         "restored on startup and written on shutdown "
                         f"(default: ${SERVE_STATE_ENV} if set)")
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, n_requests=args.requests,
          batch_size=args.batch_size, decode_steps=args.decode_steps,
          topk=args.topk, distributed_queue=args.distributed_queue,
          state_dir=args.state_dir)


if __name__ == "__main__":
    main()
