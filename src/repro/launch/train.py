"""Production training driver.

Ties together: config registry -> model -> sharded train step (steps.py) ->
synthetic data pipeline -> async checkpointing -> fault-tolerance runtime
(preemption save, step watchdog, elastic resume).

On this CPU host it runs the smoke-scale configs end-to-end (examples use
it); on a pod the same driver runs the full configs — the step function and
shardings are identical to what the dry-run compiles.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ShapeSpec, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM, device_put_batch
from repro.launch import steps as steps_lib
from repro.launch.mesh import dp_axes_of, make_host_mesh
from repro.models.model_zoo import build
from repro.runtime.fault_tolerance import PreemptionHandler, StepWatchdog
from repro.sharding.partitioning import ShardingPolicy


def train(arch: str, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, microbatch: int = 1, lr: float = 3e-3,
          ckpt_dir: str = "", ckpt_every: int = 25, optimizer: str = "adamw",
          log_every: int = 5, resume: bool = True, seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    mesh = make_host_mesh()
    dp = dp_axes_of(mesh)
    policy = ShardingPolicy(mesh=mesh, dp_axes=dp)
    model = build(cfg, policy=policy)
    shape = ShapeSpec("custom", seq, batch, "train", microbatch)

    key = jax.random.PRNGKey(seed)
    params_abs, specs = steps_lib.abstract_init(model, key)
    specs = steps_lib.sanitize_specs(specs, params_abs, mesh)
    params_sh = steps_lib.shardings_of(specs, mesh)

    fn, optimizer_obj = steps_lib.make_train_step(
        model, cfg, shape, policy, optimizer_name=optimizer,
        microbatch=microbatch, peak_lr=lr, total_steps=steps)
    opt_abs = jax.eval_shape(optimizer_obj.init, params_abs)
    opt_specs = steps_lib.sanitize_specs(
        optimizer_obj.state_specs(specs, params_abs), opt_abs, mesh)
    opt_sh = steps_lib.shardings_of(opt_specs, mesh)
    bspecs = steps_lib.sanitize_specs(
        steps_lib.batch_specs(model, shape, policy),
        model.input_specs(shape), mesh)
    batch_sh = steps_lib.shardings_of(bspecs, mesh)

    jitted = jax.jit(fn,
                     in_shardings=(params_sh, opt_sh,
                                   NamedSharding(mesh, P()), batch_sh),
                     out_shardings=(params_sh, opt_sh, None),
                     donate_argnums=(0, 1))

    # init or resume
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    params = jax.jit(lambda k: model.init(k)[0],
                     out_shardings=params_sh)(key)
    opt_state = jax.jit(optimizer_obj.init, out_shardings=opt_sh)(params)
    if ckpt is not None and resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state_like = {"params": params, "opt": opt_state}
            sh_like = {"params": params_sh, "opt": opt_sh}
            restored, extra = ckpt.restore(latest, state_like, sh_like)
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(extra.get("next_step", latest))
            print(f"[train] resumed from step {latest} "
                  f"-> starting at {start_step}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))
    preempt = PreemptionHandler().install()
    watchdog = StepWatchdog()
    losses = []
    for step in range(start_step, steps):
        np_batch = data.global_batch_at(step)
        if model.is_encdec:
            rng = np.random.default_rng((seed, step, 7))
            np_batch["frames"] = rng.standard_normal(
                (batch, cfg.enc_seq, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.vision_prefix:
            rng = np.random.default_rng((seed, step, 8))
            np_batch["vision_embeds"] = rng.standard_normal(
                (batch, cfg.vision_prefix, cfg.d_model)
            ).astype(np.float32) * 0.1
            np_batch["positions"] = np.broadcast_to(
                np.arange(seq, dtype=np.int32), (3, batch, seq)).copy()
        dev_batch = device_put_batch(np_batch, mesh, dp)
        watchdog.start()
        params, opt_state, metrics = jitted(params, opt_state,
                                            jnp.asarray(step, jnp.int32),
                                            dev_batch)
        loss = float(metrics["loss"])
        dt = watchdog.stop(step)
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")
        should_save = ckpt is not None and (
            (step + 1) % ckpt_every == 0 or preempt.preempted
            or step == steps - 1)
        if should_save:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"next_step": step + 1})
        if preempt.preempted:
            print(f"[train] preemption requested — saved at {step + 1}, "
                  "exiting")
            break
    if ckpt is not None:
        ckpt.wait()
    preempt.uninstall()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()
    losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                   batch=args.batch, seq=args.seq,
                   microbatch=args.microbatch, lr=args.lr,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                   optimizer=args.optimizer)
    print(f"[train] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
