"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

Reads results/dryrun/<cell>.json (written by dryrun.py) and derives, per
(arch x shape x mesh), the three per-device roofline terms in SECONDS:

    compute    = HLO_FLOPs / peak_FLOPs          (197 TF/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw              (819 GB/s)
    collective = collective_bytes / ICI_bw       (50 GB/s/link)

Sources & caveats (measured in this environment, see hlo_analysis.py):
  * XLA's cost_analysis counts while bodies ONCE and is per-device; the
    trip-count-corrected numbers from hlo_analysis are used as primary,
    with raw cost_analysis retained in the JSON for reference.
  * flops counts `dot` ops only (elementwise excluded — sub-1% at these
    arithmetic intensities, except noted for the bit-serial paths).
  * hbm_bytes uses operands+results at CPU-fusion granularity — an upper
    bound on TPU HBM traffic (TPU fuses more aggressively).
  * collective bytes: all-reduce counted 2x (ring RS+AG), others 1x result
    bytes; (g-1)/g ~ 1.

MODEL_FLOPS (the "useful" flops): 6*N_active*tokens for training,
2*N_active*tokens for prefill/decode; the ratio MODEL/HLO catches remat and
routing overheads.  The bound on MFU is MODEL_time / max(term).
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def model_flops_per_device(rec: dict) -> float:
    from repro.configs.base import SHAPES
    shape = SHAPES[rec["shape"]]
    n_act = rec["n_active_params"]
    if rec["kind"] == "train":
        total = 6.0 * n_act * shape.tokens
    elif rec["kind"] == "prefill":
        total = 2.0 * n_act * shape.tokens
    else:  # decode: one token per sequence per step
        total = 2.0 * n_act * shape.global_batch
    return total / rec["n_devices"]


def analytic_bytes_per_device(rec: dict) -> Dict[str, float]:
    """Analytic HBM traffic model of THIS implementation (B/device/step).

    Terms (documented in EXPERIMENTS.md §Roofline): weight streaming
    (FSDP-gathered per layer, fwd+remat+bwd), gradient accumulation,
    optimizer state, remat-saved residuals, attention score matrices
    (q-chunked but HBM-materialised, fp32, 3 passes — the dominant term for
    long-sequence cells, and precisely what a fused flash kernel removes),
    MoE dispatch buffers, KV cache, logits.  The HLO-derived figure
    (hbm_bytes) is retained as a fusion-granularity upper bound.
    """
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    nd = rec["n_devices"]
    dp = nd // 16                      # model axis is 16 in both meshes
    tp = 16
    plan = rec.get("plan", {})
    m = max(1, plan.get("microbatch", 1))
    sp = tp if plan.get("seq_shard") else 1
    P = rec["n_params"]
    Pd = P / nd * 2.0                  # bf16 weight bytes per device
    L, D = cfg.n_layers, cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    tok_dev = B * S / dp if shape.kind != "decode" else B / dp
    out: Dict[str, float] = {}

    # attention geometry (per device)
    n_heads_loc = max(1, cfg.n_heads // tp) if cfg.n_heads % tp == 0 \
        else cfg.n_heads
    attn_layers = sum(1 for i in range(L) if cfg.layer_kind(i) == "attn")

    if shape.kind == "train":
        passes = 3                     # fwd + remat-fwd + bwd
        out["weights"] = passes * Pd * m
        out["grads"] = (2 * m + 1) * 4 * P / nd
        opt = plan.get("optimizer", "adamw")
        out["optimizer"] = (8 + (16 if opt == "adamw" else 1) + 2) * P / nd
        out["activations"] = 2 * L * tok_dev * D * 2 / sp
        kv_eff = S if not cfg.window else min(S, cfg.window)
        out["attn_scores"] = (passes * attn_layers * (B / dp / m)
                              * n_heads_loc * S * kv_eff * 4.0 * m)
        out["logits"] = 3 * tok_dev * cfg.padded_vocab / tp * 4.0
        if cfg.moe:
            cap = S * cfg.moe.top_k * cfg.moe.capacity_factor \
                / cfg.moe.n_experts
            out["moe_buffers"] = (passes * 2 * (L - cfg.moe.first_dense_layers)
                                  * (B / dp) * cfg.moe.n_experts * cap
                                  * D * 2 / tp)
    elif shape.kind == "prefill":
        out["weights"] = Pd
        out["activations"] = 2 * L * tok_dev * D * 2 / sp
        kv_eff = S if not cfg.window else min(S, cfg.window)
        if plan.get("flash"):
            # in-VMEM scores: only the q/k/v/o streams touch HBM
            out["attn_scores"] = (attn_layers * (B / dp) * n_heads_loc
                                  * S * cfg.resolved_head_dim * 4 * 2.0)
        else:
            out["attn_scores"] = (attn_layers * (B / dp) * n_heads_loc
                                  * S * kv_eff * 4.0)
        kvh = max(cfg.n_kv_heads, min(tp, cfg.n_heads))
        out["kv_cache_write"] = (attn_layers * (B / dp) * S
                                 * kvh * cfg.resolved_head_dim * 2 * 2 / tp)
        out["logits"] = (B / dp) * cfg.padded_vocab / tp * 4.0
    else:  # decode: stream weights + cache once per token
        out["weights"] = Pd
        kvh = max(cfg.n_kv_heads, min(tp, cfg.n_heads))
        kv_eff = S if not cfg.window else min(S, cfg.window)
        cache_shard = tp if (kvh % tp == 0 or
                             cfg.resolved_head_dim % tp == 0) else 1
        out["kv_cache_read"] = (attn_layers * (B / dp) * kv_eff * kvh
                                * cfg.resolved_head_dim * 2 * 2 / cache_shard)
        if cfg.ssm:
            dims_state = (cfg.ssm.expand * D // cfg.ssm.head_dim
                          * cfg.ssm.head_dim * cfg.ssm.d_state)
            out["ssm_state"] = 2 * L * (B / dp) * dims_state * 4.0
        out["logits"] = (B / dp) * cfg.padded_vocab / tp * 4.0
    out["total"] = sum(out.values())
    return out


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    h = rec.get("hlo_analysis", {})
    flops = h.get("flops", 0.0)
    hbm_upper = h.get("hbm_bytes", 0.0)
    analytic = analytic_bytes_per_device(rec)
    hbm = analytic["total"]
    coll = h.get("collective_total_bytes", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    t_bound = max(terms.values())
    mfu_bound = (mf / PEAK_FLOPS) / t_bound if t_bound > 0 else 0.0
    mem_top = max((k for k in analytic if k != "total"),
                  key=analytic.get) if analytic else ""
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_memory_upper_s": hbm_upper / HBM_BW,
        "dominant": dominant,
        "memory_breakdown": analytic,
        "memory_top_term": mem_top,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "mfu_bound": mfu_bound,
        "collective_bytes_by_kind": h.get("collective_bytes", {}),
        "plan": rec.get("plan", {}),
    }


_FIX_HINTS = {
    ("compute", "train"): "more useful-flops share: trim remat recompute "
                          "(save attention outputs) or raise per-chip batch",
    ("compute", "prefill"): "compute-bound as desired; fuse attention "
                            "(flash) to cut the redundant score passes",
    ("compute", "decode"): "decode should be memory-bound; compute "
                           "domination means routing/sampling overhead — "
                           "shrink sort network width",
    ("memory", "train"): "raise arithmetic intensity: larger microbatch, "
                         "fuse optimizer update, keep weights resident",
    ("memory", "prefill"): "tile KV streaming (flash) to cut score-matrix "
                           "traffic",
    ("memory", "decode"): "expected regime (weights+cache streaming); "
                          "shrink the KV cache (window/quantise) or batch "
                          "more sequences",
    ("collective", "train"): "overlap grad all-reduce with microbatch "
                             "compute; shard params less on 'data' "
                             "(fewer all-gathers) or compress cross-pod",
    ("collective", "prefill"): "reshard activations less often; prefer "
                               "head-sharded attention end-to-end",
    ("collective", "decode"): "TP all-reduce per layer dominates: use "
                              "collective-matmul overlap or reduce TP "
                              "degree for decode",
}


def fix_hint(row: dict) -> str:
    return _FIX_HINTS.get((row["dominant"], row["kind"]), "")


def load_all(tag: str = "") -> List[dict]:
    rows = []
    for p in sorted((RESULTS / "dryrun").glob("*.json")):
        if tag and not p.stem.endswith(tag):
            continue
        if not tag and any(p.stem.endswith(t) for t in ("_opt", "_exp")):
            continue
        rec = json.loads(p.read_text())
        row = analyze_record(rec)
        if row is not None:
            rows.append(row)
    return rows


def markdown_table(rows: List[dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | MFU bound | what would move it |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_bound']*100:.1f}% | {fix_hint(r)} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_all(args.tag)
    (RESULTS / "roofline.json").write_text(json.dumps(rows, indent=1))
    print(markdown_table(rows, args.mesh))
    # summary: the three hillclimb candidates
    base = [r for r in rows if r["mesh"] == "16x16"]
    if base:
        worst = min(base, key=lambda r: r["mfu_bound"])
        coll = max(base, key=lambda r: r["t_collective_s"]
                   / max(max(r["t_compute_s"], r["t_memory_s"]), 1e-12))
        print(f"\nworst MFU bound: {worst['arch']}/{worst['shape']} "
              f"({worst['mfu_bound']*100:.1f}%)")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']}")


if __name__ == "__main__":
    main()
