import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the production meshes need 512 placeholder host devices.
Never set that flag globally — smoke tests and benchmarks see 1 device.

Per cell this driver:
  1. builds the production mesh (16x16 or 2x16x16) and the ShardingPolicy,
  2. builds the EXACT production step function (launch/steps.py),
  3. ``jax.jit(step, in/out_shardings).lower(**ShapeDtypeStructs)`` —
     no arrays are ever allocated,
  4. ``lowered.compile()`` — any sharding mismatch / unsupported collective
     / compile-time OOM fails the cell,
  5. records memory_analysis / cost_analysis / per-collective byte counts
     (parsed from the post-SPMD HLO) into results/dryrun/<cell>.json for
     §Dry-run, §Roofline and §Perf.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--tag baseline]
"""
import argparse
import collections
import dataclasses
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, cell_is_supported,
                                get_config)
from repro.launch import steps as steps_lib
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.model_zoo import build
from repro.sharding.partitioning import ShardingPolicy

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# Per-(arch, shape-kind) training knobs: microbatch count, sequence
# parallelism, optimizer, grad-accum dtype.  Derived from the memory napkin
# math in EXPERIMENTS.md §Dry-run.
@dataclasses.dataclass(frozen=True)
class CellPlan:
    microbatch: int = 1
    seq_shard: bool = False
    optimizer: str = "adamw"
    accum: str = "float32"
    flash: bool = False        # in-VMEM flash attention (prefill cells)
    layout: str = "tp"         # tp | dp (DP-heavy serve layout)


TRAIN_PLAN = {
    "whisper_tiny": CellPlan(microbatch=8),
    "deepseek_67b": CellPlan(microbatch=2, seq_shard=True),
    "minitron_4b": CellPlan(microbatch=2, seq_shard=True),
    "gemma_2b": CellPlan(microbatch=4, seq_shard=True),
    "nemotron_4_340b": CellPlan(microbatch=8, seq_shard=True,
                                optimizer="adafactor", accum="bfloat16"),
    "moonshot_v1_16b": CellPlan(microbatch=4),
    "dbrx_132b": CellPlan(microbatch=16, optimizer="adafactor"),
    "recurrentgemma_2b": CellPlan(microbatch=4),
    "qwen2_vl_72b": CellPlan(microbatch=2, seq_shard=True),
    "mamba2_13b": CellPlan(microbatch=8),
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """bytes of one HLO shape string like 'bf16[16,4096,2048]' (or a tuple)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collect_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    Wire-byte convention (documented in §Roofline): all-reduce counts 2x its
    tensor bytes (reduce-scatter + all-gather phases of a ring); the others
    count 1x their result bytes; the ring (g-1)/g factor is dropped (~1).
    """
    by_kind = collections.Counter()
    bytes_by_kind = collections.Counter()
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or " = " in s:
            m = re.search(r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s*"
                          r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                          r"collective-permute)", s)
            if not m:
                continue
            type_str, kind = m.group(1), m.group(2)
            b = _shape_bytes(type_str)
            mult = 2 if kind == "all-reduce" else 1
            by_kind[kind] += 1
            bytes_by_kind[kind] += b * mult
    return {"counts": dict(by_kind), "bytes": dict(bytes_by_kind),
            "total_bytes": int(sum(bytes_by_kind.values()))}


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               plan: CellPlan = None, seq_shard=None, microbatch=None,
               flash=None, layout=None, verbose: bool = True):
    """Build, lower, compile one cell; return the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes_of(mesh)
    plan = plan or (TRAIN_PLAN[arch] if shape.kind == "train" else CellPlan())
    if seq_shard is not None:
        plan = dataclasses.replace(plan, seq_shard=seq_shard)
    if microbatch is not None:
        plan = dataclasses.replace(plan, microbatch=microbatch)
    if flash:
        plan = dataclasses.replace(plan, flash=True)
    if layout is not None:
        plan = dataclasses.replace(plan, layout=layout)
    if plan.flash:
        cfg = dataclasses.replace(cfg, flash_prefill=True)
    policy = ShardingPolicy(
        mesh=mesh, dp_axes=dp,
        seq_shard=(plan.seq_shard and shape.kind == "train")
        or plan.layout == "cp",
        serve_layout=plan.layout in ("dp", "cp"),
        cp_layout=plan.layout == "cp")
    model = build(cfg, policy=policy)

    key = jax.random.PRNGKey(0)
    params_abs, specs = steps_lib.abstract_init(model, key)
    if plan.layout in ("dp", "cp"):
        # serve layouts: transform per-layer weights only
        for sub in ("prefix", "body", "enc", "dec"):
            if sub in specs:
                specs[sub] = policy.serve_param_specs(
                    specs[sub], keep_data=plan.layout == "cp")
    specs = steps_lib.sanitize_specs(specs, params_abs, mesh)
    params_sh = steps_lib.shardings_of(specs, mesh)
    batch_abs = model.input_specs(shape)
    bspecs = steps_lib.sanitize_specs(
        steps_lib.batch_specs(model, shape, policy), batch_abs, mesh)
    batch_sh = steps_lib.shardings_of(bspecs, mesh)
    t0 = time.time()

    if shape.kind == "train":
        accum = jnp.bfloat16 if plan.accum == "bfloat16" else jnp.float32
        fn, optimizer = steps_lib.make_train_step(
            model, cfg, shape, policy, optimizer_name=plan.optimizer,
            microbatch=plan.microbatch, accum_dtype=accum)
        opt_abs = _abstract(optimizer.init, params_abs)
        opt_specs = steps_lib.sanitize_specs(
            optimizer.state_specs(specs, params_abs), opt_abs, mesh)
        opt_sh = steps_lib.shardings_of(opt_specs, mesh)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, opt_sh, NamedSharding(mesh, P()),
                          batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1))
        lowered = jitted.lower(params_abs, opt_abs, step_abs, batch_abs)
    elif shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(model, shape)
        state_abs = _abstract(fn, params_abs, batch_abs)[1]
        st_specs = steps_lib.sanitize_specs(
            steps_lib.decode_state_specs(state_abs, policy), state_abs, mesh)
        st_sh = steps_lib.shardings_of(st_specs, mesh)
        logits_sh = NamedSharding(mesh, P(dp, None))
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                         out_shardings=(logits_sh, st_sh))
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        serve = steps_lib.make_serve_step(model, shape, sample_topk=50)
        if model.is_encdec:
            pf_batch = {
                "tokens": jax.ShapeDtypeStruct(
                    (shape.global_batch, 32), jnp.int32),
                "frames": jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.enc_seq, cfg.d_model),
                    jnp.bfloat16)}
            state_abs = _abstract(
                lambda p, b: model.prefill(p, b, max_len=shape.seq_len)[1],
                params_abs, pf_batch)
        else:
            state_abs = _abstract(
                lambda: model.decode_state(shape.global_batch,
                                           shape.seq_len))
        st_specs = steps_lib.sanitize_specs(
            steps_lib.decode_state_specs(state_abs, policy), state_abs, mesh)
        st_sh = steps_lib.shardings_of(st_specs, mesh)
        token_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        token_spec = steps_lib.sanitize_specs(P(dp, None), token_abs, mesh)
        token_sh = NamedSharding(mesh, token_spec)
        rng_abs = _abstract(lambda: jax.random.PRNGKey(0))
        jitted = jax.jit(serve,
                         in_shardings=(params_sh, token_sh, st_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=(token_sh, st_sh))
        lowered = jitted.lower(params_abs, token_abs, state_abs, rng_abs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo = compiled.as_text()
    coll = collect_collectives(hlo)
    from repro.launch import hlo_analysis
    corrected = hlo_analysis.analyze(hlo)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "plan": dataclasses.asdict(plan),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "memory": {k: int(v) for k, v in {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        }.items()},
        "collectives": coll,               # raw (loop bodies counted once)
        "hlo_analysis": corrected,         # trip-count-corrected, per device
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_lines": hlo.count("\n"),
    }
    if verbose:
        print(f"  memory_analysis: {record['memory']}")
        print(f"  cost_analysis: flops={record['flops']:.3e} "
              f"bytes={record['bytes_accessed']:.3e}")
        print(f"  collectives: {coll['counts']} "
              f"total={coll['total_bytes']:.3e} B")
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool, tag: str = "",
             **kw):
    name = f"{arch}_{shape_name}_{'2x16x16' if multi_pod else '16x16'}"
    if tag:
        name += f"_{tag}"
    print(f"[dryrun] {name} ...", flush=True)
    t0 = time.time()
    try:
        rec = lower_cell(arch, shape_name, multi_pod, **kw)
        rec["ok"] = not rec.get("skipped", False)
        status = "SKIP" if rec.get("skipped") else "OK"
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        status = "FAIL"
    rec["wall_s"] = round(time.time() - t0, 1)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] {name}: {status} ({rec['wall_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--seq-shard", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--flash", action="store_true")
    ap.add_argument("--layout", default=None)
    args = ap.parse_args()

    kw = {}
    if args.seq_shard is not None:
        kw["seq_shard"] = bool(args.seq_shard)
    if args.microbatch is not None:
        kw["microbatch"] = args.microbatch
    if args.flash:
        kw["flash"] = True
    if args.layout:
        kw["layout"] = args.layout

    from repro.configs.base import ALIASES
    cells = []
    archs = ARCH_IDS if args.all or not args.arch else \
        [ALIASES.get(args.arch, args.arch.replace("-", "_"))]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failed = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, tag=args.tag, **kw)
        if not rec.get("ok") and not rec.get("skipped"):
            failed += 1
    print(f"[dryrun] done: {len(cells)} cells, {failed} failures")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
