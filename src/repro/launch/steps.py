"""Step-function builders: train / prefill / serve, with their shardings.

These are the single source of truth for what gets jitted, lowered in the
dry-run, benchmarked, and executed by train.py / serve.py — so the dry-run
compiles EXACTLY the production step.

train_step = grad-accumulation scan over microbatches (fits the 4k x 256
global batch on the big dense configs and overlaps the cross-pod gradient
all-reduce with the next microbatch's compute) + optimizer update + bf16
parameter refresh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model_zoo import Model
from repro.optim import optimizers as opt_lib
from repro.sharding.partitioning import ShardingPolicy


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def batch_specs(model: Model, shape: ShapeSpec, policy: ShardingPolicy):
    """PartitionSpec tree matching model.input_specs(shape)."""
    dp = policy.dp_axes
    specs = {}
    for name, sds in model.input_specs(shape).items():
        if name == "positions":            # (3, B, S)
            specs[name] = P(None, dp, None)
        else:
            specs[name] = P(dp, *([None] * (len(sds.shape) - 1)))
    return specs


def _state_leaf_spec(path_str: str, leaf, policy: ShardingPolicy,
                     tp_ok) -> P:
    dp = policy.dp_axes
    body = "body" in path_str
    nd = leaf.ndim - (1 if body else 0)    # strip stacked-layer axis
    lead = (None,) if body else ()
    if nd == 4:                            # KV cache (B, S, R, H)
        s, r, h = leaf.shape[-3], leaf.shape[-2], leaf.shape[-1]
        tp = policy.tp_size
        if getattr(policy, "serve_layout", False) and tp > 1 \
                and s % tp == 0:
            # DP-heavy serve layout: cache shards on SEQUENCE
            return P(*lead, dp, policy.tp_axis, None, None)
        if tp > 1 and r % tp == 0:
            return P(*lead, dp, None, policy.tp_axis, None)
        if tp > 1 and h % tp == 0:
            return P(*lead, dp, None, None, policy.tp_axis)
        return P(*lead, dp, None, None, None)
    if nd == 0:
        return P()
    return P(*lead, dp, *([None] * (nd - 1)))


def decode_state_specs(state_abstract, policy: ShardingPolicy):
    def spec(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return _state_leaf_spec(pstr, leaf, policy, None)
    return jax.tree_util.tree_map_with_path(spec, state_abstract)


def sanitize_specs(specs, abstract, mesh: Optional[Mesh]):
    """Drop spec entries whose dimension does not divide the mesh axes —
    the safety net that lets odd sizes (vocab 51865, batch 1) compile
    replicated instead of erroring."""
    if mesh is None:
        return specs

    def fix(spec, arr):
        if not isinstance(spec, P):
            return spec
        entries = tuple(spec)
        out = []
        for i, entry in enumerate(entries):
            if entry is None or i >= arr.ndim:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            # drop axes absent from this mesh (host meshes have no 'model')
            axes = tuple(a for a in axes if a in mesh.shape)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if not axes or arr.shape[i] % size != 0:
                out.append(None)
            else:
                out.append(axes if len(axes) > 1 else axes[0])
        return P(*out)

    return jax.tree.map(fix, specs, abstract,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_init(model: Model, key):
    """(abstract params, partition specs) without allocating anything."""
    box = {}

    def params_only(k):
        p, s = model.init(k)
        box["specs"] = s
        return p

    params_abs = jax.eval_shape(params_only, key)
    return params_abs, box["specs"]


def shardings_of(tree_specs, mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainStep:
    fn: Any                    # (params, opt_state, step, batch) -> ...
    params_specs: Any
    opt_specs: Any
    batch_specs: Any

    def jit(self, mesh: Optional[Mesh], donate: bool = True):
        in_sh = (shardings_of(self.params_specs, mesh),
                 shardings_of(self.opt_specs, mesh),
                 NamedSharding(mesh, P()) if mesh else None,
                 shardings_of(self.batch_specs, mesh))
        out_sh = (shardings_of(self.params_specs, mesh),
                  shardings_of(self.opt_specs, mesh),
                  NamedSharding(mesh, P()) if mesh else None)
        kw = dict(donate_argnums=(0, 1)) if donate else {}
        if mesh is None:
            return jax.jit(self.fn, **kw)
        return jax.jit(self.fn, in_shardings=in_sh, out_shardings=out_sh,
                       **kw)


def build_train_step(model: Model, optimizer: opt_lib.Optimizer,
                     policy: ShardingPolicy, shape: ShapeSpec,
                     microbatch: int = 1, accum_dtype=jnp.float32,
                     grad_compressor=None) -> TrainStep:
    def loss_fn(params, mb):
        loss, aux = model.loss(params, mb)
        return loss, aux

    def train_step(params, opt_state, step, batch):
        if microbatch > 1:
            def split(x):
                b = x.shape[0]
                if x.ndim >= 2 and x.shape[0] == 3:   # (3,B,S) positions
                    return jnp.moveaxis(
                        x.reshape(3, microbatch, -1, *x.shape[2:]), 1, 0)
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def accum(carry, mb):
                gsum, lsum = carry
                (loss, aux), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), mbs)
            grads = jax.tree.map(
                lambda g: g.astype(jnp.float32) / microbatch, gsum)
            loss = lsum / microbatch
        else:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if grad_compressor is not None:
            grads, opt_state = grad_compressor(grads, opt_state)
        new_opt, info = optimizer.update(grads, opt_state, step)
        new_params = opt_lib.cast_like_params(new_opt["master"], params)
        metrics = {"loss": loss, **info}
        return new_params, new_opt, metrics

    return train_step


def make_train_step(model: Model, cfg: ModelConfig, shape: ShapeSpec,
                    policy: ShardingPolicy, optimizer_name: str = "adamw",
                    microbatch: int = 1, peak_lr: float = 3e-4,
                    total_steps: int = 10000, accum_dtype=jnp.float32,
                    grad_compressor=None):
    """Returns (train_step_fn, optimizer) ready to jit/lower."""
    sched = opt_lib.cosine_schedule(peak_lr, warmup=min(500, total_steps // 10),
                                    total=total_steps)
    optimizer = (opt_lib.adafactor(sched) if optimizer_name == "adafactor"
                 else opt_lib.adamw(sched))
    fn = build_train_step(model, optimizer, policy, shape,
                          microbatch=microbatch, accum_dtype=accum_dtype,
                          grad_compressor=grad_compressor)
    return fn, optimizer


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(model: Model, shape: ShapeSpec):
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, max_len=shape.seq_len)
        return logits, state
    return prefill_step


def make_serve_step(model: Model, shape: ShapeSpec, sample_topk: int = 0):
    """One decode step: token -> logits -> (sampled) next token + new state.

    With sample_topk > 0 the next token comes from top-k sampling through
    the k-aware ``repro.sort`` front door (cfg.sort_method, default
    "auto"): vocab-sized logits with k ~ 50 are the textbook selection
    workload, so the planner routes them to radix-select, not a sort.
    """
    method = model.cfg.sort_method

    def serve_step(params, token, state, rng):
        logits, new_state = model.decode_step(params, token, state)
        if sample_topk:
            from repro import sort as sorting
            v, i = sorting.topk(logits, sample_topk, method=method)
            gumbel = -jnp.log(-jnp.log(
                jax.random.uniform(rng, v.shape) + 1e-9) + 1e-9)
            choice = jnp.argmax(v / 1.0 + gumbel, axis=-1)
            nxt = jnp.take_along_axis(i, choice[..., None], axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)[..., None]
        return nxt.astype(jnp.int32), new_state

    return serve_step
