"""Generate the §Dry-run summary table from results/dryrun/*.json."""
from __future__ import annotations

import json
import pathlib
from typing import Optional, Union

# default location only — every entry point takes an explicit results dir
RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_Path = Union[str, pathlib.Path]


def rows(mesh: Optional[str] = None, results_dir: Optional[_Path] = None):
    """Parsed result records, optionally filtered to one mesh shape.

    ``mesh`` keeps only records whose ``"mesh"`` field matches, plus
    skipped records (they carry no mesh — a skip is mesh-independent).
    ``results_dir`` overrides the default ``results/dryrun`` location.
    """
    base = pathlib.Path(results_dir) if results_dir is not None else RESULTS
    out = []
    for p in sorted(base.glob("*.json")):
        if any(p.stem.endswith(t) for t in ("_flash", "_opt", "_exp")):
            continue
        r = json.loads(p.read_text())
        if mesh and not r.get("skipped") and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def markdown(mesh: str = "16x16",
             results_dir: Optional[_Path] = None) -> str:
    hdr = ("| arch | shape | status | temp GB/dev | args GB/dev | "
           "HLO flops/dev | coll bytes/dev | compile s |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = [hdr]
    for r in rows(mesh, results_dir=results_dir):
        if r.get("skipped"):
            if mesh == "16x16":   # print skips once
                lines.append(f"| {r['arch']} | {r['shape']} | SKIP "
                             f"({r['reason'][:40]}...) | | | | | |\n")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | **FAIL** "
                         f"| | | | | |\n")
            continue
        mem = r["memory"]
        h = r.get("hlo_analysis", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{mem['temp_bytes']/1e9:.1f} | "
            f"{mem['argument_bytes']/1e9:.2f} | "
            f"{h.get('flops', 0):.2e} | "
            f"{h.get('collective_total_bytes', 0):.2e} | "
            f"{r.get('compile_s', 0):.0f} |\n")
    return "".join(lines)


def status_counts(mesh: Optional[str] = None,
                  results_dir: Optional[_Path] = None):
    ok = fail = skip = 0
    for r in rows(mesh, results_dir=results_dir):
        if r.get("skipped"):
            skip += 1
        elif r.get("ok"):
            ok += 1
        else:
            fail += 1
    return ok, fail, skip


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(markdown(mesh))
    print("status:", status_counts())
