"""The paper's own workload config: N-input, W-bit in-memory sorting units."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SortUnitConfig:
    n_inputs: int = 8
    width: int = 4
    method: str = "imc"       # imc | bitonic | pallas | xla


PAPER_UNIT = SortUnitConfig()
