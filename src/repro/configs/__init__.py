"""Architecture configs (one module per assigned arch) + shape registry."""
from repro.configs.base import (ALIASES, ARCH_IDS, SHAPES, ModelConfig,
                                MoEConfig, RGLRUConfig, SSMConfig, ShapeSpec,
                                cell_is_supported, get_config,
                                get_smoke_config)
