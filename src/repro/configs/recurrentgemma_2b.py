"""recurrentgemma-2b [hybrid]: 26L, d=2560, 10H (MQA kv=1), ff=7680,
vocab=256000; RG-LRU : local-attention 2:1, window 2048.

[arXiv:2402.19427 Griffin]  Sub-quadratic -> runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, mlp_type="geglu", norm_type="rmsnorm",
    tie_embeddings=True, emb_scale=True, window=2048, max_seq=525312,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4,
                      block_pattern=("rglru", "rglru", "attn")),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, mlp_type="geglu", norm_type="rmsnorm",
        tie_embeddings=True, emb_scale=True, window=8, max_seq=64,
        rglru=RGLRUConfig(lru_width=64, conv_width=4,
                          block_pattern=("rglru", "rglru", "attn")),
    )
