"""Model/shape configuration system and the architecture registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves them, and each also provides
a ``smoke()`` reduction (same family, tiny dims) for CPU tests.

Input-shape cells (assigned per architecture) are ``ShapeSpec`` instances:
  train_4k     seq 4096  x global batch 256   -> train_step
  prefill_32k  seq 32768 x global batch 32    -> prefill_step
  decode_32k   cache 32768, batch 128         -> serve_step (1 new token)
  long_500k    cache 524288, batch 1          -> serve_step (sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # "auto": the k-aware planner weighs radix-select vs sort-prefix per
    # (n_experts, top_k); any registered backend name forces one engine
    router_method: str = "auto"
    first_dense_layers: int = 0         # leading layers use a dense MLP


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0                  # 0 -> d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"             # swiglu | geglu | relu2 | gelu
    norm_type: str = "rmsnorm"
    rope_theta: float = 10000.0
    rope_type: str = "standard"          # standard | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t/h/w split of head_dim/2
    tie_embeddings: bool = False
    emb_scale: bool = False              # gemma: scale embeddings by sqrt(d)
    logits_softcap: float = 0.0
    window: int = 0                      # local attention window (0 = global)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                     # encoder frames (frontend stub length)
    # vlm frontend stub
    vision_prefix: int = 0               # leading positions fed by patch embeds
    dtype: str = "bfloat16"
    # which mixer each layer uses; derived for hybrid families
    max_seq: int = 8192                  # positional guardrail only (no abs emb)
    # backend for sampling/routing sorts and top-k; "auto" = planner pick
    # (selection for k << n sampling, a sort engine for full orders)
    sort_method: str = "auto"
    flash_prefill: bool = False          # in-VMEM flash kernel for prefill

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 512 (Megatron-style padding) so
        the logits dimension shards on any mesh axis; padded slots are
        masked to -inf in logits_from_hidden."""
        if self.vocab_size % 512 == 0 or self.vocab_size < 4096:
            return self.vocab_size
        return ((self.vocab_size + 511) // 512) * 512

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, i: int) -> str:
        """Mixer for layer i: attn | ssm | rglru."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.rglru is not None:
            pat = self.rglru.block_pattern
            return pat[i % len(pat)]
        return "attn"

    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        gated = self.mlp_type in ("swiglu", "geglu")
        mlp = d * f * (3 if gated else 2)
        total = 0
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += attn
            elif kind == "ssm":
                s = self.ssm
                din = s.expand * d
                nheads = din // s.head_dim
                total += d * (2 * din + 2 * s.d_state + nheads) + din * d
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                total += d * w * 2 + w * d + 3 * w * self.rglru.conv_width \
                    + 2 * w * w
            if self.moe is not None and i >= self.moe.first_dense_layers \
                    and kind != "ssm":
                fe = self.moe.d_ff_expert
                per = d * fe * (3 if gated else 2)
                total += per * (self.moe.n_experts + self.moe.n_shared_experts)
                total += d * self.moe.n_experts
            elif kind == "attn" or kind == "rglru":
                total += mlp if kind == "attn" else 0
            total += 2 * d  # norms
        total += v * d * (1 if self.tie_embeddings else 2)
        enc_attn = 4 * d * d + mlp
        total += self.n_enc_layers * (enc_attn + attn)  # enc + cross-attn approx
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters: MoE counts only top-k experts."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        d = self.d_model
        gated = self.mlp_type in ("swiglu", "geglu")
        per = d * self.moe.d_ff_expert * (3 if gated else 2)
        n_moe_layers = self.n_layers - self.moe.first_dense_layers
        inactive = per * (self.moe.n_experts - self.moe.top_k) * n_moe_layers
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    microbatch: int = 1            # gradient-accumulation steps (train only)

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "whisper_tiny", "deepseek_67b", "minitron_4b", "gemma_2b",
    "nemotron_4_340b", "moonshot_v1_16b", "dbrx_132b",
    "recurrentgemma_2b", "qwen2_vl_72b", "mamba2_13b",
)

# display name -> module id
ALIASES = {
    "whisper-tiny": "whisper_tiny", "deepseek-67b": "deepseek_67b",
    "minitron-4b": "minitron_4b", "gemma-2b": "gemma_2b",
    "nemotron-4-340b": "nemotron_4_340b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b", "dbrx-132b": "dbrx_132b",
    "recurrentgemma-2b": "recurrentgemma_2b", "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-1.3b": "mamba2_13b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke()


def cell_is_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell applies (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense KV unsupported"
    return True, ""
