"""gemma-2b [dense]: 18L, d=2048, 8H (MQA kv=1), head_dim=256, ff=16384,
vocab=256000.  [arXiv:2403.08295]  GeGLU, embedding scaling, tied softmax.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, mlp_type="geglu", norm_type="rmsnorm",
    tie_embeddings=True, emb_scale=True, rope_theta=10000.0, max_seq=33024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=192, vocab_size=256, mlp_type="geglu", norm_type="rmsnorm",
        tie_embeddings=True, emb_scale=True, max_seq=64,
    )
