"""mamba2-1.3b [ssm]: 48L, d=2048, attention-free, vocab=50280,
ssm_state=128.  [arXiv:2405.21060]  SSD (state-space duality) mixer;
sub-quadratic -> runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280, norm_type="rmsnorm", rope_type="none",
    tie_embeddings=True, max_seq=525312,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=256, norm_type="rmsnorm", rope_type="none",
        tie_embeddings=True, max_seq=64,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                      chunk=8),
    )
