"""deepseek-67b [dense]: 95L, d=8192, 64H (GQA kv=8), ff=22016, vocab=102400.

[arXiv:2401.02954]  Llama architecture: RMSNorm, RoPE, SwiGLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=102400, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=10000.0, max_seq=33024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=256, mlp_type="swiglu", norm_type="rmsnorm", max_seq=64,
    )
