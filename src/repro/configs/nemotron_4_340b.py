"""nemotron-4-340b [dense]: 96L, d=18432, 96H (GQA kv=8), ff=73728,
vocab=256000.  [arXiv:2402.16819]  Squared-ReLU MLP, RoPE, LayerNorm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, mlp_type="relu2", norm_type="layernorm",
    rope_theta=10000.0, max_seq=33024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke", family="dense",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=384,
        vocab_size=256, mlp_type="relu2", norm_type="layernorm", max_seq=64,
    )
