"""minitron-4b [dense]: 32L, d=3072, 24H (GQA kv=8), ff=9216, vocab=256000.

[arXiv:2407.14679]  Pruned Nemotron-4: RoPE, squared-ReLU MLP (non-gated).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab_size=256000, mlp_type="relu2", norm_type="layernorm",
    rope_theta=10000.0, max_seq=33024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab_size=256, mlp_type="relu2", norm_type="layernorm", max_seq=64,
    )
