"""dbrx-132b [moe]: 40L, d=6144, 48H (GQA kv=8), expert ff=10752,
vocab=100352, MoE 16 experts top-4.

[hf:databricks/dbrx-base]  Fine-grained GLU experts, RoPE theta 5e5.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, mlp_type="swiglu", norm_type="layernorm",
    rope_theta=500000.0, max_seq=33024,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752,
                  capacity_factor=1.25),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256, mlp_type="swiglu", norm_type="layernorm", max_seq=64,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      capacity_factor=4.0),
    )
