"""qwen2-vl-72b [vlm]: 80L, d=8192, 64H (GQA kv=8), ff=29568, vocab=152064.

[arXiv:2409.12191]  M-RoPE backbone (t/h/w rotary sections); the vision
encoder is a stub — input_specs supplies merged patch embeddings for the
leading `vision_prefix` positions plus (3, B, S) M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, mlp_type="swiglu", norm_type="rmsnorm",
    rope_type="mrope", mrope_sections=(16, 24, 24), rope_theta=1000000.0,
    vision_prefix=1024, max_seq=33024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=256, mlp_type="swiglu", norm_type="rmsnorm",
        rope_type="mrope", mrope_sections=(2, 3, 3), vision_prefix=4,
        max_seq=64,
    )
