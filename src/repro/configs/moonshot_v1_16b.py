"""moonshot-v1-16b-a3b [moe]: 48L, d=2048, 16H (kv=16), expert ff=1408,
vocab=163840, MoE 64 experts top-6 (+2 shared), first layer dense.

[hf:moonshotai/Moonlight-16B-A3B]  DeepSeek-V3-style fine-grained MoE;
routing top-k and token grouping run through the paper's sorting kernels.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=11264,
    vocab_size=163840, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=50000.0, max_seq=33024,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, capacity_factor=1.25,
                  first_dense_layers=1),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192,
        vocab_size=256, mlp_type="swiglu", norm_type="rmsnorm", max_seq=64,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, capacity_factor=4.0,
                      first_dense_layers=1),
    )
