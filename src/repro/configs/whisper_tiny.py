"""whisper-tiny [audio]: enc-dec, 4L, d=384, 6H (kv=6), ff=1536, vocab=51865.

[arXiv:2212.04356]  Conv/mel frontend is a stub: the encoder consumes
precomputed frame embeddings (B, 1500, 384) via input_specs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, mlp_type="gelu", norm_type="layernorm",
    rope_type="none", tie_embeddings=True, enc_seq=1500, max_seq=33024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, mlp_type="gelu", norm_type="layernorm",
        rope_type="none", tie_embeddings=True, enc_seq=16, max_seq=64,
    )
