"""Async, sharded, mesh-shape-agnostic checkpointing.

Design (DESIGN.md §4):
  * layout: one .npy per pytree leaf under ``step_XXXXXXXX/``, named by the
    flattened key path, plus ``manifest.json`` (tree structure, dtypes,
    shapes, step, data-pipeline cursor).  Leaves are saved as FULL logical
    arrays — the manifest is therefore independent of the mesh that wrote
    it, which is what makes elastic restart trivial: load on ANY mesh and
    ``jax.device_put`` against the new sharding.  (On a real multi-host pod
    each host would write only its addressable shards with an index file;
    the layout keeps that extension local to ``_gather``.)
  * atomicity: everything is written into ``<dir>.tmp`` and renamed at the
    end — a preempted save can never corrupt the latest checkpoint.
  * async: ``save()`` snapshots to host memory synchronously (cheap) and
    does the disk I/O on a daemon thread; ``wait()`` joins, and train.py
    calls it before the next save or on preemption.
  * retention: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        """Snapshot ``tree`` (pytree of jax/np arrays) at ``step``."""
        self.wait()
        flat = _flatten(tree)
        # synchronous host snapshot (device -> host copy)
        host = {k: np.asarray(v) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
        }

        def _write():
            try:
                final = self.dir / f"step_{step:08d}"
                tmp = self.dir / f"step_{step:08d}.tmp"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for k, v in host.items():
                    if v.dtype.kind == "V":  # ml_dtypes (bf16 etc): raw bits
                        v = v.view(np.uint16 if v.dtype.itemsize == 2
                                   else np.uint8)
                    np.save(tmp / (self._fname(k) + ".npy"), v)
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err}")

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def restore(self, step: int, like_tree, shardings=None
                ) -> Tuple[Any, dict]:
        """Restore into the structure of ``like_tree``; if ``shardings`` (a
        matching pytree of NamedSharding) is given, leaves are placed
        directly with those shardings — this is the elastic-resume path
        (the writing mesh is irrelevant)."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like = _flatten(like_tree)
        flat_sh = _flatten(shardings) if shardings is not None else None
        out = {}
        for k, leaf in flat_like.items():
            arr = np.load(d / (self._fname(k) + ".npy"))
            want_dtype = manifest["leaves"][self._manifest_key(
                k, manifest)]["dtype"]
            if str(arr.dtype) != want_dtype:
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, want_dtype,
                                                want_dtype)))
            want = getattr(leaf, "shape", None)
            if want is not None and tuple(arr.shape) != tuple(want):
                raise ValueError(f"shape mismatch for {k}: "
                                 f"{arr.shape} vs {want}")
            if flat_sh is not None:
                out[k] = jax.device_put(arr, flat_sh[k])
            else:
                out[k] = jax.numpy.asarray(arr)
        # unflatten by re-walking like_tree
        leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(like_tree)[0]]
        restored = [out[p] for p in paths]
        return jax.tree_util.tree_unflatten(treedef, restored), \
            manifest["extra"]

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _fname(key: str) -> str:
        return re.sub(r"[^A-Za-z0-9_.-]", "_", key)[:180]

    @staticmethod
    def _manifest_key(key: str, manifest: dict) -> str:
        return key if key in manifest["leaves"] else key

    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.glob("step_*")
                       if re.fullmatch(r"step_\d+", p.name))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
