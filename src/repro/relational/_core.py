"""Shared machinery for the relational ops: the sorted post-pass
primitives, planner resolution, and obs plumbing.

Every op in this package is (sort via the front door) + (an O(n) scan /
searchsorted post-pass on the sorted column).  The post-passes here are
scatter-free where possible: compaction uses the cumulative-count
searchsorted trick (XLA:CPU serializes scatters; a binary-search gather
vectorizes), mirroring the survivor-compaction idiom in
``kernels/radix_select.py``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.relational.relspec import RelSpec, SORT_OPS, STABLE_OPS


def boundary_mask(s: jnp.ndarray) -> jnp.ndarray:
    """(n,) sorted column -> (n,) bool, True where a new value starts.

    Numeric inequality, not encoded-key inequality: the keycodec orders
    -0.0 strictly below +0.0, but relationally they are ONE value (numpy
    semantics), so the boundary test must compare decoded values.
    """
    n = s.shape[0]
    if n == 0:
        return jnp.zeros((0,), bool)
    return jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]])


def compact_sorted(s: jnp.ndarray, mask: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather the masked (first-of-run) elements of a sorted column to the
    front WITHOUT a scatter -> (compacted, n_valid, segment_ids).

    ``compacted`` is (n,) with the distinct values ascending in the first
    ``n_valid`` slots; the tail repeats the maximum value, so the array
    stays globally non-decreasing (searchsorted-safe — ``inverse`` and the
    distributed post-pass both rely on this).  ``segment_ids[i]`` is the
    0-based run id of sorted position i.
    """
    n = s.shape[0]
    csum = jnp.cumsum(mask.astype(jnp.int32))
    n_valid = csum[-1] if n else jnp.zeros((), jnp.int32)
    # slot j holds the first sorted position whose cumulative run count
    # reaches j+1; past the valid prefix searchsorted answers n -> clipped
    # to the maximum element
    src = jnp.searchsorted(csum, jnp.arange(1, n + 1, dtype=jnp.int32),
                           side="left")
    compacted = s[jnp.clip(src, 0, max(n - 1, 0))]
    return compacted, n_valid, csum - 1


def pad_tail(arr: jnp.ndarray, n_valid: jnp.ndarray, fill) -> jnp.ndarray:
    """Overwrite slots at index >= n_valid with ``fill`` (no-op fill=None)."""
    if fill is None:
        return arr
    idx = jnp.arange(arr.shape[0], dtype=jnp.int32)
    return jnp.where(idx < n_valid, arr, jnp.asarray(fill, arr.dtype))


# ---------------------------------------------------------------------------
# planner resolution + obs
# ---------------------------------------------------------------------------

def resolve_plan(spec: RelSpec, n: int, dtype):
    """-> (method, plan).  Distributed specs return (None, None): the mesh
    sort dispatches through ``planner.choose_distributed`` on its own.
    Explicit methods skip pricing; "auto" goes through the relational cost
    entries (``planner.choose_relational_cached``)."""
    if spec.mesh is not None or spec.op not in SORT_OPS:
        return None, None
    if spec.method != "auto":
        return spec.method, None
    if n == 0:
        return "xla", None
    from repro.engine import planner
    plan = planner.choose_relational_cached(spec.op, n, dtype=dtype)
    return plan.method, plan


def span(spec: RelSpec, n: int):
    """Obs span for one relational op (no-op object when obs is off),
    plus the per-op invocation counter."""
    from repro.obs import trace as _obs
    sp = _obs.trace(f"relational.{spec.op}", n=n,
                    method=spec.method, distributed=spec.mesh is not None)
    if _obs.enabled():
        from repro.obs import metrics as _m
        _m.counter(f"relational.{spec.op}").inc()
    return sp


def finish(sp, spec: RelSpec, plan, n: int) -> None:
    """Pair the fenced span with its relational plan: one
    ``relational_cost_observation`` event + the
    ``relational.cost_model_error`` ratio histogram — the same
    predicted-vs-measured audit the engine keeps for raw sorts
    (``engine._obs_finish``), in a separate histogram so relational
    post-pass noise never perturbs the autotuner's refresh signal."""
    if plan is None or sp.device_ms is None:
        return
    predicted = plan.costs.get(plan.method)
    if not predicted or predicted != predicted or predicted == float("inf"):
        return
    from repro.obs import trace as _obs
    measured_ns = sp.device_ms * 1e6
    _obs.record_event("relational_cost_observation", op=spec.op, n=n,
                      method=plan.method, predicted_ns=predicted,
                      measured_ns=measured_ns,
                      error=measured_ns / predicted)
    from repro.obs import metrics as _m
    _m.histogram("relational.cost_model_error").observe(
        measured_ns / predicted)


def sorted_column(spec: RelSpec, x: jnp.ndarray, method: Optional[str],
                  values: Optional[jnp.ndarray] = None):
    """The op's sort backbone: mesh-global sample-sort when the spec is
    distributed, the planner-picked (or pinned) local backend otherwise.
    Stable-order ops go through the stable argsort pipeline instead —
    see ``stable_order``."""
    import repro.sort as rsort
    if spec.mesh is not None:
        if values is not None:
            return rsort.sort_kv(x, values, mesh=spec.mesh,
                                 axis_name=spec.axis_name,
                                 interpret=spec.interpret)
        return rsort.sort(x, mesh=spec.mesh, axis_name=spec.axis_name,
                          interpret=spec.interpret)
    if values is not None:
        return rsort.sort_kv(x, values, method=method, stable=True,
                             interpret=spec.interpret)
    return rsort.sort(x, method=method, interpret=spec.interpret)


def stable_order(x: jnp.ndarray, method: Optional[str],
                 interpret: Optional[bool]) -> jnp.ndarray:
    """Stable ascending permutation of a 1-D column via the front door
    (non-stable backends fall back to the engine's stable merge pipeline
    — exactly what ``cost_model.relational_cost_ns`` prices them at)."""
    import repro.sort as rsort
    return rsort.argsort(x, stable=True, method=method, interpret=interpret)


__all__ = ["boundary_mask", "compact_sorted", "pad_tail", "resolve_plan",
           "span", "finish", "sorted_column", "stable_order",
           "SORT_OPS", "STABLE_OPS"]
