"""Sorted equi-join: sort both sides, merge-scan with duplicate expansion.

Sort-merge join is the survey's headline sorter application: both key
columns are stably sorted, each left element binary-searches its matching
run on the right (the merge-scan), and the duplicate-pair cross product is
expanded with a rank arithmetic pass — every step a gather, no scatters.

Pair order contract (deterministic, what the numpy reference reproduces):
pairs ascend by key; within a key, left occurrences in input order
(stability of the left sort); within one left occurrence, right
occurrences in input order.

Static-shape contract: the true pair count is data-dependent, so results
come back padded to ``size`` (default ``n_l * n_r`` — always enough) with
``fill_value`` (default -1) in the invalid tail, plus the true ``n_pairs``.
A concrete (eager) count larger than ``size`` raises rather than silently
truncating; under jit the caller owns the check.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.relational import _core
from repro.relational.relspec import RelSpec


class Join(NamedTuple):
    """``(left_idx[:n_pairs], right_idx[:n_pairs])`` enumerate the matching
    pairs by input position; the tail holds ``fill_value``."""
    left_idx: jnp.ndarray
    right_idx: jnp.ndarray
    n_pairs: jnp.ndarray                  # int32 scalar


def run(spec: RelSpec, lk: jnp.ndarray, rk: jnp.ndarray) -> Join:
    nl, nr = lk.shape[0], rk.shape[0]
    size = spec.size if spec.size is not None else max(nl * nr, 1)
    fill = -1 if spec.fill_value is None else spec.fill_value
    if nl == 0 or nr == 0:
        pad = jnp.full((size,), fill, jnp.int32)
        return Join(pad, pad, jnp.zeros((), jnp.int32))
    method, plan = _core.resolve_plan(spec, max(nl, nr), lk.dtype)
    sp = _core.span(spec, nl + nr)
    with sp:
        ol = _core.stable_order(lk, method, spec.interpret)
        sl = lk[ol]
        orr = _core.stable_order(rk, method, spec.interpret)
        sr = rk[orr]
        # merge-scan: each left-sorted element's matching run on the right
        start = jnp.searchsorted(sr, sl, side="left").astype(jnp.int32)
        stop = jnp.searchsorted(sr, sl, side="right").astype(jnp.int32)
        off = jnp.cumsum(stop - start)              # inclusive pair offsets
        n_pairs = off[-1].astype(jnp.int32)
        # duplicate-pair expansion: pair t belongs to the left-sorted
        # element li with off[li-1] <= t < off[li]; its right partner is
        # the (t - off[li-1])-th element of li's run
        t = jnp.arange(size, dtype=jnp.int32)
        li = jnp.searchsorted(off, t, side="right").astype(jnp.int32)
        li = jnp.clip(li, 0, nl - 1)
        prev = jnp.where(li > 0, off[jnp.maximum(li - 1, 0)], 0)
        ri = jnp.clip(start[li] + (t - prev), 0, nr - 1)
        valid = t < n_pairs
        out = Join(
            left_idx=jnp.where(valid, ol[li], fill).astype(jnp.int32),
            right_idx=jnp.where(valid, orr[ri], fill).astype(jnp.int32),
            n_pairs=n_pairs)
        sp.fence(out.left_idx)
    _core.finish(sp, spec, plan, nl + nr)
    try:                                  # eager calls get the honest error;
        concrete = int(out.n_pairs)       # traced counts stay the caller's
    except Exception:                     # responsibility (documented)
        concrete = None
    if concrete is not None and concrete > size:
        raise ValueError(
            f"join produced {concrete} pairs but size={size}; pass "
            f"size >= {concrete} (the padded output would truncate)")
    return out
