"""repro.relational — sort-powered relational kernels.

The hardware-sorting survey (Jalilvand et al., PAPERS.md) treats group-by,
join, dedup, and min/max search as first-class applications of a hardware
sorter; Mutlu et al. argue the win is keeping these data-movement-bound
operators next to the data.  This package is that workload class on top of
the repo's sort engine: every op is a sort (or radix selection) plus an
O(n) scan/searchsorted post-pass, described by one frozen
:class:`~repro.relational.relspec.RelSpec` and executed by ``run``:

    import repro.relational as rel

    rel.unique(x, return_counts=True)        # dedup (np.unique semantics)
    rel.group_by(keys, vals, agg=("sum", "mean"))
    rel.join(left_keys, right_keys, size=64) # sorted equi-join
    rel.run_length_encode(x)                 # sorted-column RLE
    rel.delta_encode(ids)                    # sorted-column deltas (ints)
    rel.histogram(x, num_bins=32)
    rel.quantiles(x, (0.5, 0.99))            # radix-select order statistics
    rel.group_ranks(expert_ids, num_groups=E)  # MoE dispatch primitive

    rel.unique(x, mesh=mesh, axis_name="data")   # distributed dedup
    rel.group_by(k, v, agg="sum", mesh=mesh)     # distributed group-by

Validation happens once in ``RelSpec.canonical``; ``method="auto"``
resolves through ``planner.choose_relational`` with the relational cost
entries (``cost_model.relational_cost_ns``), so the sorting backend under
each op is planner-picked per workload.  Distributed variants exist where
the op composes over the mesh (dedup, group-by): the sample-sort splitter
round co-locates equal keys, so the local post-pass is the global answer.

Static-shape contract: data-dependent result sizes (unique values, groups,
join pairs, runs) come back as fixed-size padded arrays + a valid count
(``jnp.unique(size=...)`` discipline) — see each result NamedTuple.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax.numpy as jnp

from repro.relational.relspec import AGGS, OPS, RelSpec  # noqa: F401
# module handles bound BEFORE the wrapper defs below shadow the submodule
# names on the package (rel.unique the function vs relational/unique.py)
from repro.relational import encode as _encode_mod
from repro.relational import groupby as _groupby_mod
from repro.relational import join as _join_mod
from repro.relational import sketch as _sketch_mod
from repro.relational import unique as _unique_mod
from repro.relational.encode import (  # noqa: F401
    Delta, RunLength, delta_decode, rle_decode)
from repro.relational.groupby import GroupBy, GroupRanks  # noqa: F401
from repro.relational.join import Join  # noqa: F401
from repro.relational.sketch import (  # noqa: F401
    HistogramSketch, QuantileSketch)
from repro.relational.unique import Unique  # noqa: F401

__all__ = [
    "RelSpec", "OPS", "AGGS", "run",
    "unique", "group_by", "join", "run_length_encode", "rle_decode",
    "delta_encode", "delta_decode", "histogram", "quantiles",
    "group_ranks",
    "Unique", "GroupBy", "GroupRanks", "Join", "RunLength", "Delta",
    "HistogramSketch", "QuantileSketch",
]

_Arr = jnp.ndarray


def run(spec: RelSpec, x: _Arr, values: Optional[_Arr] = None):
    """Execute ``spec``.  ``x`` is the (key) column; ``values`` is the
    payload column (group_by) or the right key column (join)."""
    x = jnp.asarray(x)
    values = None if values is None else jnp.asarray(values)
    spec = spec.canonical(x, values)
    if spec.op == "unique":
        return _unique_mod.run(spec, x)
    if spec.op == "group_by":
        return _groupby_mod.run(spec, x, values)
    if spec.op == "join":
        return _join_mod.run(spec, x, values)
    if spec.op == "rle":
        return _encode_mod.run_rle(spec, x)
    if spec.op == "delta":
        return _encode_mod.run_delta(spec, x)
    if spec.op == "histogram":
        return _sketch_mod.run_histogram(spec, x)
    if spec.op == "quantile":
        return _sketch_mod.run_quantile(spec, x)
    return _groupby_mod.run_group_ranks(spec, x)


# ---------------------------------------------------------------------------
# ergonomic wrappers — each builds a spec and runs it
# ---------------------------------------------------------------------------

def unique(x: _Arr, *, return_inverse: bool = False,
           return_counts: bool = False, fill_value=None,
           method: Optional[str] = None, mesh=None,
           axis_name: Optional[str] = None,
           interpret: Optional[bool] = None) -> Unique:
    """Distinct values of a column, ascending (np.unique semantics) —
    sort, adjacent-diff mask, searchsorted compaction.  With ``mesh`` the
    sort goes mesh-global (sample-sort) and the same post-pass applies."""
    return run(RelSpec(op="unique", return_inverse=return_inverse,
                       return_counts=return_counts, fill_value=fill_value,
                       method=method, mesh=mesh, axis_name=axis_name,
                       interpret=interpret), x)


def group_by(keys: _Arr, values: _Arr, *,
             agg: Union[str, Tuple[str, ...]] = "sum", fill_value=None,
             method: Optional[str] = None, mesh=None,
             axis_name: Optional[str] = None,
             interpret: Optional[bool] = None) -> GroupBy:
    """Aggregate ``values`` per distinct key: segmented sort -> boundary
    flags -> segment reductions.  ``agg`` is one of (or a tuple from)
    ``AGGS``; results follow its order in ``.aggregates``."""
    return run(RelSpec(op="group_by", agg=agg, fill_value=fill_value,
                       method=method, mesh=mesh, axis_name=axis_name,
                       interpret=interpret), keys, values)


def join(left_keys: _Arr, right_keys: _Arr, *, size: Optional[int] = None,
         fill_value=None, method: Optional[str] = None,
         interpret: Optional[bool] = None) -> Join:
    """Sorted equi-join -> matching (left, right) index pairs, padded to
    the static ``size`` (default ``n_l * n_r``; pass a real bound for
    production shapes).  Payload columns follow by gathering through the
    returned indices."""
    return run(RelSpec(op="join", size=size, fill_value=fill_value,
                       method=method, interpret=interpret),
               left_keys, right_keys)


def run_length_encode(x: _Arr, *, assume_sorted: bool = False,
                      fill_value=None, method: Optional[str] = None,
                      interpret: Optional[bool] = None) -> RunLength:
    """Run-length encode the sorted column (sorts first unless
    ``assume_sorted``); ``rle_decode`` rebuilds it exactly."""
    return run(RelSpec(op="rle", assume_sorted=assume_sorted,
                       fill_value=fill_value, method=method,
                       interpret=interpret), x)


def delta_encode(x: _Arr, *, assume_sorted: bool = False,
                 method: Optional[str] = None,
                 interpret: Optional[bool] = None) -> Delta:
    """Delta encode the sorted integer column (modular, bit-exact
    round-trip via ``delta_decode``)."""
    return run(RelSpec(op="delta", assume_sorted=assume_sorted,
                       method=method, interpret=interpret), x)


def histogram(x: _Arr, num_bins: int, *, lo=None, hi=None,
              interpret: Optional[bool] = None) -> HistogramSketch:
    """Equi-width histogram over [lo, hi] (defaults to the column's
    range): searchsorted over explicit float32 edges, rightmost bin
    closed (np.histogram convention)."""
    return run(RelSpec(op="histogram", num_bins=num_bins, lo=lo, hi=hi,
                       interpret=interpret), x)


def quantiles(x: _Arr, qs, *,
              interpret: Optional[bool] = None) -> QuantileSketch:
    """Lower order statistics at fractions ``qs`` via one bottom-k radix
    selection — no sort; every answer is an element of the column."""
    return run(RelSpec(op="quantile", qs=qs if isinstance(qs, tuple)
                       else tuple(qs) if not isinstance(qs, float)
                       else (qs,), interpret=interpret), x)


def group_ranks(keys: _Arr, num_groups: int, *, constrain=None,
                method: Optional[str] = None,
                interpret: Optional[bool] = None) -> GroupRanks:
    """Each element's 0-based arrival rank within its key group plus
    per-group counts — the counting-sort dispatch primitive MoE routing
    runs per batch row.  ``constrain`` (optional callable) annotates the
    one-hot's sharding on the small-domain path."""
    keys = jnp.asarray(keys)
    spec = RelSpec(op="group_ranks", num_groups=num_groups, method=method,
                   interpret=interpret).canonical(keys)
    return _groupby_mod.run_group_ranks(spec, keys, constrain=constrain)
