"""Histogram & quantile sketches riding the radix-select backend.

Quantiles are order statistics, and the O(n·passes) MSD radix selection
(PR 5, ``kernels/radix_select.py``) computes them without a sort: encode
the column ascending (keycodec), bottom-k select with k = the largest
needed order statistic, and read every requested quantile out of the
ascending survivor prefix.  ``q``'s order statistic is
``floor(q * (n - 1))`` — numpy's ``method="lower"``, so every answer is an
element of the column (exact for every supported dtype, no interpolation).

Histograms use the searchsorted formulation over explicit float32 bin
edges (bin of x = the edge interval containing it, rightmost bin closed —
``np.histogram``'s convention).  The edges are part of the result, so the
reference semantics are reproducible bit-for-bit: the numpy check
searchsorteds the same edges.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import keycodec
from repro.relational import _core
from repro.relational.relspec import RelSpec


class HistogramSketch(NamedTuple):
    """``counts[b]`` = #elements in ``[edges[b], edges[b+1])`` (last bin
    closed on the right); ``edges`` is (num_bins + 1,) float32."""
    counts: jnp.ndarray
    edges: jnp.ndarray


class QuantileSketch(NamedTuple):
    """``values[i]`` is the ``qs[i]`` quantile (an element of the column,
    lower order statistic)."""
    values: jnp.ndarray


def run_histogram(spec: RelSpec, x: jnp.ndarray) -> HistogramSketch:
    bins = spec.num_bins
    n = x.shape[0]
    sp = _core.span(spec, n)
    with sp:
        xf = x.astype(jnp.float32)
        lo = jnp.asarray(spec.lo, jnp.float32) if spec.lo is not None \
            else (jnp.min(xf) if n else jnp.zeros((), jnp.float32))
        hi = jnp.asarray(spec.hi, jnp.float32) if spec.hi is not None \
            else (jnp.max(xf) if n else jnp.ones((), jnp.float32))
        hi = jnp.where(hi > lo, hi, lo + 1.0)     # degenerate range guard
        edges = lo + (hi - lo) * (
            jnp.arange(bins + 1, dtype=jnp.float32) / bins)
        if n == 0:
            out = HistogramSketch(counts=jnp.zeros((bins,), jnp.int32),
                                  edges=edges)
        else:
            idx = jnp.clip(
                jnp.searchsorted(edges, xf, side="right") - 1, 0, bins - 1)
            inside = (xf >= lo) & (xf <= edges[-1])
            counts = jnp.zeros((bins,), jnp.int32).at[idx].add(
                inside.astype(jnp.int32))
            out = HistogramSketch(counts=counts, edges=edges)
        sp.fence(out.counts)
    _core.finish(sp, spec, None, n)
    return out


def run_quantile(spec: RelSpec, x: jnp.ndarray) -> QuantileSketch:
    n = x.shape[0]
    # lower order statistic per fraction; k = largest one we must reach
    ords = tuple(int(q * (n - 1)) for q in spec.qs)
    k = max(ords) + 1
    sp = _core.span(spec, n)
    with sp:
        enc = keycodec.encode(x, descending=False)
        kth, _ = _select(enc[None, :], k, spec.interpret)
        # ascending survivor prefix: position j IS the j-th order statistic
        vals = keycodec.decode(
            kth[0, jnp.asarray(ords, jnp.int32)], x.dtype)
        out = QuantileSketch(values=vals)
        sp.fence(out.values)
    _core.finish(sp, spec, None, n)
    return out


def _select(enc: jnp.ndarray, k: int, interpret):
    from repro.kernels import radix_select
    return radix_select.select_topk_encoded(enc, k, interpret=interpret)
