"""Group-by aggregate: segmented sort -> boundary flags -> segment_sum.

The survey's canonical sorter application: stable kv-sort co-locates each
key's values, boundary flags turn runs into segment ids, and
``jax.ops.segment_{sum,min,max}`` does the reductions in one pass.  The
distributed variant rides the sample-sort — after the splitter round equal
keys share a device, so the identical local post-pass IS the global
group-by.

Also home to ``group_ranks`` (the MoE dispatch primitive): each element's
arrival rank within its key group plus per-group counts — a counting sort
over a small key domain, the bit-width-aware strengthening of the paper's
4-bit sort that ``models/moe.py`` runs per batch row.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.relational import _core
from repro.relational.relspec import RelSpec

# one-hot counting stays cheaper than a sort pipeline while the O(n*G)
# one-hot tensor is small; past this domain the flat path sorts instead
ONE_HOT_MAX_GROUPS = 512


class GroupBy(NamedTuple):
    """``keys[:n_groups]`` are the distinct keys ascending; ``aggregates``
    holds one (n,)-shaped column per requested reduction (same order as
    ``agg``), each valid to ``n_groups`` and padded with ``fill_value``
    (default 0) past it."""
    keys: jnp.ndarray
    n_groups: jnp.ndarray                 # int32 scalar
    aggregates: Tuple[jnp.ndarray, ...]


class GroupRanks(NamedTuple):
    """``ranks`` is each element's 0-based arrival order within its key
    group (shape of the input); ``counts`` is (..., num_groups) group
    sizes."""
    ranks: jnp.ndarray
    counts: jnp.ndarray


def _aggregate(sv: jnp.ndarray, seg: jnp.ndarray, n: int, aggs,
               n_groups: jnp.ndarray, fill) -> Tuple[jnp.ndarray, ...]:
    """Segment reductions over the sorted values, one column per agg."""
    cnt = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), seg,
                              num_segments=n)
    fill = 0 if fill is None else fill
    outs = []
    for a in aggs:
        if a == "sum":
            r = jax.ops.segment_sum(sv, seg, num_segments=n)
        elif a == "min":
            r = jax.ops.segment_min(sv, seg, num_segments=n)
        elif a == "max":
            r = jax.ops.segment_max(sv, seg, num_segments=n)
        elif a == "count":
            r = cnt
        else:  # mean — float32 division of the exact segment sum, the
            # documented reference semantics (README "Relational kernels")
            s = jax.ops.segment_sum(sv.astype(jnp.float32), seg,
                                    num_segments=n)
            r = s / jnp.maximum(cnt, 1).astype(jnp.float32)
        outs.append(_core.pad_tail(r, n_groups, fill))
    return tuple(outs)


def run(spec: RelSpec, keys: jnp.ndarray, values: jnp.ndarray) -> GroupBy:
    n = keys.shape[0]
    if n == 0:
        empty = tuple(
            jnp.zeros((0,), jnp.int32 if a == "count"
                      else jnp.float32 if a == "mean" else values.dtype)
            for a in spec.agg)
        return GroupBy(keys=keys, n_groups=jnp.zeros((), jnp.int32),
                       aggregates=empty)
    method, plan = _core.resolve_plan(spec, n, keys.dtype)
    sp = _core.span(spec, n)
    with sp:
        # the mesh path's kv sample-sort is not stable, which is fine:
        # every supported reduction is order-free given exact arithmetic
        # (the stable local pipeline just fixes the summation order)
        sk, sv = _core.sorted_column(spec, keys, method, values=values)
        mask = _core.boundary_mask(sk)
        ukeys, n_groups, seg = _core.compact_sorted(sk, mask)
        aggs = _aggregate(sv, seg, n, spec.agg, n_groups, spec.fill_value)
        out = GroupBy(keys=_core.pad_tail(ukeys, n_groups, spec.fill_value),
                      n_groups=n_groups, aggregates=aggs)
        sp.fence(out.keys)
    _core.finish(sp, spec, plan, n)
    return out


def run_group_ranks(spec: RelSpec, keys: jnp.ndarray,
                    constrain: Optional[Callable] = None) -> GroupRanks:
    """Arrival rank within each key group.  Small domains (and any batched
    input) use the one-hot counting sort — O(n * num_groups) exclusive
    cumsum, fully vectorized and shardable (``constrain`` lets the caller
    annotate the one-hot's sharding, e.g. MoE's dp axes).  Large flat
    domains ride the stable sort: rank = sorted position - group start.
    """
    g = spec.num_groups
    n = keys.shape[-1]
    sp = _core.span(spec, int(keys.size))
    with sp:
        if keys.ndim > 1 or g <= ONE_HOT_MAX_GROUPS or n == 0:
            onehot = jax.nn.one_hot(keys, g, dtype=jnp.int32)
            if constrain is not None:
                onehot = constrain(onehot)
            ranks = jnp.sum((jnp.cumsum(onehot, axis=-2) - onehot) * onehot,
                            axis=-1)
            counts = jnp.sum(onehot, axis=-2)
        else:
            order = _core.stable_order(keys, spec.method, spec.interpret)
            sk = keys[order]
            seg = jnp.cumsum(_core.boundary_mask(sk).astype(jnp.int32)) - 1
            # group start in sorted coords = first position of each run;
            # rank = sorted position - start, scattered back to input order
            starts = jnp.full((n,), n, jnp.int32).at[seg].min(
                jnp.arange(n, dtype=jnp.int32))
            sorted_rank = jnp.arange(n, dtype=jnp.int32) - starts[seg]
            ranks = jnp.zeros((n,), jnp.int32).at[order].set(sorted_rank)
            counts = jnp.zeros((g,), jnp.int32).at[
                jnp.clip(keys, 0, g - 1)].add(1)
        sp.fence(ranks)
    _core.finish(sp, spec, None, n)
    return GroupRanks(ranks=ranks, counts=counts)
