"""RelSpec — the one front-door contract for every relational op.

Mirror of :class:`repro.core.sortspec.SortSpec` one workload class up: a
relational problem (dedup, group-by, join, run-length/delta encoding,
histogram/quantile sketch) is a single frozen :class:`RelSpec` value, and
``canonical()`` is the ONE place every front-door error is raised — op
combinations, dtype support, aggregate names, mesh constraints — never deep
inside an op kernel.

The hardware-sorting survey (Jalilvand et al., PAPERS.md) treats these ops
as first-class applications of a sorter; the spec layer keeps that framing
honest: every op here is a sort (or a radix selection) plus an O(n)
post-pass, and ``method`` names the *sorting backend* the op rides —
``"auto"`` resolves through ``planner.choose_relational`` with the new
``cost_model.relational_cost_ns`` entries.

Static-shape contract (the jax constraint every op shares): results whose
true size is data-dependent (unique values, groups, join pairs, runs) come
back as fixed-size padded arrays plus a valid count, exactly like
``jnp.unique(size=..., fill_value=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax.numpy as jnp

__all__ = ["RelSpec", "OPS", "AGGS", "SORT_OPS", "STABLE_OPS", "SKETCH_OPS"]

# every relational op the subsystem executes
OPS = ("unique", "group_by", "join", "rle", "delta", "histogram",
       "quantile", "group_ranks")

# ops whose backbone is a full sort (planner-priced backend choice);
# sketches ride the radix-select / searchsorted machinery instead and
# accept no per-op backend override
SORT_OPS = frozenset({"unique", "group_by", "join", "rle", "delta"})
SKETCH_OPS = frozenset({"histogram", "quantile"})

# ops that need a *stable* order pipeline (duplicate-pair order for join,
# deterministic within-group aggregation order and arrival ranks): the
# planner prices non-stable backends at the forced-stable merge fallback
# the engine would actually run
STABLE_OPS = frozenset({"group_by", "join", "group_ranks"})

# group-by reductions (mean is sum/count in float32 — the documented
# reference semantics, see README "Relational kernels")
AGGS = ("sum", "min", "max", "count", "mean")

# ops that compose over a device mesh: after the sample-sort splitter
# round equal keys are co-located, so the local post-pass IS the global op
MESH_OPS = frozenset({"unique", "group_by"})


@dataclasses.dataclass(frozen=True, eq=False)
class RelSpec:
    """One relational problem.  Field groups:

      op                      which relational kernel
      agg                     group_by reductions (name or tuple of names)
      return_inverse/counts   unique extras (np.unique-style)
      size                    join output capacity (static; default n_l*n_r)
      fill_value              what pads invalid tail slots (op-specific
                              default when None — see each op's docstring)
      assume_sorted           rle/delta: input is already sorted, skip the
                              sort (the ops encode *sorted columns*)
      num_bins / lo / hi      histogram shape
      qs                      quantile fractions in [0, 1]
      num_groups              group_ranks key domain (0 <= key < num_groups)
      mesh / axis_name        distributed variant (unique/group_by only)
      method / interpret      sorting-backend knobs (None -> "auto")

    ``eq=False`` keeps the spec hashable by identity (mesh objects ride
    along); planner caching keys on the statics it derives from the spec.
    """
    op: str = "unique"
    agg: Union[str, Tuple[str, ...]] = ("sum",)
    return_inverse: bool = False
    return_counts: bool = False
    size: Optional[int] = None
    fill_value: Any = None
    assume_sorted: bool = False
    num_bins: Optional[int] = None
    lo: Any = None
    hi: Any = None
    qs: Optional[Tuple[float, ...]] = None
    num_groups: Optional[int] = None
    mesh: Any = None
    axis_name: Optional[str] = None
    method: Optional[str] = None
    interpret: Optional[bool] = None

    # -- validation + canonicalization (the one place it happens) -----------
    def canonical(self, x: jnp.ndarray,
                  values: Optional[jnp.ndarray] = None) -> "RelSpec":
        from repro.core import keycodec
        from repro.core.sortspec import backend_names
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        op = self.op

        # ---- shape: every column op is 1-D; group_ranks allows batch dims
        if op == "group_ranks":
            if x.ndim < 1:
                raise ValueError("group_ranks expects (..., n) keys")
            if self.num_groups is None or int(self.num_groups) < 1:
                raise ValueError(
                    f"group_ranks needs num_groups >= 1, "
                    f"got {self.num_groups}")
            if not jnp.issubdtype(x.dtype, jnp.integer):
                raise ValueError(
                    f"group_ranks keys must be integers, got {x.dtype}")
        elif x.ndim != 1:
            raise ValueError(
                f"relational op {op!r} works on flat 1-D columns; "
                f"got a {x.ndim}-d input")

        # ---- method: a registered sorting backend or auto; sketches ride
        # the selection / searchsorted machinery and take no override
        method = self.method if self.method is not None else "auto"
        if op in SKETCH_OPS:
            if method != "auto":
                raise ValueError(
                    f"{op} rides the radix-select backend; method must be "
                    f"'auto', got {method!r}")
        else:
            names = backend_names() + ("auto",)
            if method not in names:
                raise ValueError(
                    f"method must be one of {names}, got {method!r}")

        # ---- mesh: only the ops where local op == global op compose
        axis_name = self.axis_name
        if axis_name is not None and self.mesh is None:
            raise ValueError("axis_name requires a mesh")
        if self.mesh is not None:
            if op not in MESH_OPS:
                raise ValueError(
                    f"distributed relational variants exist for "
                    f"{tuple(sorted(MESH_OPS))}; op {op!r} has none")
            # one axis, a tuple of axes (hierarchical meshes), or None ->
            # the whole mesh — normalised by the shared helper so the
            # relational mesh ops accept exactly what distributed_sort does
            from repro.engine.samplesort import _axes_tuple
            axis_name = _axes_tuple(self.mesh, axis_name)
            if method not in ("auto", "distributed"):
                raise ValueError(
                    "mesh-distributed relational ops run the 'distributed' "
                    f"sort; method must be 'auto' or 'distributed', "
                    f"got {method!r}")
            if not keycodec.supports(x.dtype):
                raise ValueError(
                    f"distributed {op} needs a keycodec dtype "
                    f"({keycodec.SUPPORTED}), got {x.dtype}")

        # ---- per-op field combos
        if (self.return_inverse or self.return_counts) and op != "unique":
            raise ValueError(
                "return_inverse/return_counts are unique-only fields")
        if self.size is not None:
            if op != "join":
                raise ValueError("size is a join-only field (static output "
                                 "capacity for the expanded pairs)")
            if int(self.size) < 1:
                raise ValueError(f"join size must be >= 1, got {self.size}")
        if self.assume_sorted and op not in ("rle", "delta"):
            raise ValueError("assume_sorted applies to the sorted-column "
                             "encoders (rle/delta) only")
        if op == "delta" and not jnp.issubdtype(x.dtype, jnp.integer):
            raise ValueError(
                f"delta encoding round-trips exactly for integer columns "
                f"only (modular cumsum); got {x.dtype}")
        if op == "group_by":
            agg = (self.agg,) if isinstance(self.agg, str) else \
                tuple(self.agg)
            if not agg:
                raise ValueError("group_by needs at least one aggregate")
            bad = [a for a in agg if a not in AGGS]
            if bad:
                raise ValueError(
                    f"unknown aggregates {bad}; supported: {AGGS}")
            if values is None:
                raise ValueError("group_by needs a values column")
            if values.shape != x.shape:
                raise ValueError(
                    f"group_by values shape {values.shape} must match "
                    f"keys shape {x.shape}")
        else:
            agg = self.agg if isinstance(self.agg, tuple) else (self.agg,)
        if op == "join":
            if values is None:
                raise ValueError("join needs a right key column")
            if values.ndim != 1:
                raise ValueError(
                    f"join keys are flat 1-D columns; right side is "
                    f"{values.ndim}-d")
            if values.dtype != x.dtype:
                raise ValueError(
                    f"join key dtypes must match: left {x.dtype}, "
                    f"right {values.dtype}")
        if op == "histogram":
            if self.num_bins is None or int(self.num_bins) < 1:
                raise ValueError(
                    f"histogram needs num_bins >= 1, got {self.num_bins}")
        elif self.num_bins is not None:
            raise ValueError("num_bins is a histogram-only field")
        qs = self.qs
        if op == "quantile":
            if qs is None:
                raise ValueError("quantile needs qs (fractions in [0, 1])")
            qs = (qs,) if isinstance(qs, float) else tuple(float(q)
                                                           for q in qs)
            if not qs or any(not 0.0 <= q <= 1.0 for q in qs):
                raise ValueError(
                    f"quantile fractions must lie in [0, 1], got {qs}")
            if not keycodec.supports(x.dtype):
                raise ValueError(
                    f"quantile sketches ride the radix-select backend and "
                    f"need a keycodec dtype ({keycodec.SUPPORTED}), "
                    f"got {x.dtype}")
            if x.shape[0] == 0:
                raise ValueError("quantiles of an empty column are "
                                 "undefined")
        elif qs is not None:
            raise ValueError("qs is a quantile-only field")

        return dataclasses.replace(
            self, op=op, agg=agg, method=method, axis_name=axis_name,
            qs=qs, size=None if self.size is None else int(self.size),
            num_bins=None if self.num_bins is None else int(self.num_bins),
            num_groups=None if self.num_groups is None
            else int(self.num_groups))

    def static_key(self, shape, dtype) -> tuple:
        """Hashable reduction to the statics an external cache may key on
        (mirrors ``SortSpec.static_key``)."""
        mesh_key = None if self.mesh is None else (
            tuple(zip(self.mesh.axis_names, self.mesh.devices.shape)),
            tuple(d.id for d in self.mesh.devices.flat))
        return (self.op, self.agg, self.return_inverse, self.return_counts,
                self.size, self.fill_value, self.assume_sorted,
                self.num_bins, self.lo, self.hi, self.qs, self.num_groups,
                mesh_key, self.axis_name, self.method, self.interpret,
                tuple(shape), jnp.dtype(dtype).name)
