"""Dedup / unique: sort -> adjacent-diff mask -> searchsorted compaction.

``np.unique`` semantics under the static-shape contract: the distinct
values come back ascending in a fixed (n,)-shaped array with a valid
count, plus optional inverse indices and per-value counts — the
``jnp.unique(size=n)`` shape discipline without its scatter-heavy
lowering.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp

from repro.relational import _core
from repro.relational.relspec import RelSpec


class Unique(NamedTuple):
    """``values[:n_unique]`` is ``np.unique(x)``; the tail holds
    ``fill_value`` (or repeats the maximum when fill_value is None, which
    keeps ``values`` globally non-decreasing — searchsorted-safe).
    ``inverse`` (optional) maps each input position to its slot in
    ``values``; ``counts`` (optional) is the multiplicity per slot."""
    values: jnp.ndarray
    n_unique: jnp.ndarray                 # int32 scalar
    inverse: Optional[jnp.ndarray] = None
    counts: Optional[jnp.ndarray] = None


def run(spec: RelSpec, x: jnp.ndarray) -> Unique:
    n = x.shape[0]
    if n == 0:
        return Unique(values=x,
                      n_unique=jnp.zeros((), jnp.int32),
                      inverse=jnp.zeros((0,), jnp.int32)
                      if spec.return_inverse else None,
                      counts=jnp.zeros((0,), jnp.int32)
                      if spec.return_counts else None)
    method, plan = _core.resolve_plan(spec, n, x.dtype)
    sp = _core.span(spec, n)
    with sp:
        s = _core.sorted_column(spec, x, method)
        mask = _core.boundary_mask(s)
        uvals, n_unique, _ = _core.compact_sorted(s, mask)
        inverse = counts = None
        if spec.return_inverse or spec.return_counts:
            # uvals is non-decreasing (tail repeats the max), and every
            # input value occurs in its valid prefix, so one binary
            # search recovers each element's slot — works unchanged on
            # the distributed path (no argsort needed over the mesh)
            inverse = jnp.searchsorted(uvals, x, side="left"
                                       ).astype(jnp.int32)
        if spec.return_counts:
            counts = jnp.zeros((n,), jnp.int32).at[inverse].add(1)
        out = Unique(values=_core.pad_tail(uvals, n_unique, spec.fill_value),
                     n_unique=n_unique,
                     inverse=inverse if spec.return_inverse else None,
                     counts=counts)
        sp.fence(out.values)
    _core.finish(sp, spec, plan, n)
    return out
