"""Run-length & delta encoding of sorted columns.

Both encoders sort their input first (through the planner-picked backend)
unless ``assume_sorted=True`` — they compress *sorted columns*, the form
in which dup-heavy data is maximally compressible (a sorted Zipfian token
column run-length-encodes to its vocabulary; a sorted id column
delta-encodes to small gaps).

Exactness contracts: RLE round-trips any dtype (decode rebuilds the sorted
column); delta encoding is integer-only — modular subtraction/cumsum in
the column's own dtype round-trips bit-exactly even through wraparound,
which float cancellation cannot promise (rejected at the spec layer).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.relational import _core
from repro.relational.relspec import RelSpec


class RunLength(NamedTuple):
    """``values[:n_runs]`` / ``run_lengths[:n_runs]`` describe the runs in
    order; tails hold ``fill_value`` (default: values repeat the max, run
    lengths 0)."""
    values: jnp.ndarray
    run_lengths: jnp.ndarray
    n_runs: jnp.ndarray                   # int32 scalar


class Delta(NamedTuple):
    """``deltas[0]`` is the first (smallest) element; ``deltas[i]`` the
    modular difference from its predecessor in the sorted column."""
    deltas: jnp.ndarray


def run_rle(spec: RelSpec, x: jnp.ndarray) -> RunLength:
    n = x.shape[0]
    if n == 0:
        z = jnp.zeros((0,), jnp.int32)
        return RunLength(values=x, run_lengths=z,
                         n_runs=jnp.zeros((), jnp.int32))
    method, plan = _core.resolve_plan(spec, n, x.dtype)
    sp = _core.span(spec, n)
    with sp:
        s = x if spec.assume_sorted \
            else _core.sorted_column(spec, x, method)
        mask = _core.boundary_mask(s)
        vals, n_runs, seg = _core.compact_sorted(s, mask)
        lengths = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), seg,
                                      num_segments=n)
        out = RunLength(
            values=_core.pad_tail(vals, n_runs, spec.fill_value),
            run_lengths=_core.pad_tail(lengths, n_runs, 0),
            n_runs=n_runs)
        sp.fence(out.values)
    _core.finish(sp, spec, plan, n)
    return out


def rle_decode(values: jnp.ndarray, run_lengths: jnp.ndarray,
               n: int) -> jnp.ndarray:
    """Rebuild the (sorted) column from its runs; ``n`` is the static
    output length (= the encoded column's length)."""
    ends = jnp.cumsum(run_lengths.astype(jnp.int32))
    idx = jnp.searchsorted(ends, jnp.arange(n, dtype=jnp.int32),
                           side="right")
    return values[jnp.clip(idx, 0, max(values.shape[0] - 1, 0))]


def run_delta(spec: RelSpec, x: jnp.ndarray) -> Delta:
    n = x.shape[0]
    if n == 0:
        return Delta(deltas=x)
    method, plan = _core.resolve_plan(spec, n, x.dtype)
    sp = _core.span(spec, n)
    with sp:
        s = x if spec.assume_sorted \
            else _core.sorted_column(spec, x, method)
        d = jnp.concatenate([s[:1], s[1:] - s[:-1]])
        sp.fence(d)
    _core.finish(sp, spec, plan, n)
    return Delta(deltas=d)


def delta_decode(deltas: jnp.ndarray) -> jnp.ndarray:
    """Modular prefix sum in the column's own dtype — the exact inverse of
    ``run_delta`` (sorted-column reconstruction)."""
    return jnp.cumsum(deltas, dtype=deltas.dtype)
