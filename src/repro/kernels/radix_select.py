"""MSD radix select — O(n·b/DIGIT_BITS) top-k without sorting.

The paper's architecture wins by *partial* data movement: §II-B partitions
sort concurrently and only the candidates that can still matter cross a
partition boundary.  For ``k ≪ n`` the same argument says a full
O(n log n) sort is the wrong tool entirely — the hardware-sorting
literature (MemSort's max-search mode; the "Sorting it out in Hardware"
survey's partial-sort taxonomy) treats min/max-search and partial sort as
first-class operating modes, and this module is their VMEM analogue:

  1. **digit refinement** (most-significant digit first): each pass
     histograms one ``DIGIT_BITS``-wide digit of the still-active
     elements (those matching the threshold prefix fixed by earlier
     passes) and walks the cumulative counts to pin the next digit of
     the k-th key.  ``ceil(b/DIGIT_BITS)`` passes of O(n) counting work
     — no element ever moves.
  2. **exact-k mask**: with the threshold key T and the residual tie
     budget r = k - #{enc < T}, the survivors are every element below T
     plus the *first r* (ascending index) elements equal to T.  Exactly
     k survive — the tie rule that makes the selection reproducible and
     lets every consumer budget on k (grad compression wire format,
     MoE capacity, sampling batch shapes).
  3. **compact + order**: survivors scatter to k slots in index order,
     then one tiny two-key ``lax.sort`` over (encoded key, index) puts
     the k candidates in output order — O(k log k) on k elements, dwarfed
     by the counting passes.

Keys go through ``core/keycodec.py`` with ``descending=True`` so "top-k
largest" is "k smallest encoded": ties therefore keep ascending index
order, matching ``jax.lax.top_k``'s lower-index-first rule bit-exactly.

The refinement has two interchangeable engines, mirroring
``engine/samplesort.bucket_bounds``:

  * ``use_kernel=True`` (TPU default) — DIGIT_BITS-wide passes on a
    per-tile one-hot histogram Pallas kernel in the style of
    ``radix_sort._digit_stats``: the grid partitions tiles exactly like
    the paper partitions its SRAM macro, inactive/pad slots carry an
    extra digit counted into a throwaway column.
  * ``use_kernel=False`` (host default) — radix-2 refinement, the
    faithful analogue of the paper's bit-serial CAS walk: one masked
    zero-count per key bit, pure branchless compare+reduce jnp with no
    scatter anywhere (XLA CPU scatters serialise, and an interpreted
    Pallas kernel pays the ~300x penalty the planner prices into the
    radix *sort* — selection dodges both).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import keycodec
# kernel shape parameters (digit width, histogram tile) come from the
# tuning layer's active profile — the same object the cost model prices
# with (cost_model.selection_cost_ns), so pricing, the LSD sort kernels,
# and this module can't drift apart
from repro.core import tuning as _tuning

__all__ = ["select_topk", "select_topk_kv", "select_topk_encoded",
           "kth_key_encoded"]


def _kernel_default() -> bool:
    return jax.default_backend() == "tpu"


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(tile: Optional[int], digit_bits: Optional[int]
             ) -> Tuple[int, int]:
    """Fill unset kernel parameters from the active tuning profile —
    outside any jit, so profile swaps reach fresh traces."""
    prof = None
    if tile is None or digit_bits is None:
        prof = _tuning.active()
    return (tile if tile is not None else prof.radix_tile,
            digit_bits if digit_bits is not None else prof.digit_bits)


def pass_tile_counts(n: int, dtype, use_kernel: Optional[bool] = None,
                     tile: Optional[int] = None,
                     digit_bits: Optional[int] = None) -> Tuple[int, int]:
    """(refinement passes, histogram tiles per pass) of the k-th-key
    search at this shape — analytic, from static shapes only.  The
    digit-serial kernel path runs ceil(bits/digit_bits) passes over
    ceil(n/tile) VMEM tiles; the bit-serial host path runs ``bits``
    masked zero-counts with no tiling (tiles = 0)."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    bits = keycodec.key_bits(dtype)
    if not use_kernel:
        return bits, 0
    tile, digit_bits = _resolve(tile, digit_bits)
    tile = min(tile, max(8, n))
    return -(-bits // digit_bits), -(-n // tile)


# ---------------------------------------------------------------------------
# per-tile histogram kernel (the radix_sort._digit_stats counting half)
# ---------------------------------------------------------------------------

def _hist_kernel(d_ref, hist_ref, *, ncols: int):
    """Per-tile digit histogram from one one-hot expansion on the VPU."""
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, 1, ncols), 2)
    oh = (d_ref[...][:, :, None] == slots).astype(jnp.int32)
    hist_ref[...] = jnp.sum(oh, axis=1)


def _pick_block_rows(total_rows: int, c: int, ncols: int) -> int:
    # the (br, C, ncols) one-hot tensor dominates VMEM: keep it ~2 MB
    br = max(1, min(total_rows, (2 << 20) // max(1, c * ncols * 4)))
    while total_rows % br:
        br -= 1
    return br


@functools.partial(jax.jit, static_argnames=("ncols", "interpret"))
def _tile_hist(d: jnp.ndarray, ncols: int, interpret: bool) -> jnp.ndarray:
    """(tiles, C) int32 digits in [0, ncols) -> (tiles, ncols) counts."""
    rows, c = d.shape
    br = _pick_block_rows(rows, c, ncols)
    return pl.pallas_call(
        functools.partial(_hist_kernel, ncols=ncols),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, ncols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, ncols), jnp.int32),
        interpret=interpret,
    )(d)


def _masked_hist(digits: jnp.ndarray, active: jnp.ndarray, radix: int,
                 tile: int, interpret: Optional[bool]) -> jnp.ndarray:
    """(rows, n) digits + active mask -> (rows, radix) active-only counts
    on the per-tile Pallas kernel: inactive slots carry digit ``radix``,
    counted into a throwaway column (the bucket_bounds pad trick)."""
    rows, n = digits.shape
    d = jnp.where(active, digits, radix)
    tile = min(tile, max(8, n))
    m = -(-n // tile) * tile
    if m != n:
        d = jnp.pad(d, ((0, 0), (0, m - n)), constant_values=radix)
    interp = _interpret_default() if interpret is None else interpret
    hist = _tile_hist(d.reshape(rows * (m // tile), tile), radix + 1, interp)
    return jnp.sum(hist.reshape(rows, m // tile, radix + 1), axis=1)[:, :radix]


# ---------------------------------------------------------------------------
# digit refinement: the k-th encoded key, no data movement
# ---------------------------------------------------------------------------

def _kth_key_digit_serial(enc: jnp.ndarray, k: int, digit_bits: int,
                          tile: int, interpret: Optional[bool]):
    """digit_bits-wide refinement on the Pallas histogram kernel — the
    TPU path: ceil(b/digit_bits) passes of per-tile VPU counting."""
    rows, _ = enc.shape
    bits = jnp.iinfo(enc.dtype).bits
    radix = 1 << digit_bits
    k_rem = jnp.full((rows,), k, jnp.int32)
    thresh = jnp.zeros((rows,), enc.dtype)
    for shift in range(bits - digit_bits, -1, -digit_bits):
        hi = shift + digit_bits
        if hi >= bits:
            active = jnp.ones(enc.shape, bool)
        else:
            sh = jnp.array(hi, enc.dtype)
            active = jax.lax.shift_right_logical(enc, sh) \
                == jax.lax.shift_right_logical(thresh, sh)[:, None]
        digits = (jax.lax.shift_right_logical(enc, jnp.array(shift, enc.dtype))
                  .astype(jnp.int32) & (radix - 1))
        hist = _masked_hist(digits, active, radix, tile, interpret)
        cum = jnp.cumsum(hist, axis=-1)
        # smallest digit whose cumulative count reaches the residual k
        d = jnp.argmax(cum >= k_rem[:, None], axis=-1).astype(jnp.int32)
        less = jnp.take_along_axis(cum - hist, d[:, None], -1)[:, 0]
        k_rem = k_rem - less
        thresh = thresh | (d.astype(enc.dtype)
                           << jnp.array(shift, enc.dtype))
    return thresh, k_rem


def _kth_key_bit_serial(enc: jnp.ndarray, k: int):
    """1-bit refinement in pure jnp — the host path, and the faithful
    radix-2 analogue of the paper's bit-serial CAS walk: per key bit, one
    masked zero-count (compare + reduction, branchless and SIMD-friendly)
    decides the threshold bit.  b passes of O(n) elementwise work and NOT
    ONE scatter — XLA's CPU scatter serialises, which is exactly why the
    digit histogram stays on the TPU kernel.  The pass loop is a
    ``fori_loop`` (the body is shift-uniform), so the compiled program is
    one pass long instead of b passes long — compile time at engine sizes
    stays flat."""
    rows, _ = enc.shape
    bits = jnp.iinfo(enc.dtype).bits
    one = jnp.array(1, enc.dtype)

    def body(i, carry):
        k_rem, thresh, active = carry
        sh = jnp.array(bits - 1, enc.dtype) - i.astype(enc.dtype)
        bit = (jax.lax.shift_right_logical(enc, sh) & one) != 0
        zeros = active & ~bit
        c0 = jnp.sum(zeros, axis=-1).astype(jnp.int32)
        take0 = k_rem <= c0
        active = jnp.where(take0[:, None], zeros, active & bit)
        k_rem = jnp.where(take0, k_rem, k_rem - c0)
        thresh = jnp.where(take0, thresh, thresh | (one << sh))
        return k_rem, thresh, active

    k_rem, thresh, _ = jax.lax.fori_loop(
        0, bits, body, (jnp.full((rows,), k, jnp.int32),
                        jnp.zeros((rows,), enc.dtype),
                        jnp.ones(enc.shape, bool)))
    return thresh, k_rem


def kth_key_encoded(enc: jnp.ndarray, k: int, *,
                    use_kernel: Optional[bool] = None,
                    tile: Optional[int] = None,
                    digit_bits: Optional[int] = None,
                    interpret: Optional[bool] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per row of unsigned ``(rows, n)``: the k-th *smallest* encoded key
    ``T`` and the residual tie budget ``r = k - #{enc < T}`` (how many
    threshold-equal elements the exact-k rule keeps)."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    if use_kernel:
        tile, digit_bits = _resolve(tile, digit_bits)
        return _kth_key_digit_serial(enc, k, digit_bits, tile, interpret)
    return _kth_key_bit_serial(enc, k)


# ---------------------------------------------------------------------------
# exact-k selection over encoded keys
# ---------------------------------------------------------------------------

def select_topk_encoded(enc: jnp.ndarray, k: int, *,
                        use_kernel: Optional[bool] = None,
                        tile: Optional[int] = None,
                        digit_bits: Optional[int] = None,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rows, n) unsigned encoded keys -> the k smallest per row, in
    ascending (encoded, index) order: ``(enc_topk, indices)``, both
    ``(rows, k)``.  Exactly k survive; ties keep ascending index order."""
    rows, n = enc.shape
    if not 1 <= k <= n:
        raise ValueError(
            f"topk k must satisfy 1 <= k <= n (n={n}); got k={k}")
    thresh, k_eq = kth_key_encoded(enc, k, use_kernel=use_kernel, tile=tile,
                                   digit_bits=digit_bits, interpret=interpret)
    less = enc < thresh[:, None]
    eq = enc == thresh[:, None]
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1) - 1
    take = less | (eq & (eq_rank < k_eq[:, None]))
    # compact the k survivors in index order WITHOUT a scatter: the
    # cumulative take-count is sorted per row, so the j-th survivor's
    # position is one binary search — O(k log n) gathers (XLA CPU scatters
    # serialise; a length-n scatter here would dwarf the counting passes).
    # Then one tiny two-key lexicographic sort orders the k candidates —
    # the merge step of partition-then-merge, degenerated to O(k log k)
    # because only candidates ever move.
    csum = jnp.cumsum(take.astype(jnp.int32), axis=-1)
    targets = jnp.arange(1, k + 1, dtype=jnp.int32)
    # exactly k survive, so csum[-1] == k >= every target: the search
    # always lands in range
    idx_c = jax.vmap(
        lambda c: jnp.searchsorted(c, targets, side="left"))(csum) \
        .astype(jnp.int32)
    enc_c = jnp.take_along_axis(enc, idx_c, axis=-1)
    return jax.lax.sort((enc_c, idx_c), num_keys=2)


# ---------------------------------------------------------------------------
# front doors (source dtypes through the keycodec)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "use_kernel", "tile",
                                             "digit_bits", "interpret"))
def _select_topk_impl(x: jnp.ndarray, k: int, use_kernel: Optional[bool],
                      tile: Optional[int], digit_bits: Optional[int],
                      interpret: Optional[bool]
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc = keycodec.encode(x, descending=True)
    enc_s, idx_s = select_topk_encoded(enc, k, use_kernel=use_kernel,
                                       tile=tile, digit_bits=digit_bits,
                                       interpret=interpret)
    return keycodec.decode(enc_s, x.dtype, descending=True), idx_s


def select_topk(x: jnp.ndarray, k: int, *,
                use_kernel: Optional[bool] = None,
                tile: Optional[int] = None,
                digit_bits: Optional[int] = None,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k largest per row of ``(rows, n)`` -> (values, indices), values
    descending, ties by ascending index — ``jax.lax.top_k``'s convention,
    in O(n·b/digit_bits) counting work instead of a sort.

    The kernel path's ``tile`` / ``digit_bits`` resolve from the active
    tuning profile here, outside the jit, so ``tuning.set_active`` swaps
    re-dispatch instead of hitting a stale trace cache."""
    if use_kernel is None:
        use_kernel = _kernel_default()
    if use_kernel:
        tile, digit_bits = _resolve(tile, digit_bits)
    return _select_topk_impl(x, k, use_kernel, tile, digit_bits, interpret)


def select_topk_kv(keys: jnp.ndarray, values: jnp.ndarray, k: int, *,
                   use_kernel: Optional[bool] = None,
                   tile: Optional[int] = None,
                   digit_bits: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """Key-value variant: ``(topk keys, payload, indices)`` — the payload
    rides the exact-k selection by one gather through the indices."""
    if values.shape != keys.shape:
        raise ValueError(f"values shape {values.shape} must match keys "
                         f"shape {keys.shape}")
    v, i = select_topk(keys, k, use_kernel=use_kernel, tile=tile,
                       digit_bits=digit_bits, interpret=interpret)
    return v, jnp.take_along_axis(values, i, axis=-1), i
