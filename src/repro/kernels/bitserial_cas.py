"""Bit-serial CAS network on TPU lanes — the paper's exact gate schedule.

This kernel executes the reconstructed 28-cycle NOR/NOT/AND/COPY program of
:mod:`repro.core.gates` with each SRAM *row* realised as a VMEM bit-plane of
shape (rows, lanes, W): the paper's column-parallelism maps to the W axis
and the array's batch parallelism maps to the 8x128 vector lanes.  One
simulated IMC cycle = one VPU op over every lane — the closest TPU-idiomatic
equivalent of bitline logic (DESIGN.md §2).

It is deliberately *not* the fast path (word-parallel min/max is ~W times
cheaper — measured in benchmarks/bench_sort_methods.py); it exists to prove
the paper's logic runs unchanged on the target substrate and to anchor the
faithful-baseline row of EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import gates
from repro.core.imc_array import Movement, OpKind, ROW_A, ROW_B, ROW_ONE, ROW_ZERO


def _exec_program(a: jnp.ndarray, b: jnp.ndarray, width: int):
    """Run the gate program on int operands of shape (rows, lanes)."""
    prog = gates.build_cas_program(width)
    shape = a.shape + (width,)
    # MSB first; built from an in-trace iota so Pallas sees no captured consts
    shifts = (width - 1) - jax.lax.broadcasted_iota(jnp.int32, (width,), 0)

    planes = {
        ROW_ZERO: jnp.zeros(shape, dtype=bool),
        ROW_ONE: jnp.ones(shape, dtype=bool),
        ROW_A: ((a[..., None] >> shifts) & 1).astype(bool),
        ROW_B: ((b[..., None] >> shifts) & 1).astype(bool),
    }

    for op in prog.ops:
        x = planes[op.src1]
        if op.kind is OpKind.NOR:
            r = ~(x | planes[op.src2])
        elif op.kind is OpKind.AND:
            r = x & planes[op.src2]
        elif op.kind is OpKind.NOT:
            r = ~(x | planes[ROW_ZERO])
        else:  # COPY
            r = x & planes[ROW_ONE]
        if op.movement is Movement.SHIFT_RIGHT:
            fill = jnp.full_like(r[..., :1], bool(op.fill))
            r = jnp.concatenate([fill, r[..., :-1]], axis=-1)
        elif op.movement is Movement.BCAST_LAST:
            r = jnp.broadcast_to(r[..., -1:], r.shape)
        elif op.movement is Movement.BCAST_COL:
            r = jnp.broadcast_to(r[..., op.bcast_col:op.bcast_col + 1], r.shape)
        planes[op.dst] = r

    weights = (1 << shifts).astype(jnp.int32)
    lo = jnp.sum(planes[ROW_A].astype(jnp.int32) * weights, axis=-1)
    hi = jnp.sum(planes[ROW_B].astype(jnp.int32) * weights, axis=-1)
    return lo, hi


def _cas_kernel(a_ref, b_ref, lo_ref, hi_ref, *, width: int):
    lo, hi = _exec_program(a_ref[...], b_ref[...], width)
    lo_ref[...] = lo
    hi_ref[...] = hi


@functools.partial(jax.jit, static_argnames=("width", "block_rows",
                                             "interpret"))
def cas_blocks(a: jnp.ndarray, b: jnp.ndarray, *, width: int = 4,
               block_rows: int = 8, interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Elementwise in-memory CAS of (rows, lanes) unsigned ints < 2**width."""
    rows, lanes = a.shape
    br = max(1, min(block_rows, rows))
    while rows % br:
        br -= 1
    spec = pl.BlockSpec((br, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cas_kernel, width=width),
        grid=(rows // br,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
                   jax.ShapeDtypeStruct((rows, lanes), jnp.int32)],
        interpret=interpret,
    )(a.astype(jnp.int32), b.astype(jnp.int32))
