"""In-VMEM bitonic sort — the paper's architecture mapped to the TPU.

ADS-IMC's premise: sorting is data-movement-bound, so execute the network
*where the data lives*.  On TPU the expensive movement is HBM <-> VMEM, so
this kernel reads each tile of rows into VMEM **once**, runs the *entire*
Batcher bitonic network on the VMEM-resident tile, and writes it back
**once** — 2 x tile_bytes of HBM traffic total, the bandwidth floor.

The CAS block becomes a vector min/max over VPU lanes: one instruction
compares W-bit words across 8x128 lanes simultaneously — the word-parallel
strengthening of the paper's column-parallel bitline logic (DESIGN.md §2).

Stage addressing uses the reshape trick instead of gathers: for a substage
with partner distance j, view the row as (n/(2j), 2, j); partners are then
the two middle-axis halves, and the sort direction is constant per outer
chunk (bit k of the element index) — everything static, MXU/VPU friendly.

The grid partitions the row blocks exactly like the paper partitions its
SRAM macro (§II-B): each grid cell is an independent "memory partition"
running its own network concurrently.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _substages(n: int):
    """Static (k, j) substage schedule of the n-input bitonic network."""
    out = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            out.append((k, j))
            j //= 2
        k *= 2
    return out


def _stage_dirs(n: int, k: int, j: int, descending: bool) -> jnp.ndarray:
    """descending? flag per outer chunk of the (n/(2j), 2, j) view.

    Built from an in-trace iota (not a closed-over constant) so the same
    code path works inside Pallas kernel bodies."""
    q = jax.lax.broadcasted_iota(jnp.int32, (1, n // (2 * j), 1), 1)
    desc = ((q * (2 * j)) & k) != 0
    return desc != descending if descending else desc


def _apply_network(x: jnp.ndarray, descending: bool) -> jnp.ndarray:
    """Run the full network on (rows, n); n a power of two. Pure jnp — usable
    both inside the Pallas kernel body and as the building block of the
    sort_api 'bitonic' backend."""
    rows, n = x.shape
    for (k, j) in _substages(n):
        v = x.reshape(rows, n // (2 * j), 2, j)
        a, b = v[:, :, 0, :], v[:, :, 1, :]
        desc = _stage_dirs(n, k, j, descending)
        mn, mx = jnp.minimum(a, b), jnp.maximum(a, b)
        first = jnp.where(desc, mx, mn)
        second = jnp.where(desc, mn, mx)
        x = jnp.stack([first, second], axis=2).reshape(rows, n)
    return x


def _apply_network_kv(keys: jnp.ndarray, vals: jnp.ndarray,
                      descending: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Network on (rows, n) keys carrying an int payload (for argsort/topk).

    The CAS comparator is the *composite* (key, payload) order: key in the
    requested direction, payload ascending on key ties.  Payloads are unique
    indices everywhere in this repo, so the composite is a strict total
    order — which makes the (otherwise unstable) bitonic network produce the
    stable ties-keep-ascending-index result in both directions, matching the
    engine / xla tie convention.
    """
    rows, n = keys.shape
    for (k, j) in _substages(n):
        kv = keys.reshape(rows, n // (2 * j), 2, j)
        vv = vals.reshape(rows, n // (2 * j), 2, j)
        ka, kb = kv[:, :, 0, :], kv[:, :, 1, :]
        va, vb = vv[:, :, 0, :], vv[:, :, 1, :]
        # raw chunk directions: the final direction lives in the comparator,
        # so chunks flagged here are exactly "reversed w.r.t. final order"
        rev = _stage_dirs(n, k, j, False)
        key_first = (ka > kb) if descending else (ka < kb)
        prec = key_first | ((ka == kb) & (va < vb))
        a_first = prec != rev       # XOR: reversed chunks take the maximum
        kf = jnp.where(a_first, ka, kb)
        ks = jnp.where(a_first, kb, ka)
        vf = jnp.where(a_first, va, vb)
        vs = jnp.where(a_first, vb, va)
        keys = jnp.stack([kf, ks], axis=2).reshape(rows, n)
        vals = jnp.stack([vf, vs], axis=2).reshape(rows, n)
    return keys, vals


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _sort_kernel(x_ref, o_ref, *, descending: bool):
    o_ref[...] = _apply_network(x_ref[...], descending)


def _sort_kv_kernel(k_ref, v_ref, ok_ref, ov_ref, *, descending: bool):
    sk, sv = _apply_network_kv(k_ref[...], v_ref[...], descending)
    ok_ref[...] = sk
    ov_ref[...] = sv


def default_block_rows(n: int, itemsize: int, vmem_budget: int = 8 << 20,
                       streams: int = 2) -> int:
    """Rows per VMEM tile: keep in+out tiles within the VMEM budget and the
    sublane dimension a multiple of 8."""
    rows = max(1, vmem_budget // (streams * n * itemsize * 2))
    if rows >= 8:
        rows -= rows % 8
    return rows


@functools.partial(jax.jit,
                   static_argnames=("descending", "block_rows", "interpret"))
def sort_blocks(x: jnp.ndarray, *, descending: bool = False,
                block_rows: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sort each row of (rows, n) in VMEM. n must be a power of two and rows
    must divide by block_rows (ops.py handles padding/reshaping).
    ``interpret=None`` resolves per-platform like every other kernel entry
    point (interpret mode off-TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rows, n = x.shape
    br = block_rows or min(rows, default_block_rows(n, x.dtype.itemsize))
    br = max(1, min(br, rows))
    while rows % br:
        br -= 1
    grid = (rows // br,)
    return pl.pallas_call(
        functools.partial(_sort_kernel, descending=descending),
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit,
                   static_argnames=("descending", "block_rows", "interpret"))
def sort_kv_blocks(keys: jnp.ndarray, vals: jnp.ndarray, *,
                   descending: bool = False,
                   block_rows: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """Key-value sort of (rows, n) by keys, carrying int32 payloads.
    ``interpret=None`` resolves per-platform (interpret mode off-TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rows, n = keys.shape
    itemsize = keys.dtype.itemsize + vals.dtype.itemsize
    br = block_rows or min(rows, default_block_rows(n, itemsize))
    br = max(1, min(br, rows))
    while rows % br:
        br -= 1
    grid = (rows // br,)
    spec = pl.BlockSpec((br, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sort_kv_kernel, descending=descending),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, n), keys.dtype),
                   jax.ShapeDtypeStruct((rows, n), vals.dtype)],
        interpret=interpret,
    )(keys, vals)
