"""Merge-path (diagonal-partitioned) merge of sorted runs, in VMEM.

The engine's merge tree needs a merge primitive whose work is O(n) per level
instead of the bitonic merge box's O(n log n) compare-and-swaps.  Merge path
(Green/McColl/Odeh) splits the output of ``merge(a, b)`` into equal chunks by
binary-searching the merge matrix's diagonals; each chunk then depends on one
bounded window of ``a`` and one of ``b`` (|window_a| + |window_b| = chunk), so
chunks are embarrassingly parallel and perfectly load-balanced — the same
partition-then-exchange structure ADS-IMC uses across its SRAM CAS partitions
(§II-B), applied one level up the hierarchy.

Division of labour:

  host (jnp)     diagonal binary search -> per-chunk window starts/counts,
                 windows gathered into contiguous (rows*chunks, C) arrays.
  kernel (VMEM)  rank-based merge of the two windows: each element's output
                 slot is its window index plus its cross-rank in the other
                 window (counted with a C x C comparison matrix on the VPU),
                 then a one-hot select writes the chunk — no dynamic scatter,
                 no serial loop, everything vector ops.

Validity is tracked with explicit per-window counts (not key sentinels), so
inputs containing the dtype's extreme values still merge bit-exactly.  Keys
must be NaN-free (comparisons follow min/max semantics, like the bitonic
kernels).  Ascending only — callers flip for descending merges.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 256


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _window_ranks(a, b, ca):
    """Output slot of every window element (ascending, a wins ties).

    a, b: (br, C) value windows; ca: (br, 1) count of valid a-elements
    (valid b-count is C - ca).  Invalid slots rank past the chunk (>= C).
    """
    br, c = a.shape
    ii = jax.lax.broadcasted_iota(jnp.int32, (br, c), 1)
    valid_a = ii < ca
    valid_b = ii < (c - ca)
    # b_before[r, i, j]: does b[j] precede a[i]?  (strict: a first on ties)
    b_before = (b[:, None, :] < a[:, :, None]) & valid_b[:, None, :]
    ra = ii + jnp.sum(b_before.astype(jnp.int32), axis=2)
    ra = jnp.where(valid_a, ra, c)
    # a_before_or_tie[r, i, j]: does a[i] precede b[j]?
    a_before = (a[:, :, None] <= b[:, None, :]) & valid_a[:, :, None]
    rb = ii + jnp.sum(a_before.astype(jnp.int32), axis=1)
    rb = jnp.where(valid_b, rb, c)
    return ra, rb


def _one_hot_place(src, ranks, c):
    """Route src[r, i] to output slot ranks[r, i]; slots >= c drop out."""
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, 1, c), 2)
    hit = ranks[:, :, None] == slots
    zero = jnp.zeros((), src.dtype)
    return jnp.sum(jnp.where(hit, src[:, :, None], zero), axis=1)


def _merge_chunk_kernel(ca_ref, wa_ref, wb_ref, o_ref):
    a, b = wa_ref[...], wb_ref[...]
    c = a.shape[-1]
    ra, rb = _window_ranks(a, b, ca_ref[...])
    o_ref[...] = _one_hot_place(a, ra, c) + _one_hot_place(b, rb, c)


def _merge_chunk_kv_kernel(ca_ref, wa_ref, wb_ref, va_ref, vb_ref,
                           ok_ref, ov_ref):
    a, b = wa_ref[...], wb_ref[...]
    c = a.shape[-1]
    ra, rb = _window_ranks(a, b, ca_ref[...])
    ok_ref[...] = _one_hot_place(a, ra, c) + _one_hot_place(b, rb, c)
    ov_ref[...] = (_one_hot_place(va_ref[...], ra, c)
                   + _one_hot_place(vb_ref[...], rb, c))


# ---------------------------------------------------------------------------
# host side: diagonal partition + window gather
# ---------------------------------------------------------------------------

def _diag_search(a, b, diag):
    """Merge-path split: #a-elements among the first ``diag`` merged outputs.

    a, b: (rows, La/Lb) ascending; diag: (n_diag,) int32.  Returns
    (rows, n_diag).  Ties go to ``a`` (stable when a precedes b).  Classic
    monotone-predicate binary search, vectorised over rows x diagonals.
    """
    la, lb = a.shape[-1], b.shape[-1]
    d = jnp.broadcast_to(diag[None, :], (a.shape[0], diag.shape[0]))
    lo = jnp.maximum(0, d - lb)
    hi = jnp.minimum(d, la)
    steps = max(1, int(la).bit_length())
    for _ in range(steps):
        mid = (lo + hi + 1) // 2
        a_prev = jnp.take_along_axis(a, jnp.clip(mid - 1, 0, la - 1), axis=-1)
        b_next = jnp.take_along_axis(b, jnp.clip(d - mid, 0, lb - 1), axis=-1)
        # feasible(mid): can take >= mid elements of a before diag?
        feasible = (mid <= lo) | (d - mid >= lb) | (a_prev <= b_next)
        lo = jnp.where(feasible, jnp.maximum(lo, mid), lo)
        hi = jnp.where(feasible, hi, jnp.minimum(hi, mid - 1))
    return lo


def _gather_windows(x, starts, c):
    """x: (rows, L) -> (rows, n_chunks, c) windows starting at ``starts``."""
    l = x.shape[-1]
    idx = jnp.clip(starts[..., None]
                   + jnp.arange(c, dtype=jnp.int32)[None, None, :], 0, l - 1)
    return jnp.take_along_axis(x[:, None, :], idx, axis=-1)


def _pick_block_rows(total_rows: int, c: int) -> int:
    # the (br, C, C) comparison tensor dominates VMEM: keep it ~2 MB
    br = max(1, min(total_rows, (2 << 20) // max(1, c * c * 4)))
    while total_rows % br:
        br -= 1
    return br


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def merge_pairs_blocks(a: jnp.ndarray, b: jnp.ndarray, *,
                       chunk: int = DEFAULT_CHUNK,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Merge row-wise sorted (rows, L) + (rows, L) -> (rows, 2L), ascending."""
    (out,) = _merge_impl(a, b, (), chunk, interpret)
    return out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def merge_pairs_kv_blocks(a: jnp.ndarray, b: jnp.ndarray,
                          va: jnp.ndarray, vb: jnp.ndarray, *,
                          chunk: int = DEFAULT_CHUNK,
                          interpret: Optional[bool] = None
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Key-value variant: payloads ride along their keys through the merge."""
    return tuple(_merge_impl(a, b, (va, vb), chunk, interpret))


def _merge_impl(a, b, values, chunk, interpret):
    interp = _interpret_default() if interpret is None else interpret
    rows, l = a.shape
    total = 2 * l
    c = min(chunk, total)
    nc = total // c
    diag = (jnp.arange(nc, dtype=jnp.int32)) * c
    starts_a = _diag_search(a, b, diag)                     # (rows, nc)
    ends_a = jnp.concatenate(
        [starts_a[:, 1:], jnp.full((rows, 1), l, jnp.int32)], axis=-1)
    counts_a = (ends_a - starts_a).reshape(rows * nc, 1)
    starts_b = diag[None, :] - starts_a
    wa = _gather_windows(a, starts_a, c).reshape(rows * nc, c)
    wb = _gather_windows(b, starts_b, c).reshape(rows * nc, c)
    ins = [counts_a, wa, wb]
    outs = [jax.ShapeDtypeStruct((rows * nc, c), a.dtype)]
    kernel = _merge_chunk_kernel
    if values:
        va, vb = values
        ins += [_gather_windows(va, starts_a, c).reshape(rows * nc, c),
                _gather_windows(vb, starts_b, c).reshape(rows * nc, c)]
        outs.append(jax.ShapeDtypeStruct((rows * nc, c), va.dtype))
        kernel = _merge_chunk_kv_kernel
    br = _pick_block_rows(rows * nc, c)
    grid = (rows * nc // br,)
    cspec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    wspec = pl.BlockSpec((br, c), lambda i: (i, 0))
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[cspec] + [wspec] * (len(ins) - 1),
        out_specs=[wspec] * len(outs),
        out_shape=outs,
        interpret=interp,
    )(*ins)
    return [r.reshape(rows, total) for r in res]
