"""In-VMEM bitonic top-k — partial sort for MoE routing and sampling.

Top-k is the framework's hottest sorting workload (expert selection per
token; logits filtering per decode step).  The kernel sorts a VMEM-resident
block descending with the bitonic network, carrying lane indices as payload,
and emits only the first k columns — one HBM read of the block, one HBM
write of k columns.

For large n (vocab-sized), ops.py composes this hierarchically: chunk the
axis, per-chunk kernel top-k, then kv-merge of the (n/chunk)*k candidates —
the same partition-then-merge structure the paper uses across its SRAM
partitions (§II-B).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic_sort import (_apply_network_kv,
                                        default_block_rows)


def _topk_kernel(x_ref, ov_ref, oi_ref, *, k: int):
    x = x_ref[...]
    rows, n = x.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (rows, n), 1)
    sk, si = _apply_network_kv(x, idx, descending=True)
    ov_ref[...] = sk[:, :k]
    oi_ref[...] = si[:, :k]


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_blocks(x: jnp.ndarray, k: int, *, block_rows: Optional[int] = None,
                interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row top-k of (rows, n) -> (rows, k) values + indices (descending).
    n must be a power of two >= k (ops.py handles padding)."""
    rows, n = x.shape
    br = block_rows or min(rows, default_block_rows(n, x.dtype.itemsize + 4))
    br = max(1, min(br, rows))
    while rows % br:
        br -= 1
    grid = (rows // br,)
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, k), lambda i: (i, 0)),
                   pl.BlockSpec((br, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, k), x.dtype),
                   jax.ShapeDtypeStruct((rows, k), jnp.int32)],
        interpret=interpret,
    )(x)
