"""Public, jit'd entry points for the Pallas sorting kernels.

Handles everything the raw kernels don't: arbitrary axes and leading dims,
non-power-of-two padding, hierarchical composition for vocab-sized top-k,
autodiff (custom VJPs — sort is a permutation, so its transpose is a
scatter), and interpret-mode fallback so the same code runs on CPU CI.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitonic_sort as _bs
from repro.kernels import bitonic_topk as _bt
from repro.kernels import bitserial_cas as _bc


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _sentinel(dtype, descending: bool):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if descending else jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if descending else info.max, dtype)


def _to_rows(x: jnp.ndarray, axis: int):
    """Move ``axis`` last and flatten leading dims -> (rows, n)."""
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead, axis


def _from_rows(rows: jnp.ndarray, lead, axis: int):
    return jnp.moveaxis(rows.reshape(*lead, rows.shape[-1]), -1, axis)


# ---------------------------------------------------------------------------
# sort
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def bitonic_sort(x: jnp.ndarray, axis: int = -1, descending: bool = False,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sort along ``axis`` with the in-VMEM bitonic kernel."""
    out, _ = _sort_fwd_impl(x, axis, descending, interpret)
    return out


def _sort_fwd_impl(x, axis, descending, interpret):
    interp = _interpret_default() if interpret is None else interpret
    rows, lead, ax = _to_rows(x, axis)
    n = rows.shape[-1]
    m = _next_pow2(n)
    if m != n:
        rows = jnp.pad(rows, ((0, 0), (0, m - n)),
                       constant_values=_sentinel(x.dtype, descending))
    idx = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), rows.shape)
    sk, si = _bs.sort_kv_blocks(rows, idx, descending=descending,
                                interpret=interp)
    sk, si = sk[:, :n], si[:, :n]
    return _from_rows(sk, lead, ax), _from_rows(si, lead, ax)


def _sort_fwd(x, axis, descending, interpret):
    out, order = _sort_fwd_impl(x, axis, descending, interpret)
    return out, order


def _sort_bwd(axis, descending, interpret, order, g):
    shape = order.shape
    ax = axis % len(shape)
    go = jnp.moveaxis(g, ax, -1)
    oo = jnp.moveaxis(order, ax, -1)
    lead = go.shape[:-1]
    n = go.shape[-1]
    go2 = go.reshape(-1, n)
    oo2 = oo.reshape(-1, n)
    gx = jnp.zeros_like(go2)
    rows = jnp.arange(go2.shape[0])[:, None]
    gx = gx.at[rows, oo2].add(go2)
    gx = jnp.moveaxis(gx.reshape(*lead, n), -1, ax)
    return (gx,)


bitonic_sort.defvjp(_sort_fwd, _sort_bwd)


def bitonic_argsort(x: jnp.ndarray, axis: int = -1, descending: bool = False,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Argsort along ``axis`` with the in-VMEM kv kernel (int32 indices)."""
    _, order = _sort_fwd_impl(x, axis, descending, interpret)
    return order


# ---------------------------------------------------------------------------
# top-k (hierarchical for large n)
# ---------------------------------------------------------------------------

_TOPK_CHUNK = 2048


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def bitonic_topk(x: jnp.ndarray, k: int, chunk: int = _TOPK_CHUNK,
                 interpret: Optional[bool] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k along the last axis -> (values, indices), descending order.

    Large axes are processed as partitions of ``chunk`` lanes (per-partition
    kernel top-k) followed by a kv-merge of candidates — the paper's
    partition-then-merge structure (§II-B).
    """
    return _topk_impl(x, k, chunk, interpret)


def _topk_impl(x, k, chunk, interpret):
    interp = _interpret_default() if interpret is None else interpret
    rows, lead, _ = _to_rows(x, -1)
    n = rows.shape[-1]
    sent = _sentinel(x.dtype, descending=True)

    if n <= chunk:
        m = max(_next_pow2(n), _next_pow2(k))
        if m != n:
            rows = jnp.pad(rows, ((0, 0), (0, m - n)), constant_values=sent)
        v, i = _bt.topk_blocks(rows, k, interpret=interp)
        return (v.reshape(*lead, k), i.reshape(*lead, k))

    # hierarchical: per-chunk top-k, then merge candidates by key
    n_chunks = -(-n // chunk)
    m = n_chunks * chunk
    if m != n:
        rows = jnp.pad(rows, ((0, 0), (0, m - n)), constant_values=sent)
    r = rows.reshape(-1, chunk)
    kk = min(k, chunk)
    v, i = _bt.topk_blocks(r, kk, interpret=interp)
    offs = (jnp.arange(n_chunks, dtype=jnp.int32) * chunk)[None, :, None]
    v = v.reshape(-1, n_chunks, kk)
    i = i.reshape(-1, n_chunks, kk) + offs
    cand_v = v.reshape(-1, n_chunks * kk)
    cand_i = i.reshape(-1, n_chunks * kk)
    cm = _next_pow2(cand_v.shape[-1])
    if cm != cand_v.shape[-1]:
        pad = cm - cand_v.shape[-1]
        cand_v = jnp.pad(cand_v, ((0, 0), (0, pad)), constant_values=sent)
        cand_i = jnp.pad(cand_i, ((0, 0), (0, pad)), constant_values=-1)
    sv, si = _bs.sort_kv_blocks(cand_v, cand_i, descending=True,
                                interpret=interp)
    return (sv[:, :k].reshape(*lead, k), si[:, :k].reshape(*lead, k))


def _topk_fwd(x, k, chunk, interpret):
    v, i = _topk_impl(x, k, chunk, interpret)
    return (v, i), (i, jnp.shape(x)[-1], x.shape)


def _topk_bwd(k, chunk, interpret, res, g):
    idx, n, shape = res
    gv, _ = g
    lead_n = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    gv2 = gv.reshape(lead_n, k)
    ix2 = idx.reshape(lead_n, k)
    gx = jnp.zeros((lead_n, n), dtype=gv.dtype)
    rows = jnp.arange(lead_n)[:, None]
    gx = gx.at[rows, ix2].add(gv2)
    return (gx.reshape(shape),)


bitonic_topk.defvjp(_topk_fwd, _topk_bwd)


# ---------------------------------------------------------------------------
# bit-serial CAS (faithful mode)
# ---------------------------------------------------------------------------

def bitserial_cas(a: jnp.ndarray, b: jnp.ndarray, *, width: int = 4,
                  interpret: Optional[bool] = None):
    """Elementwise (min, max) of unsigned ints via the paper's gate program."""
    interp = _interpret_default() if interpret is None else interpret
    shape = a.shape
    flat_a = a.reshape(-1)
    flat_b = b.reshape(-1)
    n = flat_a.shape[0]
    lanes = 128 if n >= 128 else n
    m = -(-n // lanes) * lanes
    if m != n:
        flat_a = jnp.pad(flat_a, (0, m - n))
        flat_b = jnp.pad(flat_b, (0, m - n))
    lo, hi = _bc.cas_blocks(flat_a.reshape(-1, lanes),
                            flat_b.reshape(-1, lanes),
                            width=width, interpret=interp)
    return (lo.reshape(-1)[:n].reshape(shape),
            hi.reshape(-1)[:n].reshape(shape))
