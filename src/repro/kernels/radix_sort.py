"""LSD radix sort over encoded keys — the O(n·b) digit-serial backend.

ADS-IMC's CAS program is *bit*-serial: one pass over the operands per key
bit, constant work per pass.  This kernel is the VMEM analogue one level up:
a least-significant-digit radix sort whose passes are *digit*-serial
(``DIGIT_BITS`` bits at a time), giving O(n·b/DIGIT_BITS) total work — the
asymptotic the comparison backends (O(n log n) merge, O(n log^2 n) bitonic)
cannot reach once n outgrows the key width.

Keys must already be unsigned with order matching ``<`` on the source dtype
— that is ``core/keycodec.py``'s job (sign-flip for ints, sign-magnitude ->
lexicographic for floats, complement for descending).  This module is
ascending-only and *stable*: equal keys keep their input order, which also
makes the padding scheme safe (pads carry the max key and are appended
after the payload, so stability parks them at the far end).

Division of labour per digit pass (the classic three-phase LSD structure):

  kernel 1 (VMEM)  per-tile digit histogram + per-element local stable rank
                   (exclusive running count of equal digits), both from one
                   one-hot expansion on the VPU.
  host (jnp)       digit-major exclusive prefix-sum across all tiles of a
                   row -> the global base offset of every (tile, digit).
  kernel 2 (VMEM)  global position = base[digit] gathered by one-hot select
                   + local rank.
  host (jnp)       one stable scatter materialises the permutation (flat
                   int32 indices), then keys/values move with gathers.

The grid partitions tiles exactly like the paper partitions its SRAM macro
(§II-B): each grid cell histograms its own partition concurrently, and the
exclusive prefix-sum plays the role of the operand-exchange step between
partitions.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the kernel shape parameters (digit width, histogram tile) live in the
# tuning layer; the analytic cost model resolves the same profile, so
# pricing and kernel can't drift — and the dependency points the right way
# (kernels consume tuning; cost_model consumes tuning; neither owns the
# other's constants)
from repro.core import tuning as _tuning


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(tile: Optional[int], digit_bits: Optional[int]
             ) -> Tuple[int, int]:
    """Fill unset kernel parameters from the active tuning profile.  Runs
    *outside* the jitted entry points so a profile swap reaches fresh
    traces instead of being baked into a stale jit cache."""
    prof = None
    if tile is None or digit_bits is None:
        prof = _tuning.active()
    return (tile if tile is not None else prof.radix_tile,
            digit_bits if digit_bits is not None else prof.digit_bits)


def pass_tile_counts(n: int, dtype, tile: Optional[int] = None,
                     digit_bits: Optional[int] = None) -> Tuple[int, int]:
    """(digit passes, VMEM tiles per row) ``sort_blocks`` runs at this
    shape — analytic, from static shapes only, so observability spans and
    cost-model cross-checks can label a jitted kernel call without
    reaching inside the trace."""
    from repro.core import keycodec
    tile, digit_bits = _resolve(tile, digit_bits)
    bits = keycodec.key_bits(dtype)
    tile = min(tile, max(8, n))
    return -(-bits // digit_bits), -(-n // tile)


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _one_hot(d, radix: int):
    """(br, C) int32 digits -> (br, C, radix) int32 one-hot."""
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, 1, radix), 2)
    return (d[:, :, None] == slots).astype(jnp.int32)


def _digit_stats_kernel(d_ref, hist_ref, rank_ref, *, radix: int):
    """Per-tile histogram + local stable rank of each element's digit."""
    oh = _one_hot(d_ref[...], radix)
    hist_ref[...] = jnp.sum(oh, axis=1)
    # exclusive running count of this digit within the tile = stable rank
    rank_ref[...] = jnp.sum((jnp.cumsum(oh, axis=1) - oh) * oh, axis=2)


def _global_pos_kernel(d_ref, base_ref, rank_ref, pos_ref, *, radix: int):
    """Global slot = base offset of (tile, digit) + local rank."""
    oh = _one_hot(d_ref[...], radix)
    base = base_ref[...]                                  # (br, radix)
    pos_ref[...] = jnp.sum(base[:, None, :] * oh, axis=2) + rank_ref[...]


# ---------------------------------------------------------------------------
# pallas wrappers
# ---------------------------------------------------------------------------

def _pick_block_rows(total_rows: int, c: int, radix: int) -> int:
    # the (br, C, radix) one-hot tensor dominates VMEM: keep it ~2 MB
    br = max(1, min(total_rows, (2 << 20) // max(1, c * radix * 4)))
    while total_rows % br:
        br -= 1
    return br


@functools.partial(jax.jit, static_argnames=("radix", "interpret"))
def _digit_stats(d: jnp.ndarray, radix: int, interpret: bool):
    rows, c = d.shape
    br = _pick_block_rows(rows, c, radix)
    dspec = pl.BlockSpec((br, c), lambda i: (i, 0))
    hspec = pl.BlockSpec((br, radix), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_digit_stats_kernel, radix=radix),
        grid=(rows // br,),
        in_specs=[dspec],
        out_specs=[hspec, dspec],
        out_shape=[jax.ShapeDtypeStruct((rows, radix), jnp.int32),
                   jax.ShapeDtypeStruct((rows, c), jnp.int32)],
        interpret=interpret,
    )(d)


@functools.partial(jax.jit, static_argnames=("radix", "interpret"))
def _global_pos(d: jnp.ndarray, base: jnp.ndarray, rank: jnp.ndarray,
                radix: int, interpret: bool) -> jnp.ndarray:
    rows, c = d.shape
    br = _pick_block_rows(rows, c, radix)
    dspec = pl.BlockSpec((br, c), lambda i: (i, 0))
    bspec = pl.BlockSpec((br, radix), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_global_pos_kernel, radix=radix),
        grid=(rows // br,),
        in_specs=[dspec, bspec, dspec],
        out_specs=dspec,
        out_shape=jax.ShapeDtypeStruct((rows, c), jnp.int32),
        interpret=interpret,
    )(d, base, rank)


# ---------------------------------------------------------------------------
# host orchestration: pass loop, padding, permutation
# ---------------------------------------------------------------------------

def _pass_permutation(keys: jnp.ndarray, shift: int, tile: int,
                      digit_bits: int, interpret: bool) -> jnp.ndarray:
    """Stable permutation ordering ``keys`` by digit ``shift`` (gather form)."""
    rows, n = keys.shape
    radix = 1 << digit_bits
    n_tiles = n // tile
    digits = jax.lax.shift_right_logical(
        keys, jnp.array(shift, keys.dtype)).astype(jnp.int32) & (radix - 1)
    d = digits.reshape(rows * n_tiles, tile)
    hist, rank = _digit_stats(d, radix, interpret)
    # exclusive prefix-sum in digit-major, tile-minor order: every element
    # with a smaller digit anywhere in the row, or the same digit in an
    # earlier tile, precedes you
    h = hist.reshape(rows, n_tiles, radix)
    flat = jnp.swapaxes(h, 1, 2).reshape(rows, radix * n_tiles)
    excl = jnp.cumsum(flat, axis=-1) - flat
    base = jnp.swapaxes(excl.reshape(rows, radix, n_tiles), 1, 2)
    pos = _global_pos(d, base.reshape(rows * n_tiles, radix), rank,
                      radix, interpret).reshape(rows, n)
    # stable scatter: invert the position map once, then everything moves
    # by gathers (XLA CPU scatters serialise; one int32 scatter is the floor)
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (rows, n))
    inv = jnp.zeros((rows, n), jnp.int32).at[
        jnp.arange(rows, dtype=jnp.int32)[:, None], pos].set(src)
    return inv


def _padded(keys, vals, tile):
    rows, n = keys.shape
    tile = min(tile, max(8, n))
    m = -(-n // tile) * tile
    if m != n:
        maxkey = jnp.array((1 << jnp.iinfo(keys.dtype).bits) - 1, keys.dtype)
        keys = jnp.pad(keys, ((0, 0), (0, m - n)), constant_values=maxkey)
        if vals is not None:
            # out-of-range marker; stability keeps pads behind real
            # elements even when genuine keys equal the pad key
            vals = jnp.pad(vals, ((0, 0), (0, m - n)),
                           constant_values=jnp.array(n, vals.dtype))
    return keys, vals, tile


@functools.partial(jax.jit,
                   static_argnames=("tile", "digit_bits", "interpret"))
def _sort_blocks_impl(keys: jnp.ndarray, *, tile: int, digit_bits: int,
                      interpret: bool) -> jnp.ndarray:
    rows, n = keys.shape
    keys, _, tile = _padded(keys, None, tile)
    for shift in range(0, jnp.iinfo(keys.dtype).bits, digit_bits):
        inv = _pass_permutation(keys, shift, tile, digit_bits, interpret)
        keys = jnp.take_along_axis(keys, inv, axis=-1)
    return keys[:, :n]


@functools.partial(jax.jit,
                   static_argnames=("tile", "digit_bits", "interpret"))
def _sort_kv_blocks_impl(keys: jnp.ndarray, vals: jnp.ndarray, *, tile: int,
                         digit_bits: int, interpret: bool
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    rows, n = keys.shape
    keys, vals, tile = _padded(keys, vals, tile)
    for shift in range(0, jnp.iinfo(keys.dtype).bits, digit_bits):
        inv = _pass_permutation(keys, shift, tile, digit_bits, interpret)
        keys = jnp.take_along_axis(keys, inv, axis=-1)
        vals = jnp.take_along_axis(vals, inv, axis=-1)
    return keys[:, :n], vals[:, :n]


def sort_blocks(keys: jnp.ndarray, *, tile: Optional[int] = None,
                digit_bits: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Stable ascending LSD radix sort of each row of unsigned (rows, n).

    ``tile`` / ``digit_bits`` default to the active tuning profile; the
    resolution happens here, outside the jit, so the inner trace sees
    concrete statics and a ``tuning.set_active`` swap re-dispatches
    instead of replaying a cache keyed on stale parameters."""
    tile, digit_bits = _resolve(tile, digit_bits)
    interp = _interpret_default() if interpret is None else interpret
    return _sort_blocks_impl(keys, tile=tile, digit_bits=digit_bits,
                             interpret=interp)


def sort_kv_blocks(keys: jnp.ndarray, vals: jnp.ndarray, *,
                   tile: Optional[int] = None,
                   digit_bits: Optional[int] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Key-value variant: payloads ride their keys through every pass."""
    tile, digit_bits = _resolve(tile, digit_bits)
    interp = _interpret_default() if interpret is None else interpret
    return _sort_kv_blocks_impl(keys, vals, tile=tile, digit_bits=digit_bits,
                                interpret=interp)
