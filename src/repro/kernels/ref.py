"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def bitonic_sort(x: jnp.ndarray, descending: bool = False) -> jnp.ndarray:
    """Oracle for kernels.bitonic_sort: sort along the last axis."""
    out = jnp.sort(x, axis=-1)
    return jnp.flip(out, -1) if descending else out


def bitonic_sort_kv(keys: jnp.ndarray, values: jnp.ndarray,
                    descending: bool = False):
    """Oracle for the key-value sort: stable argsort by key, gather payload."""
    order = jnp.argsort(keys, axis=-1, stable=True)
    if descending:
        order = jnp.flip(order, -1)
    sk = jnp.take_along_axis(keys, order, axis=-1)
    sv = jnp.take_along_axis(values, order, axis=-1)
    return sk, sv


def bitonic_topk(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.bitonic_topk (descending values + indices)."""
    return jax.lax.top_k(x, k)


def bitserial_cas(a: jnp.ndarray, b: jnp.ndarray):
    """Oracle for the bit-serial CAS kernel."""
    return jnp.minimum(a, b), jnp.maximum(a, b)
