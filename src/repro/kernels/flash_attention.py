"""Flash attention (forward) — in-VMEM softmax-attention, Pallas.

The ADS-IMC thesis applied to attention: the S x S score matrix never
touches HBM.  Each grid cell owns one query block in VMEM and streams KV
blocks through it with the online-softmax recurrence (running max m,
normaliser l, accumulator acc — all fp32 in registers/VMEM).  HBM traffic
collapses from O(S^2) score bytes to the O(S) q/k/v/o streams, which is
exactly the term that dominates the prefill_32k roofline cells
(EXPERIMENTS.md §Roofline).

Layout: inputs are flattened to rows — q2 (B*R*G, S, H); k2/v2 (B*R, T, H).
Row r of q2 attends to kv row r // G (blocked GQA grouping, matching
attention._attend).  The grid is (rows, S/q_block); the kv stream is a
`fori_loop` whose upper bound is causal-clipped, so fully-masked blocks are
never read.

Forward-only by design: training keeps the q-chunked einsum path (its
backward is handled by remat), serving/prefill use this kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, off_ref, o_ref, *, q_block: int,
                  k_block: int, causal: bool, window: int, t_len: int,
                  scale: float):
    j = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (qb, H)
    qb, h = q.shape

    # global query offset (context-parallel shards pass their shard origin)
    q_start = j * q_block + off_ref[0, 0]
    if causal:
        hi = jnp.minimum(t_len, q_start + q_block)       # last visible key+1
    else:
        hi = t_len
    n_kv = pl.cdiv(hi, k_block)

    def body(c, carry):
        m, l, acc = carry
        # index the leading block dim with a size-1 ds: this jax build's
        # pl.load rejects bare int indices (int has no .shape)
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(c * k_block, k_block),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(c * k_block, k_block),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                      # (qb, kb)
        kpos = c * k_block + jax.lax.broadcasted_iota(
            jnp.int32, (qb, k_block), 1)
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (qb, k_block), 0)
        mask = kpos < t_len
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + jnp.sum(p, axis=-1)
        acc_new = acc * correction[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((qb,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb,), jnp.float32)
    acc0 = jnp.zeros((qb, h), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "k_block", "interpret"))
def flash_rows(q2: jnp.ndarray, k2: jnp.ndarray, v2: jnp.ndarray,
               q_offset: jnp.ndarray = None, *,
               causal: bool = True, window: int = 0, q_block: int = 512,
               k_block: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q2: (RQ, S, H); k2/v2: (RK, T, H); RQ = RK * G.  S % q_block == 0.
    q_offset: scalar global origin of q2's sequence (context parallelism)."""
    rq, s, h = q2.shape
    rk, t, _ = k2.shape
    g = rq // rk
    scale = 1.0 / (h ** 0.5)
    t_pad = (-t) % k_block
    if t_pad:
        k2 = jnp.pad(k2, ((0, 0), (0, t_pad), (0, 0)))
        v2 = jnp.pad(v2, ((0, 0), (0, t_pad), (0, 0)))
    if q_offset is None:
        q_offset = jnp.zeros((), jnp.int32)
    off = jnp.reshape(q_offset.astype(jnp.int32), (1, 1))
    grid = (rq, s // q_block)
    return pl.pallas_call(
        functools.partial(_flash_kernel, q_block=q_block, k_block=k_block,
                          causal=causal, window=window, t_len=t,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, h), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t + t_pad, h), lambda i, j: (i // g, 0, 0)),
            pl.BlockSpec((1, t + t_pad, h), lambda i, j: (i // g, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, h), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((rq, s, h), q2.dtype),
        interpret=interpret,
    )(q2, k2, v2, off)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 512, k_block: int = 512,
                    q_offset=None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, S, N, H); k/v: (B, T, R, H) with N = R * G (blocked groups).
    q_offset: scalar global position of q[:, 0] (context parallelism).
    Returns (B, S, N, H)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, n, h = q.shape
    t, r = k.shape[1], k.shape[2]
    # rows: q (B,S,N,H) -> (B,N,S,H) -> (B*N, S, H); N = R*G blocked, so
    # q row (b*n) maps to kv row (b*r + n//g) with g = n // r
    q2 = jnp.moveaxis(q, 1, 2).reshape(b * n, s, h)
    k2 = jnp.moveaxis(k, 1, 2).reshape(b * r, t, h)
    v2 = jnp.moveaxis(v, 1, 2).reshape(b * r, t, h)
    qb = min(q_block, s)
    pad = (-s) % qb
    if pad:
        q2 = jnp.pad(q2, ((0, 0), (0, pad), (0, 0)))
    out = flash_rows(q2, k2, v2, q_offset, causal=causal, window=window,
                     q_block=qb, k_block=min(k_block, t),
                     interpret=interpret)
    out = out[:, :s].reshape(b, n, s, h)
    return jnp.moveaxis(out, 1, 2)
