"""repro.sort — the one front door for every sort in the system.

A sort problem is a :class:`~repro.core.sortspec.SortSpec` value; executing
one is ``run(spec, x)``.  Everything else in this module is an ergonomic
wrapper that builds the spec for you:

    import repro.sort as rsort

    rsort.sort(x)                                  # ambient default (auto)
    rsort.sort(x, method="radix", descending=True)
    rsort.argsort(x, stable=True)                  # stable permutation
    rsort.topk(logits, 50)                         # (values, indices)
    rsort.sort_kv(keys, payload)                   # payload follows keys
    rsort.segment_sort(vals, segment_ids=seg)      # ragged groups
    rsort.sort(padded, valid_lengths=lens)         # padded-row batches

    with rsort.sort_defaults(method="merge", run_len=4096):
        rsort.sort(x)                              # ambient configuration

Validation (axis range, 1 <= k <= n, incompatible field combos, unknown
methods) happens once at the spec layer; execution is delegated to
``repro.engine``, whose planner resolves "auto" through the backend
registry and caches plans per (spec statics, shape, dtype).  New engines
plug in with ``@register_backend`` — see core/sortspec.py — and are
immediately reachable from every wrapper here.

The legacy ``repro.core.sort_api`` call forms remain as deprecation shims
forwarding to these wrappers.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.sortspec import (  # noqa: F401  (public re-exports)
    Capabilities, SortBackend, SortSpec, backend_names, get_backend,
    register_backend, registered_backends, sort_defaults, unregister_backend)
from repro.engine.planner import clear_plan_cache  # noqa: F401

__all__ = [
    "run", "sort", "argsort", "topk", "sort_kv", "segment_sort",
    "SortSpec", "Capabilities", "SortBackend", "register_backend",
    "unregister_backend", "registered_backends", "backend_names",
    "get_backend", "sort_defaults", "clear_plan_cache",
]

_Arr = jnp.ndarray


def run(spec: SortSpec, x: _Arr) -> Union[_Arr, Tuple[_Arr, _Arr]]:
    """Execute ``spec`` on ``x``.  Returns, by spec shape:

      plain sort                       sorted array
      ``indices=True``                 the sorting permutation (int32)
      ``values`` payload               (sorted keys, permuted payload)
      ``k`` set                        (top-k values, indices), descending
      ``segment_ids``/``row_splits``   (sorted values, grouped segment ids),
                                       or the permutation if ``indices=True``
      ``valid_lengths``                padded rows, valid prefixes sorted
    """
    from repro import engine
    x = jnp.asarray(x)
    spec = spec.canonical(x)

    if spec.mesh is not None:
        # mesh-global path: the distributed backend dispatches sample-sort
        # vs odd-even transposition through planner.choose_distributed;
        # top-k specs run the candidate path (local select + one
        # all-gather) — never a full mesh sort
        from repro.core.sortspec import get_backend as _get
        if spec.k is not None:
            return _get("distributed").topk_mesh(
                x, spec.k, spec.mesh, spec.axis_name,
                interpret=spec.interpret)
        return _get("distributed").sort_mesh(
            x, spec.mesh, spec.axis_name, values=spec.values,
            descending=spec.descending, interpret=spec.interpret)

    if spec.valid_lengths is not None:
        if spec.indices or spec.values is not None:
            raise ValueError("valid_lengths supports value sorts only")
        if x.ndim != 2 or spec.axis != 1:
            raise ValueError("valid_lengths expects a padded (rows, L) "
                             "batch sorted along the last axis")
        return engine.sort_padded_rows(
            x, jnp.asarray(spec.valid_lengths),
            descending=spec.descending, method=spec.method,
            fill_value=spec.fill_value, run_len=spec.run_len,
            interpret=spec.interpret)

    if spec.segment_ids is not None or spec.row_splits is not None:
        if spec.axis != x.ndim - 1:
            raise ValueError("segmented sort runs along the last axis")
        seg = spec.segment_ids
        if seg is None:
            seg = engine.segment_ids_from_row_splits(
                jnp.asarray(spec.row_splits), x.shape[spec.axis])
        seg = jnp.asarray(seg)
        if spec.indices or spec.values is not None:
            order = engine.segmented_argsort(
                x, seg, descending=spec.descending, method=spec.method,
                run_len=spec.run_len, interpret=spec.interpret)
            if spec.indices:
                return order
            return (jnp.take_along_axis(x, order, axis=-1),
                    jnp.take_along_axis(spec.values, order, axis=-1))
        return engine.segmented_sort(
            x, seg, descending=spec.descending, method=spec.method,
            run_len=spec.run_len, interpret=spec.interpret)

    if spec.k is not None:
        ax = spec.axis
        if ax != x.ndim - 1:
            x = jnp.moveaxis(x, ax, -1)
        v, i = engine.topk(x, spec.k, method=spec.method,
                           run_len=spec.run_len, interpret=spec.interpret)
        if ax != v.ndim - 1:
            v, i = jnp.moveaxis(v, -1, ax), jnp.moveaxis(i, -1, ax)
        return v, i

    if spec.indices:
        return engine.argsort(x, axis=spec.axis, descending=spec.descending,
                              method=spec.method, stable=spec.stable,
                              run_len=spec.run_len, interpret=spec.interpret)
    if spec.values is not None:
        return engine.sort_kv(x, spec.values, axis=spec.axis,
                              descending=spec.descending, method=spec.method,
                              stable=spec.stable, run_len=spec.run_len,
                              interpret=spec.interpret)
    return engine.sort(x, axis=spec.axis, descending=spec.descending,
                       method=spec.method, run_len=spec.run_len,
                       interpret=spec.interpret)


# ---------------------------------------------------------------------------
# ergonomic wrappers — each builds a spec and runs it
# ---------------------------------------------------------------------------

def sort(x: _Arr, *, axis: int = -1, descending: bool = False,
         method: Optional[str] = None, run_len: Optional[int] = None,
         interpret: Optional[bool] = None,
         valid_lengths: Optional[_Arr] = None, fill_value=0,
         mesh=None, axis_name: Optional[str] = None) -> _Arr:
    """Sort along ``axis``; with ``valid_lengths``, sort each row's valid
    prefix of a padded batch (the scheduler's fixed-shape buckets); with
    ``mesh``/``axis_name``, sort a flat array globally over the mesh
    (sample-sort; ``axis_name=None`` spans all mesh axes, taking the
    two-level ICI/DCN schedule on multi-axis meshes; odd-even fallback
    on a single axis)."""
    return run(SortSpec(axis=axis, descending=descending, method=method,
                        run_len=run_len, interpret=interpret,
                        valid_lengths=valid_lengths, fill_value=fill_value,
                        mesh=mesh, axis_name=axis_name), x)


def argsort(x: _Arr, *, axis: int = -1, descending: bool = False,
            stable: bool = False, method: Optional[str] = None,
            run_len: Optional[int] = None,
            interpret: Optional[bool] = None) -> _Arr:
    """The sorting permutation (ties keep ascending index order in both
    directions on every backend; ``stable=True`` forces a stable pipeline)."""
    return run(SortSpec(axis=axis, descending=descending, stable=stable,
                        indices=True, method=method, run_len=run_len,
                        interpret=interpret), x)


def topk(x: _Arr, k: int, *, axis: int = -1, method: Optional[str] = None,
         run_len: Optional[int] = None, interpret: Optional[bool] = None,
         mesh=None, axis_name: Optional[str] = None) -> Tuple[_Arr, _Arr]:
    """Top-k along ``axis`` -> (values, indices), descending.  ``k`` is
    validated at the spec layer: 1 <= k <= n or ValueError.

    The plan is k-aware: "auto" picks O(n·passes) radix selection over
    sort-prefix whenever the cost model says ``k ≪ n`` pays.  With
    ``mesh``/``axis_name`` a flat array is selected globally over the mesh
    axis — local select per shard plus ONE candidate all-gather, matching
    ``jax.lax.top_k`` bit-exactly (indices are global positions)."""
    return run(SortSpec(axis=axis, k=k, descending=True, method=method,
                        run_len=run_len, interpret=interpret,
                        mesh=mesh, axis_name=axis_name), x)


def sort_kv(keys: _Arr, values: _Arr, *, axis: int = -1,
            descending: bool = False, stable: bool = False,
            method: Optional[str] = None, run_len: Optional[int] = None,
            interpret: Optional[bool] = None,
            mesh=None, axis_name: Optional[str] = None) -> Tuple[_Arr, _Arr]:
    """Sort ``keys`` carrying ``values`` -> (sorted keys, permuted values).
    With ``mesh``/``axis_name`` the pair is sorted globally over the mesh
    axis (payload buckets ride the sample-sort exchange)."""
    return run(SortSpec(axis=axis, descending=descending, stable=stable,
                        values=jnp.asarray(values), method=method,
                        run_len=run_len, interpret=interpret,
                        mesh=mesh, axis_name=axis_name), keys)


def segment_sort(values: _Arr, *, segment_ids: Optional[_Arr] = None,
                 row_splits: Optional[_Arr] = None, descending: bool = False,
                 method: Optional[str] = None, indices: bool = False):
    """Sort within ragged groups (flat values + segment ids or row splits).

    Returns (sorted values, grouped segment ids), or just the grouping
    permutation with ``indices=True``.
    """
    if segment_ids is None and row_splits is None:
        raise ValueError("segment_sort needs segment_ids or row_splits")
    return run(SortSpec(descending=descending, method=method,
                        segment_ids=segment_ids, row_splits=row_splits,
                        indices=indices), values)
