"""build(config) -> a uniform Model facade over all architecture families.

The facade exposes exactly what launch/, examples/ and tests/ need:

    model.init(key)              -> (params, partition-spec tree)
    model.loss(params, batch)    -> (scalar, aux)       [training]
    model.prefill(params, **)    -> (last logits, decode state)
    model.decode_step(params, token, state) -> (logits, state)
    model.input_specs(shape)     -> ShapeDtypeStruct stand-ins per cell
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.encdec import EncDecTransformer
from repro.models.transformer import Transformer


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    impl: Any                  # Transformer | EncDecTransformer
    policy: Any = None

    @property
    def is_encdec(self) -> bool:
        return isinstance(self.impl, EncDecTransformer)

    def init(self, key):
        return self.impl.init(key)

    def loss(self, params, batch):
        return self.impl.loss(params, batch)

    def prefill(self, params, batch, max_len: int):
        if self.is_encdec:
            return self.impl.prefill(params, batch["frames"],
                                     batch["tokens"], max_len)
        return self.impl.prefill(params, batch["tokens"], max_len,
                                 positions=batch.get("positions"),
                                 vision_embeds=batch.get("vision_embeds"))

    def decode_state(self, batch_size: int, max_len: int):
        if self.is_encdec:
            raise NotImplementedError("enc-dec state comes from prefill")
        return self.impl.init_state(batch_size, max_len)

    def decode_step(self, params, token, state):
        return self.impl.decode_step(params, token, state)

    # ------------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for each input of the step function
        this shape exercises (no allocation; dry-run contract)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if self.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            if cfg.vision_prefix:
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.vision_prefix, cfg.d_model), jnp.bfloat16)
                specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if self.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            if cfg.vision_prefix:
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.vision_prefix, cfg.d_model), jnp.bfloat16)
                specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
            return specs
        # decode: one new token against a seq_len-deep cache
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}


def build(cfg: ModelConfig, policy=None, remat: bool = True) -> Model:
    if cfg.family == "encdec":
        impl = EncDecTransformer(cfg, policy=policy, remat=remat)
    else:
        impl = Transformer(cfg, policy=policy, remat=remat)
    return Model(cfg=cfg, impl=impl, policy=policy)
