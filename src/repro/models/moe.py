"""Mixture-of-Experts with sort-based token routing — the paper's technique
as the dispatch backbone.

Routing pipeline (per data shard, device-local by construction):

  1. router logits -> softmax -> top-k experts per token
     (top-k runs through the repro.sort front door: any registered backend)
  2. the (token, expert) assignment list is *sorted by expert id* with the
     bitonic kv-sort — grouping tokens by expert is literally the paper's
     sorting workload sitting in the middle of the MoE layer
  3. grouped tokens are scattered into a static-capacity (E * C, D) buffer
     (flat 1-D scatter: no batched gather/scatter, SPMD-local)
  4. batched expert matmuls (E, C, D) x (E, D, F) — expert dim sharded over
     the 'model' mesh axis (EP = TP axis, DESIGN.md §4)
  5. outputs gathered back and combined with gate weights (scatter-add)

Distribution: the layer is wrapped in a *partial-manual* shard_map — manual
over the data axes (every shard routes/sorts/scatters its own tokens; zero
cross-device traffic for dispatch), auto over 'model' so GSPMD shards the
expert einsums and inserts the usual TP reduce.  Overflow beyond capacity is
dropped (standard capacity-factor semantics); the residual path keeps those
tokens intact.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import relational
from repro import sort as sorting
from repro.configs.base import MoEConfig
from repro.models import layers


def init(key, d_model: int, cfg: MoEConfig, mlp_type: str, dtype):
    e, f = cfg.n_experts, cfg.d_ff_expert
    gated = mlp_type in ("swiglu", "geglu")
    ks = jax.random.split(key, 5)
    std_in, std_out = 1 / math.sqrt(d_model), 1 / math.sqrt(f)
    params = {
        "router": layers.truncnorm_init(ks[0], (d_model, e), std_in,
                                        jnp.float32),
        "wi": layers.truncnorm_init(ks[1], (e, d_model, f), std_in, dtype),
        "wo": layers.truncnorm_init(ks[2], (e, f, d_model), std_out, dtype),
    }
    specs = {
        "router": P("data", None),
        "wi": P("model", "data", None),
        "wo": P("model", None, "data"),
    }
    if gated:
        params["wg"] = layers.truncnorm_init(ks[3], (e, d_model, f), std_in,
                                             dtype)
        specs["wg"] = P("model", "data", None)
    if cfg.n_shared_experts:
        shared_f = cfg.n_shared_experts * f
        params["shared"], specs["shared"] = layers.mlp_init(
            ks[4], d_model, shared_f, mlp_type, dtype)
    return params, specs


def capacity(tokens_local: int, cfg: MoEConfig) -> int:
    if tokens_local <= cfg.n_experts:
        # decode / tiny-batch regime: capacity = T guarantees zero drops
        # (an expert can receive at most T assignments)
        return tokens_local
    c = int(math.ceil(tokens_local * cfg.top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(c, cfg.top_k)


def apply(params, x, cfg: MoEConfig, mlp_type: str, policy=None):
    """MoE layer under plain pjit (batch-grouped dispatch).

    Dispatch is formulated per batch row so every scatter/gather carries the
    batch dimension: GSPMD partitions batch-dim scatters locally (no token
    exchange over the mesh — the paper's partition-locality property), and
    the only communication is the expert einsum's TP reduce plus the combine
    all-gather over the expert axis.  (A partial-manual shard_map variant
    was measurably cleaner but its VJP crashes this XLA build —
    "Invalid binary instruction opcode copy" — so pjit it is; see
    EXPERIMENTS.md §Dry-run.)
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dp = policy.dp_axes if policy is not None else ()
    tpa = policy.tp_axis if policy is not None else None

    def constrain(v, spec):
        if policy is None or policy.mesh is None:
            return v
        return jax.lax.with_sharding_constraint(
            v, jax.sharding.NamedSharding(policy.mesh, spec))

    # 1. routing (fp32 softmax); expert top-k through the k-aware front
    # door — the planner weighs radix selection against sort-prefix per
    # (n_experts, top_k), so routing never pays for a full sort it
    # doesn't need (cfg.router_method pins a specific backend if set)
    rl = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    rl = constrain(rl, P(dp, None, None))
    probs = jax.nn.softmax(rl, axis=-1)
    gate_v, gate_i = sorting.topk(probs, k, method=cfg.router_method)
    gate_v = gate_v / (jnp.sum(gate_v, axis=-1, keepdims=True) + 1e-9)

    # aux: load-balance (Switch) + router z-loss (global means — pjit
    # reduces across the mesh natively)
    onehot_sel = jax.nn.one_hot(gate_i, e, dtype=jnp.float32)   # (B,S,k,E)
    dispatch_frac = jnp.mean(jnp.sum(onehot_sel, axis=2), axis=(0, 1)) / k
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(dispatch_frac * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(rl, axis=-1)))

    # 2. group (token, expert) pairs by expert id, PER BATCH ROW.  Expert
    # ids are log2(E)-bit keys, so the grouping sort is a COUNTING sort:
    # ``relational.group_ranks`` gives each pair its arrival rank within
    # its expert (one-hot exclusive cumsum on this batched/small-domain
    # shape) — the bit-width-aware strengthening of the paper's 4-bit
    # bitonic sort (DESIGN.md §2).  The bitonic comparison network still
    # powers the top-k above.
    # (token, expert) pairs in (token-major, k-minor) order: pair p belongs
    # to token p // k — a STATIC pattern, so the token-side gather/scatter
    # are reshape/segment-sum ops with cheap, shardable transposes (the
    # dynamic-gather backward was a 26 GB fp32 all-reduce per layer-pass on
    # moonshot before this — EXPERIMENTS.md §Perf iA.2).
    flat_e = gate_i.reshape(b, s * k)                           # (B, S*k)
    flat_g = gate_v.reshape(b, s * k)

    pos = relational.group_ranks(
        flat_e, e,
        constrain=lambda oh: constrain(oh, P(dp, None, None))).ranks

    cap = capacity(s, cfg)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)         # (B, S*k)

    # 3. scatter tokens into per-row expert buffers (B, E*C+1, D)
    xk = jnp.repeat(x, k, axis=1)                               # (B, S*k, D)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buf = buf.at[rows, slot].add(xk)
    buf = buf[:, :-1].reshape(b, e, cap, d)
    buf = constrain(buf, P(dp, tpa, None, None))                # EP slice

    # 4. batched expert matmuls, experts on the TP axis
    act = layers._ACTS[mlp_type]
    h = jnp.einsum("becd,edf->becf", buf, params["wi"])
    if "wg" in params:
        h = act(jnp.einsum("becd,edf->becf", buf, params["wg"])) * h
    else:
        h = act(h)
    y = jnp.einsum("becf,efd->becd", h, params["wo"])           # (B,E,C,D)
    y = constrain(y, P(dp, None, None, None))                   # EP combine

    # 5. gather outputs back per pair (dynamic, slot-indexed), then reduce
    # over the k pairs of each token with a STATIC segment-sum.
    yf = y.reshape(b, e * cap, d)
    g_idx = jnp.where(keep, slot, 0)
    gathered = jnp.take_along_axis(yf, g_idx[..., None], axis=1)
    contrib = gathered * (flat_g * keep).astype(yf.dtype)[..., None]
    out = contrib.reshape(b, s, k, d).sum(axis=2)
    out = constrain(out, P(dp, None, None))

    if cfg.n_shared_experts:
        out = out + layers.mlp_apply(params["shared"], x, mlp_type)
    return out, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}
