"""Attention: MHA/GQA/MQA with RoPE/M-RoPE, causal + sliding-window masks,
cross-attention (enc-dec), and a prefill/decode KV cache.

KV-cache layout: ``(B, S_cache, R, head_dim)`` where R is the *stored* kv-head
count — the raw ``n_kv_heads`` optionally repeated up to the tensor-parallel
degree so the head axis shards evenly (DESIGN.md §4: "repeat-to-TP"); the
repeat factor is decided by the ShardingPolicy, not here.  Sliding-window
layers keep only ``window`` positions (ring buffer) — this is what makes the
recurrentgemma long_500k cell O(window) instead of O(seq).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_cache, R, H)
    v: jnp.ndarray          # (B, S_cache, R, H)


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_type: str = "standard"        # standard | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    causal: bool = True
    window: int = 0                    # 0 = global
    kv_repeat: int = 1                 # R = n_kv_heads * kv_repeat

    @property
    def r_heads(self) -> int:
        return self.n_kv_heads * self.kv_repeat


def init(key, cfg: AttentionConfig, dtype):
    d, n, k, h = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": layers.truncnorm_init(ks[0], (d, n * h), 1 / math.sqrt(d), dtype),
        "wk": layers.truncnorm_init(ks[1], (d, k * h), 1 / math.sqrt(d), dtype),
        "wv": layers.truncnorm_init(ks[2], (d, k * h), 1 / math.sqrt(d), dtype),
        "wo": layers.truncnorm_init(ks[3], (n * h, d), 1 / math.sqrt(n * h),
                                    dtype),
    }
    specs = {"wq": P("data", "model"), "wk": P("data", "model"),
             "wv": P("data", "model"), "wo": P("model", "data")}
    return params, specs


def _rope(cfg: AttentionConfig, x, positions):
    if cfg.rope_type == "none" or positions is None:
        return x
    if cfg.rope_type == "mrope":
        return layers.apply_mrope(x, positions, cfg.rope_theta,
                                  cfg.mrope_sections)
    return layers.apply_rope(x, positions, cfg.rope_theta)


def _repeat_kv(cfg: AttentionConfig, x):
    if cfg.kv_repeat == 1:
        return x
    return jnp.repeat(x, cfg.kv_repeat, axis=2)


def _attend(cfg: AttentionConfig, q, k, v, mask, policy=None):
    """q: (B,S,N,H); k/v: (B,T,R,H); mask: (B,1,S,T) or None -> (B,S,N,H).

    Grouped-query attention: the N query heads are split into R groups.
    Softmax in fp32 (numerics), output cast back to q.dtype.
    """
    b, s, n, h = q.shape
    t, r = k.shape[1], k.shape[2]
    g = n // r
    # BLOCKED head grouping: q head index = r_idx * g + j, so kv-repeated
    # head r serves q heads [r*g, (r+1)*g).  Keeping r as the leading factor
    # of the reshape means a model-axis sharding of the N heads maps 1:1
    # onto the r axis of the scores — without this, GSPMD cannot shard the
    # score tensors and all-reduces them per q-chunk (measured 3-13 GB per
    # occurrence on nemotron-340b before the fix).
    q = q.reshape(b, s, r, g, h)
    scale = 1.0 / math.sqrt(h)
    logits = jnp.einsum("bsrgh,btrh->brgst", q, k) * scale
    logits = logits.astype(jnp.float32)
    if policy is not None:
        logits = policy.shard_scores(logits)
    if mask is not None:
        logits = jnp.where(mask[:, None, :, :, :] if mask.ndim == 4
                           else mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    if policy is not None:
        probs = policy.shard_scores(probs)
    out = jnp.einsum("brgst,btrh->bsrgh", probs, v)
    return out.reshape(b, s, n, h)


def _attend_q_chunked(cfg: AttentionConfig, q, k, v, q_chunk: int,
                      policy=None):
    """Causal/windowed self-attention scanned over query blocks.

    Never materialises the full (S x S) score matrix — per step the live
    score block is (B, heads, q_chunk, S), the memory-safe formulation for
    the 32k prefill cells (flash-style KV-streaming is the obvious further
    step; q-chunking alone already bounds live memory by 1/(S/q_chunk)).
    """
    b, s, n, h = q.shape
    nc = s // q_chunk
    qs = jnp.moveaxis(q.reshape(b, nc, q_chunk, n, h), 1, 0)

    def step(_, inp):
        i, qc = inp
        qpos = i * q_chunk + jnp.arange(q_chunk)
        kpos = jnp.arange(s)
        m = kpos[None, :] <= qpos[:, None]
        if cfg.window:
            m &= kpos[None, :] > qpos[:, None] - cfg.window
        out = _attend(cfg, qc, k, v, m[None, None], policy=policy)
        return None, out

    _, outs = jax.lax.scan(step, None,
                           (jnp.arange(nc, dtype=jnp.int32), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, n, h)


def causal_mask(s: int, t_offset: int = 0, window: int = 0):
    """(1, 1, S, S+t_offset) boolean mask; True = attend."""
    qpos = jnp.arange(s)[:, None] + t_offset
    kpos = jnp.arange(s + t_offset)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None]


def apply(params, cfg: AttentionConfig, x, positions=None, *,
          mask=None, kv=None, policy=None, use_flash: bool = False):
    """Full-sequence attention (training / prefill / encoder).

    kv: optional (keys_src, values_src) hidden states for cross-attention.
    use_flash: route self-attention through the in-VMEM flash kernel
    (forward-only — prefill/serving paths).
    Returns (out, (k_r, v_r)) — the repeated K/V for cache initialisation.
    """
    b, s, _ = x.shape
    n, r, h = cfg.n_heads, cfg.r_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, n, h)
    src = x if kv is None else kv
    k = (src @ params["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads, h)
    v = (src @ params["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads, h)
    if kv is None:                       # self-attention: rotary applies
        q = _rope(cfg, q, positions)
        k = _rope(cfg, k, positions)
    k = _repeat_kv(cfg, k)
    v = _repeat_kv(cfg, v)
    if policy is not None:
        q = policy.shard_heads(q)
        k = policy.shard_heads(k)
        v = policy.shard_heads(v)
    if use_flash and kv is None and mask is None and cfg.causal:
        if policy is not None:
            out = policy.run_sharded_flash(q, k, v, causal=True,
                                           window=cfg.window)
        else:
            from repro.kernels.flash_attention import flash_attention
            out = flash_attention(q, k, v, causal=True, window=cfg.window)
    elif mask is None and cfg.causal and kv is None and s > 2048 \
            and s % 1024 == 0:
        out = _attend_q_chunked(cfg, q, k, v, q_chunk=1024, policy=policy)
    else:
        if mask is None and cfg.causal and kv is None:
            mask = causal_mask(s, window=cfg.window)
        out = _attend(cfg, q, k, v, mask, policy=policy)
    out = out.reshape(b, s, n * h)
    return out @ params["wo"], KVCache(k=k, v=v)


def init_cache(cfg: AttentionConfig, batch: int, max_len: int, dtype
               ) -> KVCache:
    length = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, length, cfg.r_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def decode_step(params, cfg: AttentionConfig, x, cache: KVCache,
                t, positions=None, *, policy=None):
    """Single-token decode. x: (B, 1, D); t: scalar int32 current position.

    Returns (out, new_cache).  Sliding-window layers write into a ring
    buffer (slot = t mod window) and mask by recency.
    """
    b = x.shape[0]
    n, r, h = cfg.n_heads, cfg.r_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, 1, n, h)
    k = (x @ params["wk"]).reshape(b, 1, cfg.n_kv_heads, h)
    v = (x @ params["wv"]).reshape(b, 1, cfg.n_kv_heads, h)
    if positions is None:
        if cfg.rope_type == "mrope":
            positions = jnp.broadcast_to(t, (3, b, 1)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(t, (b, 1)).astype(jnp.int32)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    k = _repeat_kv(cfg, k)
    v = _repeat_kv(cfg, v)

    s_cache = cache.k.shape[1]
    slot = jnp.mod(t, s_cache) if cfg.window else t
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, slot, 0, 0))
    if policy is not None:
        new_k = policy.shard_cache(new_k)
        new_v = policy.shard_cache(new_v)

    kpos = jnp.arange(s_cache)
    if cfg.window:
        # ring buffer: valid if the stored position is within the window
        stored_pos = kpos + (t - slot).astype(kpos.dtype) \
            - jnp.where(kpos > slot, s_cache, 0)
        valid = (stored_pos >= 0) & (stored_pos <= t) & \
                (stored_pos > t - cfg.window)
    else:
        valid = kpos <= t
    mask = valid[None, None, None, :]    # (1,1,1,S_cache)
    out = _attend(cfg, q, new_k, new_v, mask, policy=policy)
    out = out.reshape(b, 1, n * h)
    return out @ params["wo"], KVCache(k=new_k, v=new_v)
