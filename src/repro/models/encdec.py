"""Whisper-style encoder-decoder (whisper-tiny backbone).

Per the assignment, the conv/mel audio frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, S_enc, d_model) directly to the
encoder.  Positions use fixed sinusoids (whisper's encoder does too; the
decoder's learned embedding is approximated with the same sinusoids — noted
in DESIGN.md §6).  LayerNorm + GELU + MHA (n_kv == n_heads), pre-norm.

Decode keeps two caches per decoder layer: a growing self-attention KV cache
and the static cross-attention KV computed once from the encoder output.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers


def sinusoids(length: int, channels: int):
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


@dataclasses.dataclass
class EncDecTransformer:
    cfg: ModelConfig
    policy: Any = None
    remat: bool = True

    def __post_init__(self):
        kvr = 1
        if self.policy is not None:
            kvr = self.policy.kv_repeat(self.cfg.n_kv_heads, self.cfg.n_heads)
        base = dict(d_model=self.cfg.d_model, n_heads=self.cfg.n_heads,
                    n_kv_heads=self.cfg.n_kv_heads,
                    head_dim=self.cfg.resolved_head_dim, rope_type="none",
                    kv_repeat=kvr)
        self.enc_attn = attention.AttentionConfig(causal=False, **base)
        self.dec_attn = attention.AttentionConfig(causal=True, **base)
        self.cross_attn = attention.AttentionConfig(causal=False, **base)

    # ---------------------------------------------------------------- init
    def _enc_layer_init(self, key, dtype):
        ks = jax.random.split(key, 2)
        p, s = {}, {}
        (p["ln1"], s["ln1"]), _ = layers.make_norm("layernorm",
                                                   self.cfg.d_model, dtype)
        p["attn"], s["attn"] = attention.init(ks[0], self.enc_attn, dtype)
        (p["ln2"], s["ln2"]), _ = layers.make_norm("layernorm",
                                                   self.cfg.d_model, dtype)
        p["mlp"], s["mlp"] = layers.mlp_init(ks[1], self.cfg.d_model,
                                             self.cfg.d_ff, "gelu", dtype)
        return p, s

    def _dec_layer_init(self, key, dtype):
        ks = jax.random.split(key, 3)
        p, s = {}, {}
        (p["ln1"], s["ln1"]), _ = layers.make_norm("layernorm",
                                                   self.cfg.d_model, dtype)
        p["self_attn"], s["self_attn"] = attention.init(ks[0], self.dec_attn,
                                                        dtype)
        (p["lnx"], s["lnx"]), _ = layers.make_norm("layernorm",
                                                   self.cfg.d_model, dtype)
        p["cross_attn"], s["cross_attn"] = attention.init(
            ks[1], self.cross_attn, dtype)
        (p["ln2"], s["ln2"]), _ = layers.make_norm("layernorm",
                                                   self.cfg.d_model, dtype)
        p["mlp"], s["mlp"] = layers.mlp_init(ks[2], self.cfg.d_model,
                                             self.cfg.d_ff, "gelu", dtype)
        return p, s

    def init(self, key):
        cfg = self.cfg
        dtype = cfg.param_dtype()
        n_enc = cfg.n_enc_layers
        keys = jax.random.split(key, n_enc + cfg.n_layers + 3)
        params: Dict[str, Any] = {"enc": [], "dec": []}
        specs: Dict[str, Any] = {"enc": [], "dec": []}
        for i in range(n_enc):
            p, s = self._enc_layer_init(keys[i], dtype)
            params["enc"].append(p)
            specs["enc"].append(s)
        for i in range(cfg.n_layers):
            p, s = self._dec_layer_init(keys[n_enc + i], dtype)
            params["dec"].append(p)
            specs["dec"].append(s)
        params["embed"], specs["embed"] = layers.embedding_init(
            keys[-1], cfg.padded_vocab, cfg.d_model, dtype, tied=True)
        (params["enc_ln"], specs["enc_ln"]), _ = layers.make_norm(
            "layernorm", cfg.d_model, dtype)
        (params["dec_ln"], specs["dec_ln"]), _ = layers.make_norm(
            "layernorm", cfg.d_model, dtype)
        return params, specs

    # -------------------------------------------------------------- encoder
    def encode(self, params, frames):
        """frames: (B, S_enc, D) stubbed audio embeddings."""
        x = frames.astype(self.cfg.param_dtype())
        x = x + sinusoids(x.shape[1], x.shape[2]).astype(x.dtype)[None]
        if self.policy is not None:
            x = self.policy.shard_activations(x)

        def layer(p, x):
            h = layers.layernorm(p["ln1"], x)
            mix, _ = attention.apply(p["attn"], self.enc_attn, h,
                                     positions=None, policy=self.policy)
            x = x + mix
            h2 = layers.layernorm(p["ln2"], x)
            x = x + layers.mlp_apply(p["mlp"], h2, "gelu")
            if self.policy is not None:
                x = self.policy.shard_activations(x)
            return x

        for p in params["enc"]:
            fn = layer
            if self.remat:
                fn = jax.checkpoint(
                    layer, policy=jax.checkpoint_policies.nothing_saveable)
            x = fn(p, x)
        return layers.layernorm(params["enc_ln"], x)

    # -------------------------------------------------------------- decoder
    def _dec_layer(self, p, x, enc_out, self_mask=None):
        h = layers.layernorm(p["ln1"], x)
        mix, _ = attention.apply(p["self_attn"], self.dec_attn, h,
                                 positions=None, mask=self_mask,
                                 policy=self.policy)
        x = x + mix
        hx = layers.layernorm(p["lnx"], x)
        cross, _ = attention.apply(p["cross_attn"], self.cross_attn, hx,
                                   positions=None, kv=enc_out,
                                   policy=self.policy)
        x = x + cross
        h2 = layers.layernorm(p["ln2"], x)
        x = x + layers.mlp_apply(p["mlp"], h2, "gelu")
        if self.policy is not None:
            x = self.policy.shard_activations(x)
        return x

    def decode_hidden(self, params, tokens, enc_out):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens, False, cfg.d_model)
        x = x + sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        for p in params["dec"]:
            fn = self._dec_layer
            if self.remat:
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable)
            x = fn(p, x, enc_out)
        return layers.layernorm(params["dec_ln"], x)

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        hidden = self.decode_hidden(params, batch["tokens"], enc_out)
        logits = layers.logits_from_hidden(hidden, params["embed"], None,
                                           tie=True,
                                           true_vocab=cfg.vocab_size)
        ce = layers.cross_entropy_loss(logits, batch["labels"], self.policy)
        return ce, {"ce_loss": ce}

    # ------------------------------------------------------ prefill / decode
    def prefill(self, params, frames, tokens, max_len: int):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        b, s = tokens.shape
        x = layers.embed(params["embed"], tokens, False, cfg.d_model)
        x = x + sinusoids(s, cfg.d_model).astype(x.dtype)[None]
        states = []
        for p in params["dec"]:
            h = layers.layernorm(p["ln1"], x)
            mix, kv = attention.apply(p["self_attn"], self.dec_attn, h,
                                      positions=None, policy=self.policy)
            pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
            kv = attention.KVCache(k=jnp.pad(kv.k, pad), v=jnp.pad(kv.v, pad))
            x = x + mix
            hx = layers.layernorm(p["lnx"], x)
            # cross K/V computed once and frozen for the whole decode
            src_k = (enc_out @ p["cross_attn"]["wk"]).reshape(
                b, enc_out.shape[1], cfg.n_kv_heads, self.cross_attn.head_dim)
            src_v = (enc_out @ p["cross_attn"]["wv"]).reshape(
                b, enc_out.shape[1], cfg.n_kv_heads, self.cross_attn.head_dim)
            src_k = attention._repeat_kv(self.cross_attn, src_k)
            src_v = attention._repeat_kv(self.cross_attn, src_v)
            cross, _ = attention.apply(p["cross_attn"], self.cross_attn, hx,
                                       positions=None, kv=enc_out,
                                       policy=self.policy)
            x = x + cross
            h2 = layers.layernorm(p["ln2"], x)
            x = x + layers.mlp_apply(p["mlp"], h2, "gelu")
            states.append({"self": kv,
                           "cross": attention.KVCache(k=src_k, v=src_v)})
        hidden = layers.layernorm(params["dec_ln"], x)
        logits = layers.logits_from_hidden(hidden[:, -1:], params["embed"],
                                           None, tie=True,
                                           true_vocab=cfg.vocab_size)
        return logits[:, 0], {"layers": states,
                              "t": jnp.full((), s, jnp.int32)}

    def decode_step(self, params, token, state):
        cfg = self.cfg
        t = state["t"]
        b = token.shape[0]
        x = layers.embed(params["embed"], token, False, cfg.d_model)
        # sinusoid at position t computed directly (no table materialisation)
        half = cfg.d_model // 2
        log_ts = math.log(10000.0) / (half - 1)
        inv = jnp.exp(-log_ts * jnp.arange(half, dtype=jnp.float32))
        ang = t.astype(jnp.float32) * inv
        pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
        x = x + pos.astype(x.dtype)
        new_states = []
        for p, st in zip(params["dec"], state["layers"]):
            h = layers.layernorm(p["ln1"], x)
            mix, new_kv = attention.decode_step(p["self_attn"], self.dec_attn,
                                                h, st["self"], t,
                                                positions=None,
                                                policy=self.policy)
            x = x + mix
            hx = layers.layernorm(p["lnx"], x)
            # cross-attention against the frozen encoder K/V
            q = (hx @ p["cross_attn"]["wq"]).reshape(
                b, 1, cfg.n_heads, self.cross_attn.head_dim)
            out = attention._attend(self.cross_attn, q, st["cross"].k,
                                    st["cross"].v, None)
            cross = out.reshape(b, 1, -1) @ p["cross_attn"]["wo"]
            x = x + cross
            h2 = layers.layernorm(p["ln2"], x)
            x = x + layers.mlp_apply(p["mlp"], h2, "gelu")
            new_states.append({"self": new_kv, "cross": st["cross"]})
        hidden = layers.layernorm(params["dec_ln"], x)
        logits = layers.logits_from_hidden(hidden, params["embed"], None,
                                           tie=True,
                                           true_vocab=cfg.vocab_size)
        return logits[:, 0], {"layers": new_states, "t": t + 1}
