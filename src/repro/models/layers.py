"""Shared neural building blocks (pure functional JAX).

Every ``*_init`` returns ``(params, specs)`` — a pytree of arrays and a
matching pytree of ``PartitionSpec`` leaves.  Sharding convention (DESIGN.md
§4): 2-D "FSDP x TP" — matmul weights are sharded on BOTH mesh axes,
('data' on the contraction/input dim, 'model' on the output/head dim, or
transposed for down-projections); vectors are replicated.  The 'pod' axis
never appears in parameter specs (pure-DP outer axis).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def truncnorm_init(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, spec: P,
               std: Optional[float] = None):
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    return truncnorm_init(key, (d_in, d_out), std, dtype), spec


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.zeros((d,), dtype)}, {"scale": P(None)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    nx = x32 * jax.lax.rsqrt(var + eps)
    return (nx * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype):
    return ({"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": P(None), "bias": P(None)})


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    nx = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = nx * (1.0 + params["scale"].astype(jnp.float32)) \
        + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def make_norm(norm_type: str, d: int, dtype):
    if norm_type == "rmsnorm":
        return rmsnorm_init(d, dtype), rmsnorm
    return layernorm_init(d, dtype), layernorm


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, N, H); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                   # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]):
    """Multimodal RoPE (Qwen2-VL §3): the rotary spectrum is split into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, N, H); positions: (3, B, S) int32 (t/h/w ids; text uses t=h=w).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                    # (half,)
    # choose which position stream drives each frequency band
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    pos = jnp.take(positions, sec_id, axis=0)                 # (half, B, S)
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

_ACTS = {
    "swiglu": jax.nn.silu,
    "geglu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def mlp_init(key, d: int, f: int, mlp_type: str, dtype):
    gated = mlp_type in ("swiglu", "geglu")
    ks = jax.random.split(key, 3)
    params, specs = {}, {}
    params["wi"], specs["wi"] = dense_init(ks[0], d, f, dtype, P("data", "model"))
    if gated:
        params["wg"], specs["wg"] = dense_init(ks[1], d, f, dtype,
                                               P("data", "model"))
    params["wo"], specs["wo"] = dense_init(ks[2], f, d, dtype,
                                           P("model", "data"))
    return params, specs


def mlp_apply(params, x, mlp_type: str):
    act = _ACTS[mlp_type]
    h = x @ params["wi"]
    if "wg" in params:
        h = act(x @ params["wg"]) * h
    else:
        h = act(h)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype, tied: bool = False):
    """Input embedding table (V, D).

    Untied tables shard D over BOTH mesh axes and keep V replicated-in-spec:
    the token gather then partitions trivially (no vocab-sharded gather, no
    table replication — decisive for the 256k x 18k tables).  Tied tables
    keep V on 'model' so the logits matmul stays vocab-sharded.
    """
    w = truncnorm_init(key, (vocab, d), 0.02, dtype)
    spec = P("model", "data") if tied else P(None, ("data", "model"))
    return {"embedding": w}, {"embedding": spec}


def embed(params, tokens, scale: bool, d: int):
    x = jnp.take(params["embedding"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(d), x.dtype)
    return x


def unembed_init(key, vocab: int, d: int, dtype):
    w = truncnorm_init(key, (d, vocab), 1.0 / math.sqrt(d), dtype)
    return {"unembedding": w}, {"unembedding": P("data", "model")}


def cross_entropy_loss(logits, labels, policy=None):
    """Masked CE over (B, S, V) fp32 logits; labels < 0 are masked.

    Written in the vocab-sharded formulation: the max / logsumexp reductions
    and the one-hot contraction all reduce over V locally + one all-reduce,
    so the (B, S, V) tensor never needs to be gathered (V stays sharded on
    the TP axis per policy.shard_logits).
    """
    if policy is not None:
        logits = policy.shard_logits(logits)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    m = jnp.max(logits, axis=-1)
    z = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
    if policy is not None:
        onehot = policy.shard_logits(onehot)
    true_logit = jnp.sum(logits * onehot, axis=-1)
    ll = true_logit - z
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def logits_from_hidden(x, emb_params, unemb_params, tie: bool,
                       softcap: float = 0.0, true_vocab: int = 0):
    if tie:
        w = emb_params["embedding"]          # (V_pad, D)
        logits = x @ w.T
    else:
        logits = x @ unemb_params["unembedding"]
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if true_vocab and true_vocab < logits.shape[-1]:
        pad_mask = jnp.arange(logits.shape[-1]) >= true_vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits
