"""Mamba-2 mixer via SSD (state-space duality), chunked-scan formulation.

Implements the SSD algorithm of Dao & Gu (arXiv:2405.21060): the sequence is
split into chunks of length Q; within a chunk the output is computed in its
"attention-like" dual form (quadratic in Q only), and a (H, P, N) recurrent
state is passed *between* chunks with a linear scan — giving O(S·Q) work and
O(S/Q) sequential depth.  Training/prefill use the chunked path; decode is
the O(1) recurrent update on a persistent fp32 state.

Scalar-A parameterisation (one decay per head), conv1d front, gated RMSNorm
and D skip as in the reference architecture.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SSMConfig
from repro.models import layers


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    conv_width: int
    chunk: int

    @staticmethod
    def from_config(d_model: int, cfg: SSMConfig) -> "SSMDims":
        d_inner = cfg.expand * d_model
        return SSMDims(d_model=d_model, d_inner=d_inner,
                       n_heads=d_inner // cfg.head_dim,
                       head_dim=cfg.head_dim, d_state=cfg.d_state,
                       conv_width=cfg.conv_width, chunk=cfg.chunk)


class SSMState(NamedTuple):
    state: jnp.ndarray       # (B, H, P, N) fp32
    conv: jnp.ndarray        # (B, conv_width - 1, conv_channels)


def init(key, dims: SSMDims, dtype):
    d, di, h, n = dims.d_model, dims.d_inner, dims.n_heads, dims.d_state
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 6)
    std = 1 / math.sqrt(d)
    params = {
        # fused input projection: [z, xBC, dt]
        "in_proj": layers.truncnorm_init(
            ks[0], (d, di + conv_ch + h), std, dtype),
        "conv_w": layers.truncnorm_init(
            ks[1], (dims.conv_width, conv_ch), 1 / math.sqrt(dims.conv_width),
            dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32)
                    * (math.log(0.1) - math.log(0.001)) + math.log(0.001)))),
        "norm": layers.rmsnorm_init(di, dtype)[0],
        "out_proj": layers.truncnorm_init(ks[3], (di, d),
                                          1 / math.sqrt(di), dtype),
    }
    specs = {
        "in_proj": P("data", "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "a_log": P(None), "d_skip": P(None), "dt_bias": P(None),
        "norm": {"scale": P(None)},
        "out_proj": P("model", "data"),
    }
    return params, specs


def _split(params, x, dims: SSMDims):
    di, h, n = dims.d_inner, dims.n_heads, dims.d_state
    conv_ch = di + 2 * n
    zxbcdt = x @ params["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + conv_ch]
    dt = zxbcdt[..., di + conv_ch:]
    return z, xbc, dt


def _conv(params, xbc, dims: SSMDims, conv_state=None):
    """Causal depthwise conv1d over (B, S, C)."""
    w = params["conv_w"].astype(xbc.dtype)                 # (W, C)
    pad = dims.conv_width - 1
    if conv_state is None:
        padded = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    else:
        padded = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = sum(padded[:, i:i + xbc.shape[1], :] * w[i]
              for i in range(dims.conv_width))
    out = out + params["conv_b"].astype(xbc.dtype)
    return jax.nn.silu(out), padded[:, -pad:, :]


def _ssd_chunked(xh, dt, bmat, cmat, a, dims: SSMDims, init_state=None):
    """Chunked SSD. xh: (B,S,H,P); dt: (B,S,H) fp32; bmat/cmat: (B,S,N);
    a: (H,) negative decay rates. Returns (y, final_state)."""
    b, s_orig, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(dims.chunk, s_orig)
    pad = (-s_orig) % q
    if pad:
        # zero-pad to a chunk multiple: padded steps carry dt=0, so they
        # neither update the state nor contribute to real outputs
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q

    xq = xh.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtq = dt.reshape(b, nc, q, h)
    bq = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cq = cmat.reshape(b, nc, q, n).astype(jnp.float32)

    da = dtq * a[None, None, None, :]                       # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(da, axis=2)                            # within-chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (dual / attention-like form)
    scores = jnp.einsum("bcin,bcjn->bcij", cq, bq)          # (B,nc,Q,Q)
    wdt = l_mat * dtq[:, :, None, :, :]                     # decay * dt_j
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, wdt, xq)

    # per-chunk contribution to the recurrent state
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    wstate = (decay_to_end * dtq)                           # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bq, wstate, xq)

    # inter-chunk scan over nc
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))              # (B,nc,H)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        dec, cs = inp
        new = carry * dec[:, :, None, None] + cs
        return new, carry                                   # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(chunk_states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    # inter-chunk (state -> outputs)
    state_decay = jnp.exp(cum)                              # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cq, state_decay,
                         prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y, final


def apply(params, x, dims: SSMDims, policy=None,
          init_state: SSMState = None) -> Tuple[jnp.ndarray, SSMState]:
    """Full-sequence mixer. x: (B,S,D) -> (out, final_state)."""
    bsz, s, _ = x.shape
    h, p, n = dims.n_heads, dims.head_dim, dims.d_state
    z, xbc, dt = _split(params, x, dims)
    xbc, conv_tail = _conv(params, xbc, dims,
                           None if init_state is None else init_state.conv)
    xh = xbc[..., :dims.d_inner].reshape(bsz, s, h, p)
    bmat = xbc[..., dims.d_inner:dims.d_inner + n]
    cmat = xbc[..., dims.d_inner + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"])
    y, final = _ssd_chunked(
        xh, dt, bmat, cmat, a, dims,
        None if init_state is None else init_state.state)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, dims.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm(params["norm"], y)
    return y @ params["out_proj"], SSMState(state=final, conv=conv_tail)


def init_state(dims: SSMDims, batch: int, dtype) -> SSMState:
    conv_ch = dims.d_inner + 2 * dims.d_state
    return SSMState(
        state=jnp.zeros((batch, dims.n_heads, dims.head_dim, dims.d_state),
                        jnp.float32),
        conv=jnp.zeros((batch, dims.conv_width - 1, conv_ch), dtype))


def decode_step(params, x, dims: SSMDims, st: SSMState
                ) -> Tuple[jnp.ndarray, SSMState]:
    """Single-token recurrent update. x: (B,1,D)."""
    bsz = x.shape[0]
    h, p, n = dims.n_heads, dims.head_dim, dims.d_state
    z, xbc, dt = _split(params, x, dims)
    xbc, conv_tail = _conv(params, xbc, dims, st.conv)
    xh = xbc[..., :dims.d_inner].reshape(bsz, h, p).astype(jnp.float32)
    bmat = xbc[..., dims.d_inner:dims.d_inner + n].reshape(bsz, n)
    cmat = xbc[..., dims.d_inner + n:].reshape(bsz, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a[None, :])                        # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bmat.astype(jnp.float32))
    new_state = st.state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat.astype(jnp.float32), new_state)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, dims.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm(params["norm"], y)
    return y @ params["out_proj"], SSMState(state=new_state, conv=conv_tail)
