"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure: two parallel projections of the residual stream; one passes
through a short causal conv1d and the Real-Gated Linear Recurrent Unit, the
other is a GeLU gate; their product is projected back to d_model.

RG-LRU recurrence (fp32):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(c * r_t * log_a)            log_a = -8 * softplus(lambda) <= 0
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluate the recurrence with an associative scan
(O(log S) depth); decode is the O(1) update.  Sub-quadratic by construction
— this mixer plus windowed attention is what qualifies recurrentgemma for
the long_500k cell.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RGLRUConfig
from repro.models import layers

_C = 8.0


class RGLRUState(NamedTuple):
    h: jnp.ndarray           # (B, W) fp32 recurrent state
    conv: jnp.ndarray        # (B, conv_width - 1, W)


def init(key, d_model: int, width: int, cfg: RGLRUConfig, dtype):
    ks = jax.random.split(key, 7)
    std = 1 / math.sqrt(d_model)
    stdw = 1 / math.sqrt(width)
    params = {
        "in_x": layers.truncnorm_init(ks[0], (d_model, width), std, dtype),
        "in_gate": layers.truncnorm_init(ks[1], (d_model, width), std, dtype),
        "conv_w": layers.truncnorm_init(ks[2], (cfg.conv_width, width),
                                        1 / math.sqrt(cfg.conv_width), dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": layers.truncnorm_init(ks[3], (width, width), stdw, dtype),
        "b_a": jnp.zeros((width,), jnp.float32),
        "w_i": layers.truncnorm_init(ks[4], (width, width), stdw, dtype),
        "b_i": jnp.zeros((width,), jnp.float32),
        # init so that a^c spans ~(0.9, 0.999): lambda via inverse softplus
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.9, 0.999, width) ** -(1.0 / _C) - 1.0 + 1e-8)
        ).astype(jnp.float32),
        "out": layers.truncnorm_init(ks[5], (width, d_model), stdw, dtype),
    }
    specs = {
        "in_x": P("data", "model"), "in_gate": P("data", "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "w_a": P("data", "model"), "b_a": P(None),
        "w_i": P("data", "model"), "b_i": P(None),
        "lam": P(None),
        "out": P("model", "data"),
    }
    return params, specs


def _conv(params, x, conv_width: int, conv_state=None):
    w = params["conv_w"].astype(x.dtype)
    pad = conv_width - 1
    if conv_state is None:
        padded = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    else:
        padded = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(padded[:, i:i + x.shape[1], :] * w[i] for i in range(conv_width))
    return out + params["conv_b"].astype(x.dtype), padded[:, -pad:, :]


def _gates(params, xw):
    """xw: (..., W) conv output -> (a_t, gated input) in fp32."""
    x32 = xw.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ params["w_a"].astype(jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid(x32 @ params["w_i"].astype(jnp.float32)
                       + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"])            # (W,) <= 0
    a = jnp.exp(r * log_a[None, ...])
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x32)
    return a, gated


def apply(params, x, width: int, cfg: RGLRUConfig, policy=None,
          init_state: RGLRUState = None) -> Tuple[jnp.ndarray, RGLRUState]:
    """Full-sequence block. x: (B,S,D) -> (out, final_state)."""
    xb = x @ params["in_x"]
    gate = jax.nn.gelu(x @ params["in_gate"], approximate=True)
    xw, conv_tail = _conv(params, xb, cfg.conv_width,
                          None if init_state is None else init_state.conv)
    a, gated = _gates(params, xw)                           # (B,S,W) fp32

    if init_state is not None:
        # fold h0 in by treating it as an extra leading element
        gated = gated.at[:, 0, :].add(a[:, 0, :] * init_state.h)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    final = RGLRUState(h=h[:, -1, :], conv=conv_tail)
    y = h.astype(x.dtype) * gate
    return y @ params["out"], final


def init_state(width: int, cfg: RGLRUConfig, batch: int, dtype) -> RGLRUState:
    return RGLRUState(h=jnp.zeros((batch, width), jnp.float32),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, width),
                                     dtype))


def decode_step(params, x, width: int, cfg: RGLRUConfig, st: RGLRUState
                ) -> Tuple[jnp.ndarray, RGLRUState]:
    """Single-token update. x: (B,1,D)."""
    xb = x @ params["in_x"]
    gate = jax.nn.gelu(x @ params["in_gate"], approximate=True)
    xw, conv_tail = _conv(params, xb, cfg.conv_width, st.conv)
    a, gated = _gates(params, xw[:, 0, :])
    h = a * st.h + gated
    y = h[:, None, :].astype(x.dtype) * gate
    return y @ params["out"], RGLRUState(h=h, conv=conv_tail)
