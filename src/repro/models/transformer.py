"""Decoder-only transformer trunk: the shared substrate for 9 of the 10
assigned architectures (whisper's encoder-decoder wraps it in encdec.py).

Layer mixers are pluggable per ModelConfig.layer_kind(i): attention (dense /
GQA / MQA, global or windowed), Mamba-2 SSD, or RG-LRU.  FFNs are dense MLPs
or sort-routed MoE.  Homogeneous layer stacks are executed with
``lax.scan`` over stacked parameters (one layer's HLO regardless of depth —
essential for the 95/96-layer dry-runs) wrapped in ``jax.checkpoint`` so
only the residual stream is saved per layer; heterogeneous stacks (hybrid
patterns, leading dense-MoE layers) unroll.

Decode threads per-layer recurrent state (KV cache / SSM state / RG-LRU
state) through the same scan.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe, rglru, ssm


def _layer_signature(cfg: ModelConfig, i: int) -> Tuple[str, bool]:
    has_moe = (cfg.moe is not None and i >= cfg.moe.first_dense_layers)
    return (cfg.layer_kind(i), has_moe)


def _attn_config(cfg: ModelConfig, kv_repeat: int) -> attention.AttentionConfig:
    return attention.AttentionConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_type=cfg.rope_type,
        rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
        causal=True, window=cfg.window, kv_repeat=kv_repeat)


@dataclasses.dataclass
class Transformer:
    cfg: ModelConfig
    policy: Any = None               # ShardingPolicy or None
    remat: bool = True

    # ------------------------------------------------------------------ init
    def __post_init__(self):
        kvr = 1
        if self.policy is not None:
            kvr = self.policy.kv_repeat(self.cfg.n_kv_heads, self.cfg.n_heads)
        self.attn_cfg = _attn_config(self.cfg, kvr)
        if self.cfg.ssm is not None:
            self.ssm_dims = ssm.SSMDims.from_config(self.cfg.d_model,
                                                    self.cfg.ssm)
        self.rglru_width = (0 if self.cfg.rglru is None else
                            (self.cfg.rglru.lru_width or self.cfg.d_model))
        sigs = [_layer_signature(self.cfg, i) for i in range(self.cfg.n_layers)]
        first = self.cfg.moe.first_dense_layers if self.cfg.moe else 0
        body = sigs[first:]
        self.scan_body = len(set(body)) == 1 and len(body) > 1
        self.n_prefix = first if self.scan_body else (
            0 if len(set(sigs)) == 1 and len(sigs) > 1 else self.cfg.n_layers)
        if len(set(sigs)) == 1 and len(sigs) > 1:
            self.scan_body, self.n_prefix = True, 0
        self.n_body = self.cfg.n_layers - self.n_prefix

    # -------------------------------------------------------- layer (single)
    def _init_layer(self, key, i: int):
        cfg = self.cfg
        kind, has_moe = _layer_signature(cfg, i)
        ks = jax.random.split(key, 4)
        dtype = cfg.param_dtype()
        params: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}
        norm_init, _ = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        params["ln1"], specs["ln1"] = norm_init
        norm_init2, _ = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        params["ln2"], specs["ln2"] = norm_init2
        if kind == "attn":
            params["mixer"], specs["mixer"] = attention.init(
                ks[0], self.attn_cfg, dtype)
        elif kind == "ssm":
            params["mixer"], specs["mixer"] = ssm.init(ks[0], self.ssm_dims,
                                                       dtype)
        else:
            params["mixer"], specs["mixer"] = rglru.init(
                ks[0], cfg.d_model, self.rglru_width, cfg.rglru, dtype)
        if kind == "ssm":
            params.pop("ln2")
            specs.pop("ln2")          # mamba blocks: single norm per layer
        elif has_moe:
            params["ffn"], specs["ffn"] = moe.init(
                ks[1], cfg.d_model, cfg.moe, cfg.mlp_type, dtype)
        else:
            params["ffn"], specs["ffn"] = layers.mlp_init(
                ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
        return params, specs

    def init(self, key) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        dtype = cfg.param_dtype()
        keys = jax.random.split(key, cfg.n_layers + 3)
        params: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}
        params["embed"], specs["embed"] = layers.embedding_init(
            keys[-1], cfg.padded_vocab, cfg.d_model, dtype,
            tied=cfg.tie_embeddings)
        if not cfg.tie_embeddings:
            params["unembed"], specs["unembed"] = layers.unembed_init(
                keys[-2], cfg.padded_vocab, cfg.d_model, dtype)
        norm_init, _ = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        params["final_ln"], specs["final_ln"] = norm_init

        prefix_p, prefix_s = [], []
        for i in range(self.n_prefix):
            p, s = self._init_layer(keys[i], i)
            prefix_p.append(p)
            prefix_s.append(s)
        params["prefix"], specs["prefix"] = prefix_p, prefix_s

        if self.scan_body:
            body_keys = jnp.stack(keys[self.n_prefix:cfg.n_layers])
            stacked = jax.vmap(
                lambda k: self._init_layer(k, self.n_prefix)[0])(body_keys)
            _, s = self._init_layer(keys[self.n_prefix], self.n_prefix)
            params["body"] = stacked
            specs["body"] = jax.tree.map(
                lambda spec: P(*((None,) + tuple(spec))), s,
                is_leaf=lambda x: isinstance(x, P))
        else:
            params["body"], specs["body"] = {}, {}
        return params, specs

    # ------------------------------------------------------------- forwards
    def _layer_fwd(self, lp, x, i: int, positions, aux):
        cfg = self.cfg
        kind, has_moe = _layer_signature(cfg, i)
        norm = layers.rmsnorm if cfg.norm_type == "rmsnorm" else layers.layernorm
        pol = self.policy
        h = norm(lp["ln1"], x)
        if pol is not None:
            h = pol.sp_gather(h)           # SP: gather seq once per block
        if kind == "attn":
            mix, _ = attention.apply(lp["mixer"], self.attn_cfg, h, positions,
                                     policy=pol)
        elif kind == "ssm":
            mix, _ = ssm.apply(lp["mixer"], h, self.ssm_dims, policy=pol)
        else:
            mix, _ = rglru.apply(lp["mixer"], h, self.rglru_width, cfg.rglru,
                                 policy=pol)
        if pol is not None:
            mix = pol.sp_scatter(mix)      # SP: TP partial-sum -> RS
        x = x + mix
        if kind != "ssm":
            h2 = norm(lp["ln2"], x)
            if pol is not None:
                h2 = pol.sp_gather(h2)
            if has_moe:
                f, moe_aux = moe.apply(lp["ffn"], h2, cfg.moe, cfg.mlp_type,
                                       pol)
                aux = {k: aux.get(k, 0.0) + v for k, v in moe_aux.items()}
            else:
                f = layers.mlp_apply(lp["ffn"], h2, cfg.mlp_type)
            if pol is not None:
                f = pol.sp_scatter(f)
            x = x + f
        if pol is not None:
            x = pol.shard_activations(x)
        return x, aux

    def hidden_states(self, params, tokens, positions=None,
                      vision_embeds=None):
        """Token ids -> final hidden states (B, S, D)."""
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens, cfg.emb_scale, cfg.d_model)
        if vision_embeds is not None and cfg.vision_prefix:
            sv = cfg.vision_prefix
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, sv:]],
                                axis=1)
        if positions is None:
            positions = self._default_positions(tokens)
        if self.policy is not None:
            x = self.policy.shard_activations(x)

        aux: Dict[str, jnp.ndarray] = {}
        for i, lp in enumerate(params["prefix"]):
            fwd = functools.partial(self._layer_fwd, i=i, positions=positions)
            if self.remat:
                fwd = jax.checkpoint(
                    fwd, policy=jax.checkpoint_policies.nothing_saveable)
            x, aux = fwd(lp, x, aux=aux)

        if self.scan_body:
            i0 = self.n_prefix

            def body(carry, lp):
                xc, auxc = carry
                xn, auxn = self._layer_fwd(lp, xc, i=i0, positions=positions,
                                           aux=auxc)
                return (xn, auxn), None

            if self.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            aux0 = dict(aux)
            if self.cfg.moe is not None:
                aux0.setdefault("moe_lb_loss", jnp.zeros((), jnp.float32))
                aux0.setdefault("moe_z_loss", jnp.zeros((), jnp.float32))
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["body"])

        norm = layers.rmsnorm if cfg.norm_type == "rmsnorm" else layers.layernorm
        x = norm(params["final_ln"], x)
        return x, aux

    def logits(self, params, hidden):
        cfg = self.cfg
        return layers.logits_from_hidden(
            hidden, params["embed"], params.get("unembed"),
            cfg.tie_embeddings, cfg.logits_softcap,
            true_vocab=cfg.vocab_size)

    def _default_positions(self, tokens):
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if self.cfg.rope_type == "mrope":
            return jnp.broadcast_to(pos, (3, b, s))
        return pos

    # ------------------------------------------------------------- training
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        """batch: {tokens, labels, (positions), (vision_embeds)}; labels are
        next-token ids with -100 = masked."""
        hidden, aux = self.hidden_states(
            params, batch["tokens"], batch.get("positions"),
            batch.get("vision_embeds"))
        logits = self.logits(params, hidden)
        ce = layers.cross_entropy_loss(logits, batch["labels"], self.policy)
        total = ce
        if self.cfg.moe is not None:
            total = total + 0.01 * aux.get("moe_lb_loss", 0.0) \
                + 1e-3 * aux.get("moe_z_loss", 0.0)
        aux = dict(aux)
        aux["ce_loss"] = ce
        return total, aux

    # ------------------------------------------------------ prefill / decode
    def _init_layer_state(self, i: int, batch: int, max_len: int):
        kind, _ = _layer_signature(self.cfg, i)
        dtype = self.cfg.param_dtype()
        if kind == "attn":
            return attention.init_cache(self.attn_cfg, batch, max_len, dtype)
        if kind == "ssm":
            return ssm.init_state(self.ssm_dims, batch, dtype)
        return rglru.init_state(self.rglru_width, self.cfg.rglru, batch, dtype)

    def init_state(self, batch: int, max_len: int):
        prefix = [self._init_layer_state(i, batch, max_len)
                  for i in range(self.n_prefix)]
        body = None
        if self.scan_body:
            one = self._init_layer_state(self.n_prefix, batch, max_len)
            body = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (self.n_body,) + a.shape),
                one)
        return {"prefix": prefix, "body": body,
                "t": jnp.zeros((), jnp.int32)}

    def _layer_decode(self, lp, x, state, i: int, t):
        kind, has_moe = _layer_signature(self.cfg, i)
        cfg = self.cfg
        norm = layers.rmsnorm if cfg.norm_type == "rmsnorm" else layers.layernorm
        h = norm(lp["ln1"], x)
        if kind == "attn":
            mix, new_state = attention.decode_step(
                lp["mixer"], self.attn_cfg, h, state, t, policy=self.policy)
        elif kind == "ssm":
            mix, new_state = ssm.decode_step(lp["mixer"], h, self.ssm_dims,
                                             state)
        else:
            mix, new_state = rglru.decode_step(lp["mixer"], h,
                                               self.rglru_width, cfg.rglru,
                                               state)
        x = x + mix
        if kind != "ssm":
            h2 = norm(lp["ln2"], x)
            if has_moe:
                f, _ = moe.apply(lp["ffn"], h2, cfg.moe, cfg.mlp_type,
                                 self.policy)
            else:
                f = layers.mlp_apply(lp["ffn"], h2, cfg.mlp_type)
            x = x + f
        return x, new_state

    def decode_step(self, params, token, state):
        """One decode step. token: (B, 1) int32. Returns (logits, state)."""
        cfg = self.cfg
        t = state["t"]
        x = layers.embed(params["embed"], token, cfg.emb_scale, cfg.d_model)
        new_prefix = []
        for i, (lp, st) in enumerate(zip(params["prefix"], state["prefix"])):
            x, ns = self._layer_decode(lp, x, st, i, t)
            new_prefix.append(ns)
        new_body = state["body"]
        if self.scan_body:
            i0 = self.n_prefix

            def body(carry, lp_st):
                lp, st = lp_st
                xn, ns = self._layer_decode(lp, carry, st, i0, t)
                return xn, ns

            x, new_body = jax.lax.scan(body, x, (params["body"],
                                                 state["body"]))
        norm = layers.rmsnorm if cfg.norm_type == "rmsnorm" else layers.layernorm
        hidden = norm(params["final_ln"], x)
        logits = self.logits(params, hidden)
        new_state = {"prefix": new_prefix, "body": new_body, "t": t + 1}
        return logits[:, 0], new_state

    def prefill(self, params, tokens, max_len: int, positions=None,
                vision_embeds=None):
        """Run the full prompt, build decode state, return last logits.

        Attention layers re-run their projections to fill the cache at the
        right layout; recurrent layers get their final states from the
        sequence pass.
        """
        cfg = self.cfg
        b, s = tokens.shape
        x = layers.embed(params["embed"], tokens, cfg.emb_scale, cfg.d_model)
        if vision_embeds is not None and cfg.vision_prefix:
            sv = cfg.vision_prefix
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, sv:]],
                                axis=1)
        if positions is None:
            positions = self._default_positions(tokens)
        if self.policy is not None:
            x = self.policy.shard_activations(x)
        norm = layers.rmsnorm if cfg.norm_type == "rmsnorm" else layers.layernorm

        def layer_prefill(lp, x, i):
            kind, has_moe = _layer_signature(cfg, i)
            h = norm(lp["ln1"], x)
            if kind == "attn":
                mix, kv = attention.apply(lp["mixer"], self.attn_cfg, h,
                                          positions, policy=self.policy,
                                          use_flash=cfg.flash_prefill)
                st = self._pad_cache(kv, max_len)
            elif kind == "ssm":
                mix, st = ssm.apply(lp["mixer"], h, self.ssm_dims,
                                    policy=self.policy)
            else:
                mix, st = rglru.apply(lp["mixer"], h, self.rglru_width,
                                      cfg.rglru, policy=self.policy)
            x = x + mix
            if kind != "ssm":
                h2 = norm(lp["ln2"], x)
                if has_moe:
                    f, _ = moe.apply(lp["ffn"], h2, cfg.moe, cfg.mlp_type,
                                     self.policy)
                else:
                    f = layers.mlp_apply(lp["ffn"], h2, cfg.mlp_type)
                x = x + f
            if self.policy is not None:
                x = self.policy.shard_activations(x)
            return x, st

        states_prefix = []
        for i, lp in enumerate(params["prefix"]):
            x, st = layer_prefill(lp, x, i)
            states_prefix.append(st)
        body_states = None
        if self.scan_body:
            i0 = self.n_prefix

            def body(carry, lp):
                xn, st = layer_prefill(lp, carry, i0)
                return xn, st

            x, body_states = jax.lax.scan(body, x, params["body"])
        hidden = norm(params["final_ln"], x)
        logits = self.logits(params, hidden[:, -1:, :])
        state = {"prefix": states_prefix, "body": body_states,
                 "t": jnp.full((), s, jnp.int32)}
        return logits[:, 0], state

    def _pad_cache(self, kv: attention.KVCache, max_len: int):
        s = kv.k.shape[1]
        cap = min(max_len, self.attn_cfg.window) if self.attn_cfg.window \
            else max_len
        if s == cap:
            return kv
        if s > cap:
            # windowed layer: keep the last `cap` positions, rolled so that
            # stored position p sits at ring slot p % cap (decode layout)
            k = jnp.roll(kv.k[:, -cap:], (s - cap) % cap, axis=1)
            v = jnp.roll(kv.v[:, -cap:], (s - cap) % cap, axis=1)
            return attention.KVCache(k=k, v=v)
        pad = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
        return attention.KVCache(k=jnp.pad(kv.k, pad), v=jnp.pad(kv.v, pad))
