"""Deterministic, shardable, resumable synthetic token pipeline.

Production posture without external data: batches are generated from a
counter-based PRNG (threefry over (seed, step, shard)), so

  * every host materialises ONLY its shard (data-parallel loading),
  * any step's batch is reproducible from (seed, step) alone — checkpoint
    resume needs no iterator state beyond the step counter,
  * elastic restarts with a different dp-degree re-slice the same global
    batch (the global sample order is invariant to the host count).

The synthetic distribution is a mixture of Zipfian unigrams and short
repeated motifs, so cross-entropy has learnable structure (motif copying)
— enough signal for examples/train_lm.py to show a falling loss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    motif_len: int = 16
    n_motifs: int = 64
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank (part of the dataset definition, not the stream)
        self.motifs = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len),
            dtype=np.int32)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.zipf_p = (p / p.sum()).astype(np.float64)

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The full (global_batch, seq_len) batch for a step — deterministic
        in (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s), p=self.zipf_p
                          ).astype(np.int32)
        # plant motifs: ~25% of positions covered by repeated motifs
        n_plant = max(1, (b * s) // (4 * cfg.motif_len))
        rows = rng.integers(0, b, n_plant)
        offs = rng.integers(0, max(1, s - cfg.motif_len), n_plant)
        ids = rng.integers(0, cfg.n_motifs, n_plant)
        for r, o, m in zip(rows, offs, ids):
            toks[r, o:o + cfg.motif_len] = self.motifs[m]
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), -100,
                                                      np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}

    def shard_at(self, step: int, shard: int, n_shards: int
                 ) -> Dict[str, np.ndarray]:
        """This host's slice of the step's global batch.

        Shard-layout mistakes are launcher *configuration* errors, so they
        raise ``ValueError`` with the offending numbers (a bare ``assert``
        would vanish under ``python -O`` and read as a raw tuple).
        """
        b = self.cfg.global_batch
        if n_shards < 1 or b % n_shards != 0:
            raise ValueError(
                f"global_batch={b} is not divisible into n_shards="
                f"{n_shards} equal host shards; adjust the dp degree or "
                f"the batch size")
        if not 0 <= shard < n_shards:
            raise ValueError(
                f"shard index {shard} out of range for n_shards={n_shards}")
        g = self.global_batch_at(step)
        lo = (b // n_shards) * shard
        hi = lo + b // n_shards
        return {k: v[lo:hi] for k, v in g.items()}

    def iterate(self, start_step: int = 0, shard: int = 0,
                n_shards: int = 1, dedup: bool = False
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Batch stream; ``dedup=True`` drops duplicate token rows within
        each shard batch (motif planting repeats rows at small seq_len), so
        the batch dimension can shrink step to step."""
        step = start_step
        while True:
            batch = self.shard_at(step, shard, n_shards)
            if dedup:
                keep = dedup_rows(batch["tokens"])
                batch = {k: v[keep] for k, v in batch.items()}
            yield batch
            step += 1


def row_fingerprints(tokens: np.ndarray) -> np.ndarray:
    """uint32 polynomial hash of each token row (multiplier 1000003,
    modular): equal rows always share a fingerprint, so dedup over
    fingerprints is dedup over rows (up to a ~b^2/2^33 collision risk the
    synthetic stream doesn't approach)."""
    t = np.ascontiguousarray(tokens).astype(np.uint32)
    s = t.shape[-1]
    pows = np.empty((s,), np.uint32)
    acc = 1
    for i in range(s - 1, -1, -1):
        pows[i] = acc
        acc = (acc * 1000003) % (1 << 32)
    return (t * pows).sum(axis=-1, dtype=np.uint32)


def _keep_first_distinct(tokens: np.ndarray, group: np.ndarray,
                         keep: np.ndarray) -> None:
    """Within one fingerprint group (ascending original positions), mark the
    first occurrence of each DISTINCT token row.  Fingerprint equality is
    necessary but not sufficient — two different rows can collide — so a
    row is only dropped after a full ``np.array_equal`` against a kept
    member of its group.  Groups are almost always singletons or true
    duplicates, so the quadratic inner walk touches a handful of rows."""
    if group.shape[0] == 1:
        keep[group[0]] = True
        return
    kept: list = []
    for gi in group:
        gi = int(gi)
        if not any(np.array_equal(tokens[gi], tokens[kj]) for kj in kept):
            keep[gi] = True
            kept.append(gi)


def _first_occurrence_mask(tokens: np.ndarray, sorted_groups: np.ndarray,
                           sorted_pos: np.ndarray) -> np.ndarray:
    """Keep-mask from a fingerprint column already sorted into groups.
    ``sorted_groups[i]`` is the group key at sorted rank i and
    ``sorted_pos[i]`` the row's original position (ascending within a group
    — the sort must be stable)."""
    n = sorted_pos.shape[0]
    keep = np.zeros((n,), bool)
    bounds = np.flatnonzero(
        np.r_[True, sorted_groups[1:] != sorted_groups[:-1], True])
    for s, e in zip(bounds[:-1], bounds[1:]):
        _keep_first_distinct(tokens, sorted_pos[s:e], keep)
    return keep


def dedup_rows(tokens: np.ndarray) -> np.ndarray:
    """Keep-mask selecting the FIRST occurrence of each distinct token row.

    The fingerprint column goes through ``relational.unique`` (sort-based
    dedup — the subsystem's canonical workload) to find candidate duplicate
    groups; rows inside a group are then verified byte-for-byte before any
    is dropped.  Fingerprints alone are NOT a dedup key: the uint32 hash
    collides for crafted (and, at scale, eventually natural) row pairs, and
    dropping on hash equality alone silently loses data.
    """
    import jax.numpy as jnp

    from repro import relational
    tokens = np.asarray(tokens)
    h = row_fingerprints(tokens)
    n = h.shape[0]
    if n == 0:
        return np.zeros((0,), bool)
    u = relational.unique(jnp.asarray(h), return_inverse=True)
    inv = np.asarray(u.inverse)
    order = np.argsort(inv, kind="stable").astype(np.int64)
    return _first_occurrence_mask(tokens, inv[order], order)


def global_dedup(tokens: np.ndarray, *, chunk_bytes: int = None
                 ) -> np.ndarray:
    """Dataset-scale first-occurrence keep-mask over the spill tier.

    Same contract as :func:`dedup_rows`, but the fingerprint column is
    sorted out-of-core (``engine.spill.spill_sort_kv`` carrying original
    row positions), so only one device-sized chunk of fingerprints is
    resident at a time — the grouping scales to corpora whose fingerprint
    column alone exceeds device memory.  The kv spill path is stable, so
    positions within a fingerprint group come back ascending and the
    first-occurrence/collision-verification walk is shared with
    ``dedup_rows``.  ``chunk_bytes`` forces a chunk size (testing); the
    default comes from the active tuning profile's spill threshold.
    """
    from repro.engine import spill
    tokens = np.asarray(tokens)
    n = tokens.shape[0]
    if n == 0:
        return np.zeros((0,), bool)
    h = row_fingerprints(tokens)
    pos = np.arange(n, dtype=np.int32)
    sh, sp = spill.spill_sort_kv(h, pos, chunk_bytes=chunk_bytes)
    return _first_occurrence_mask(tokens, np.asarray(sh),
                                  np.asarray(sp).astype(np.int64))


def device_put_batch(batch: Dict[str, np.ndarray], mesh, dp_axes):
    """Place a (host-local or global) numpy batch onto the mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(dp_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
