"""Deterministic, shardable, resumable synthetic token pipeline.

Production posture without external data: batches are generated from a
counter-based PRNG (threefry over (seed, step, shard)), so

  * every host materialises ONLY its shard (data-parallel loading),
  * any step's batch is reproducible from (seed, step) alone — checkpoint
    resume needs no iterator state beyond the step counter,
  * elastic restarts with a different dp-degree re-slice the same global
    batch (the global sample order is invariant to the host count).

The synthetic distribution is a mixture of Zipfian unigrams and short
repeated motifs, so cross-entropy has learnable structure (motif copying)
— enough signal for examples/train_lm.py to show a falling loss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    motif_len: int = 16
    n_motifs: int = 64
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank (part of the dataset definition, not the stream)
        self.motifs = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len),
            dtype=np.int32)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.zipf_p = (p / p.sum()).astype(np.float64)

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The full (global_batch, seq_len) batch for a step — deterministic
        in (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s), p=self.zipf_p
                          ).astype(np.int32)
        # plant motifs: ~25% of positions covered by repeated motifs
        n_plant = max(1, (b * s) // (4 * cfg.motif_len))
        rows = rng.integers(0, b, n_plant)
        offs = rng.integers(0, max(1, s - cfg.motif_len), n_plant)
        ids = rng.integers(0, cfg.n_motifs, n_plant)
        for r, o, m in zip(rows, offs, ids):
            toks[r, o:o + cfg.motif_len] = self.motifs[m]
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), -100,
                                                      np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}

    def shard_at(self, step: int, shard: int, n_shards: int
                 ) -> Dict[str, np.ndarray]:
        """This host's slice of the step's global batch."""
        g = self.global_batch_at(step)
        b = self.cfg.global_batch
        assert b % n_shards == 0, (b, n_shards)
        lo = (b // n_shards) * shard
        hi = lo + b // n_shards
        return {k: v[lo:hi] for k, v in g.items()}

    def iterate(self, start_step: int = 0, shard: int = 0,
                n_shards: int = 1, dedup: bool = False
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Batch stream; ``dedup=True`` drops duplicate token rows within
        each shard batch (motif planting repeats rows at small seq_len), so
        the batch dimension can shrink step to step."""
        step = start_step
        while True:
            batch = self.shard_at(step, shard, n_shards)
            if dedup:
                keep = dedup_rows(batch["tokens"])
                batch = {k: v[keep] for k, v in batch.items()}
            yield batch
            step += 1


def row_fingerprints(tokens: np.ndarray) -> np.ndarray:
    """uint32 polynomial hash of each token row (multiplier 1000003,
    modular): equal rows always share a fingerprint, so dedup over
    fingerprints is dedup over rows (up to a ~b^2/2^33 collision risk the
    synthetic stream doesn't approach)."""
    t = np.ascontiguousarray(tokens).astype(np.uint32)
    s = t.shape[-1]
    pows = np.empty((s,), np.uint32)
    acc = 1
    for i in range(s - 1, -1, -1):
        pows[i] = acc
        acc = (acc * 1000003) % (1 << 32)
    return (t * pows).sum(axis=-1, dtype=np.uint32)


def dedup_rows(tokens: np.ndarray) -> np.ndarray:
    """Keep-mask selecting the FIRST occurrence of each distinct token row.

    The fingerprint column goes through ``relational.unique`` (sort-based
    dedup — the subsystem's canonical workload); first-occurrence selection
    is a scatter-min of positions over the inverse index.
    """
    import jax.numpy as jnp

    from repro import relational
    h = row_fingerprints(tokens)
    n = h.shape[0]
    if n == 0:
        return np.zeros((0,), bool)
    u = relational.unique(jnp.asarray(h), return_inverse=True)
    pos = jnp.arange(n, dtype=jnp.int32)
    first = jnp.full((n,), n, jnp.int32).at[u.inverse].min(pos)
    return np.asarray(first[u.inverse] == pos)


def device_put_batch(batch: Dict[str, np.ndarray], mesh, dp_axes):
    """Place a (host-local or global) numpy batch onto the mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(dp_axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
