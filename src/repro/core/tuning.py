"""Per-device tuning profiles — measured constants + tunable kernel knobs.

The paper's whole argument is cycle accounting: Tables I/II price every op
and every temp-row movement cycle, and the comparison figures only hold
because those constants describe the *actual* substrate (Mutlu et al.'s
practicality argument: the PIM win evaporates when the cost model is wrong
about the hardware).  The software stack has the same exposure one level
up — the planner's dispatch decisions are only as good as the per-element
constants and kernel shape parameters they are priced with.

This module is the one home for all of that state:

  * :class:`DeviceSortConstants` — the ns-per-element leading constants of
    every software backend (previously ``cost_model.DeviceSortConstants``;
    the cost model now *consumes* this layer instead of owning it).
  * :class:`TuningProfile` — a frozen record of those constants **plus**
    the tunable kernel parameters (radix ``digit_bits``, histogram tile,
    engine run length, sample-sort capacity slack, selection switch-over),
    keyed by a device fingerprint (platform, device kind, jax version) and
    schema-versioned for JSON persistence.
  * an **active profile** ambient: ``active()`` lazily resolves the
    profile for the running device — a persisted profile when one matches
    the fingerprint, the per-platform defaults otherwise — and every
    consumer (cost model, kernels, engine, sample-sort) reads its
    parameters from it.  ``set_active`` bumps a generation counter that
    the planner folds into its plan-cache keys, so swapping profiles
    transparently re-plans.
  * persistence: ``save``/``load``/``load_for_device`` with a search path
    of ``$REPRO_TUNING_DIR``, the user cache (``~/.cache/repro/profiles``)
    and the repo's committed baselines (``benchmarks/profiles/``).
  * the observability feedback hook: :func:`refresh_if_stale` re-probes
    (``planner.calibrate``) when the ``planner.cost_model_error``
    histogram's p90 drifts outside the trust band, closing the loop the
    obs subsystem opened.

Layering: this module is the *bottom* of the sorting stack — it imports
nothing from ``cost_model`` / ``planner`` / the kernels at module level
(they all import it), and jax only lazily inside the fingerprint helpers.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import threading
import time
from typing import Dict, Optional, Tuple

__all__ = [
    "SCHEMA", "DeviceSortConstants", "TuningProfile", "ProfileError",
    "device_fingerprint", "default_profile", "active", "set_active",
    "generation", "save", "load", "load_for_device", "persisted_path",
    "profile_path", "search_dirs", "refresh_if_stale", "maybe_refresh",
]

SCHEMA = "repro.tuning.profile/v1"

PROFILE_DIR_ENV = "REPRO_TUNING_DIR"     # highest-priority profile dir
AUTOTUNE_ENV = "REPRO_AUTOTUNE"          # "1" => maybe_refresh() is live

# ---- tunable-parameter defaults (the "default profile") ----------------------
# These are the *only* hardcoded homes of the kernel shape constants; every
# other module (cost_model pricing, the radix kernels, the engine's run
# generation, sample-sort capacity policy) resolves them through the active
# profile.
DEFAULT_DIGIT_BITS = 8          # radix 256: 4 passes for 32-bit keys
DEFAULT_RADIX_TILE = 256        # elements per histogram partition
DEFAULT_RUN_LEN = 2048          # engine tile: one VMEM tile on TPU
DEFAULT_CPU_RUN_LEN = 8192      # host tile: measured jnp sweet spot
DEFAULT_CAPACITY_SLACK = 1.0    # sample-sort bucket capacity multiplier
DEFAULT_SELECT_MIN_N = 1024     # auto never picks selection below this n
# k-way merge fan-in: how many sorted runs one merge tournament consumes
# at a time before cascading (the spill tier's host merge groups runs in
# fan-in-sized batches; planner.calibrate() sweeps this)
DEFAULT_MERGE_FANIN = 16
# Out-of-core spill tier: arrays whose key payload exceeds this many bytes
# auto-route to repro.engine.spill (chunked device sorts + host k-way
# merge).  The default is sized for a ~16 GiB accelerator with headroom
# for the sort's own scratch (runs + merge ping-pong ~ 4x the input).
DEFAULT_SPILL_THRESHOLD_BYTES = 4 << 30
# floor: a chunk must hold at least a handful of elements of the widest
# key dtype (8 B) for the chunk/merge machinery to be meaningful; tests
# force tiny thresholds (e.g. 256 B) to exercise many-chunk paths cheaply
MIN_SPILL_THRESHOLD_BYTES = 64

_VALID_DIGIT_BITS = (1, 2, 4, 8)

# observability feedback band: re-probe when cost_model_error p90 leaves
# [1/threshold, threshold] after at least min-observations samples
REFRESH_P90_THRESHOLD = 4.0
REFRESH_MIN_OBSERVATIONS = 32
# minimum seconds between drift-triggered recalibrations: a calibrate()
# sweep is milliseconds-to-seconds of probe sorts, so a persistently noisy
# drift signal (e.g. a co-tenant stealing the device) must not turn the
# closed loop into a calibration storm
REFRESH_COOLDOWN_S = 300.0


@dataclasses.dataclass(frozen=True)
class DeviceSortConstants:
    """ns-per-element leading constants for each software backend.

    Asymptotics are fixed per backend (``cost_model``); these are the
    measured leading constants ``planner.calibrate()`` fits on the live
    device.  The defaults are coarse seeds good enough for dispatch
    ordering.
    """
    xla: float = 6.0             # comparison sort: c * n log2 n
    bitonic: float = 1.2         # word-parallel jnp network: c * n log2^2 n
    pallas: float = 0.25         # VMEM-resident network: c * n log2^2 n
    merge_run: float = 6.0       # run generation: c * n log2 run_len
    merge_level: float = 12.0    # one merge-path level: c * n
    radix: float = 12.0          # LSD digit pass: c * n * passes
    # MSD select, c * n * pass units.  The constant is seeded from the
    # measured CPU bit-serial path (which runs digit_bits 1-bit
    # refinements per pass unit), putting the modeled select/sort-prefix
    # crossover at n ~ 1-2k for f32/k=64 — where the bench measures it
    select: float = 15.0
    # native lax.top_k on substrates where it lowers to a tuned O(n)
    # selection (XLA:CPU): c * n.  Seeded from the measured 3.4ms at n=1M
    # (results_engine_cpu.csv topk_xla rows); on TPU lax.top_k is
    # sort-based and the xla backend keeps the sort-prefix price instead
    xla_topk: float = 3.5
    pallas_interpret_penalty: float = 300.0   # CPU interpret-mode multiplier
    # mesh collectives (distributed dispatch): one collective round costs
    # alpha (launch/latency) + bytes-moved-per-device / bandwidth.  The
    # ici pair prices the fast intra-host tier; the dcn pair the ~10x
    # slower inter-host tier (repro.core.topology derives its default
    # per-axis link rates from these, and a calibrated Topology overrides
    # them per mesh axis).
    collective_alpha: float = 2_000.0         # ns per collective launch
    collective_per_byte: float = 0.02         # ns/byte (~50 GB/s ICI link)
    dcn_alpha: float = 20_000.0               # ns per cross-host launch
    dcn_per_byte: float = 0.2                 # ns/byte (~5 GB/s DCN link)
    # spill tier (out-of-core): host<->device link bandwidth term and the
    # host-side k-way merge constant.  0.0625 ns/byte ~ 16 GB/s, a
    # PCIe-gen4-class x16 link; the merge constant prices one host
    # cursor-partition + device block-merge pass per element
    pcie_per_byte: float = 0.0625
    host_merge_level: float = 8.0


class ProfileError(ValueError):
    """A persisted profile that cannot be trusted: wrong schema version,
    malformed JSON, or field values outside the validated ranges."""


@dataclasses.dataclass(frozen=True)
class TuningProfile:
    """One device's measured cost constants + tuned kernel parameters.

    ``source`` records provenance: ``"default"`` (built-in per-platform
    seeds), ``"calibrated"`` (``planner.calibrate`` ran in this process),
    ``"persisted"`` (loaded from disk).  ``probe_ns`` and ``sweeps`` keep
    the raw measurement tables the autotuner derived the winners from, so
    a persisted profile is auditable.
    """
    fingerprint: str
    constants: DeviceSortConstants = DeviceSortConstants()
    digit_bits: int = DEFAULT_DIGIT_BITS
    radix_tile: int = DEFAULT_RADIX_TILE
    run_len: int = DEFAULT_RUN_LEN
    capacity_slack: float = DEFAULT_CAPACITY_SLACK
    select_min_n: int = DEFAULT_SELECT_MIN_N
    merge_fanin: int = DEFAULT_MERGE_FANIN
    spill_threshold_bytes: int = DEFAULT_SPILL_THRESHOLD_BYTES
    source: str = "default"
    probe_ns: Optional[Dict[str, float]] = None
    sweeps: Optional[Dict[str, Dict[str, float]]] = None
    schema: str = SCHEMA

    def __post_init__(self):
        if self.schema != SCHEMA:
            raise ProfileError(
                f"unknown profile schema {self.schema!r} (expected {SCHEMA!r})")
        if self.digit_bits not in _VALID_DIGIT_BITS:
            raise ProfileError(
                f"digit_bits must be one of {_VALID_DIGIT_BITS}, "
                f"got {self.digit_bits}")
        if self.radix_tile < 8:
            raise ProfileError(f"radix_tile too small: {self.radix_tile}")
        if self.run_len < 2:
            raise ProfileError(f"run_len too small: {self.run_len}")
        if self.capacity_slack < 1.0:
            # slack < 1 would undersize exchange buffers and drop elements
            raise ProfileError(
                f"capacity_slack must be >= 1.0, got {self.capacity_slack}")
        if self.select_min_n < 0:
            raise ProfileError(
                f"select_min_n must be >= 0, got {self.select_min_n}")
        if self.merge_fanin < 2:
            # a 1-way "merge" never terminates the cascade
            raise ProfileError(
                f"merge_fanin must be >= 2, got {self.merge_fanin}")
        if self.spill_threshold_bytes < MIN_SPILL_THRESHOLD_BYTES:
            raise ProfileError(
                f"spill_threshold_bytes must be >= "
                f"{MIN_SPILL_THRESHOLD_BYTES}, "
                f"got {self.spill_threshold_bytes}")

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TuningProfile":
        if not isinstance(d, dict):
            raise ProfileError(f"profile document must be an object, "
                               f"got {type(d).__name__}")
        if d.get("schema") != SCHEMA:
            raise ProfileError(
                f"unknown profile schema {d.get('schema')!r} "
                f"(expected {SCHEMA!r})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ProfileError(
                f"unknown profile fields {sorted(unknown)} (schema {SCHEMA})")
        if "fingerprint" not in d or not isinstance(d["fingerprint"], str):
            raise ProfileError("profile is missing its device fingerprint")
        d = dict(d)
        consts = d.get("constants")
        if consts is not None:
            if not isinstance(consts, dict):
                raise ProfileError("profile constants must be an object")
            cfields = {f.name for f in dataclasses.fields(DeviceSortConstants)}
            bad = set(consts) - cfields
            if bad:
                raise ProfileError(
                    f"unknown cost constants {sorted(bad)} (schema {SCHEMA})")
            d["constants"] = DeviceSortConstants(
                **{k: float(v) for k, v in consts.items()})
        try:
            return cls(**d)
        except TypeError as e:
            raise ProfileError(f"malformed profile: {e}") from e


# ---------------------------------------------------------------------------
# device fingerprint + per-platform defaults
# ---------------------------------------------------------------------------

def device_fingerprint() -> str:
    """(platform, device kind, jax version) — the key a persisted profile
    is trusted under.  Constants measured on one substrate say nothing
    about another, and a jax upgrade can change every lowering."""
    import jax
    devs = jax.devices()
    kind = devs[0].device_kind if devs else "unknown"
    fp = f"{jax.default_backend()}/{kind}/jax-{jax.__version__}"
    return fp.replace(" ", "-")


def default_profile() -> TuningProfile:
    """The built-in seeds for the running platform — what the stack uses
    until a calibration runs or a persisted profile matches."""
    import jax
    tpu = jax.default_backend() == "tpu"
    return TuningProfile(
        fingerprint=device_fingerprint(),
        run_len=DEFAULT_RUN_LEN if tpu else DEFAULT_CPU_RUN_LEN,
        source="default")


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def _repo_profile_dir() -> pathlib.Path:
    # src/repro/core/tuning.py -> repo root / benchmarks / profiles
    return pathlib.Path(__file__).resolve().parents[3] / "benchmarks" \
        / "profiles"


def cache_dir() -> pathlib.Path:
    """Where ``calibrate(persist=True)`` writes by default:
    ``$REPRO_TUNING_DIR`` when set, else ``~/.cache/repro/profiles``."""
    env = os.environ.get(PROFILE_DIR_ENV)
    if env:
        return pathlib.Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = pathlib.Path(xdg) if xdg else pathlib.Path.home() / ".cache"
    return base / "repro" / "profiles"


def search_dirs() -> Tuple[pathlib.Path, ...]:
    """Profile lookup order: env override, user cache, repo baselines."""
    dirs = []
    env = os.environ.get(PROFILE_DIR_ENV)
    if env:
        dirs.append(pathlib.Path(env))
    else:
        dirs.append(cache_dir())
    dirs.append(_repo_profile_dir())
    return tuple(dirs)


def _filename(fingerprint: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "_", fingerprint) + ".json"


def profile_path(fingerprint: Optional[str] = None,
                 directory: Optional[os.PathLike] = None) -> pathlib.Path:
    """Canonical file path for a fingerprint's profile."""
    fp = fingerprint or device_fingerprint()
    d = pathlib.Path(directory) if directory is not None else cache_dir()
    return d / _filename(fp)


def save(profile: TuningProfile,
         path: Optional[os.PathLike] = None) -> pathlib.Path:
    """Persist ``profile`` as schema-versioned JSON; returns the path."""
    p = pathlib.Path(path) if path is not None \
        else profile_path(profile.fingerprint)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(profile.to_dict(), indent=2, allow_nan=False,
                            sort_keys=True) + "\n")
    return p


def load(path: os.PathLike) -> TuningProfile:
    """Load one profile file.  Raises :class:`ProfileError` on a schema
    mismatch or malformed document (never silently trusts stale data)."""
    try:
        doc = json.loads(pathlib.Path(path).read_text())
    except (OSError, ValueError) as e:
        raise ProfileError(f"cannot read profile {path}: {e}") from e
    return TuningProfile.from_dict(doc)


def persisted_path(fingerprint: Optional[str] = None
                   ) -> Optional[pathlib.Path]:
    """First path in the search order holding a *valid* profile whose
    fingerprint matches, or None."""
    fp = fingerprint or device_fingerprint()
    for d in search_dirs():
        p = d / _filename(fp)
        if not p.is_file():
            continue
        try:
            if load(p).fingerprint == fp:
                return p
        except ProfileError:
            continue
    return None


def load_for_device(fingerprint: Optional[str] = None
                    ) -> Optional[TuningProfile]:
    """The persisted profile for this device, or None.  A file whose
    stored fingerprint does not match (mislabelled or copied from another
    machine) is rejected — constants fall back to the defaults rather
    than mispricing every plan."""
    fp = fingerprint or device_fingerprint()
    p = persisted_path(fp)
    if p is None:
        return None
    return dataclasses.replace(load(p), source="persisted")


# ---------------------------------------------------------------------------
# active-profile ambient
# ---------------------------------------------------------------------------

_LOCK = threading.Lock()
_active: Optional[TuningProfile] = None
_generation = 0


def active() -> TuningProfile:
    """The profile the stack currently runs on.  Resolved lazily on first
    use: a persisted profile matching the device fingerprint wins, else
    the per-platform defaults.  ``planner.calibrate()`` replaces it via
    :func:`set_active`."""
    global _active
    if _active is None:
        with _LOCK:
            if _active is None:
                prof = load_for_device()
                _set(prof if prof is not None else default_profile())
    return _active


def _set(profile: Optional[TuningProfile]) -> None:
    global _active, _generation, _last_refresh_t
    _active = profile
    _generation += 1
    # Installing/resetting a profile starts a fresh refresh epoch: the
    # drift-refresh cooldown stamp must not leak from one install to the
    # next (a calibrate in one test would silently suppress drift
    # refreshes in the next for REFRESH_COOLDOWN_S).  refresh_if_stale
    # re-stamps *after* its calibrate() returns, so the cooldown it
    # enforces always refers to the profile it installed.
    _last_refresh_t = None


def set_active(profile: Optional[TuningProfile]) -> None:
    """Swap the active profile (``None`` = forget and lazily re-resolve).
    Bumps the generation counter, which the planner folds into every
    plan-cache key — cached plans priced under the old profile die."""
    with _LOCK:
        _set(profile)


def generation() -> int:
    """Monotonic counter for cache keys; forces resolution first so a plan
    cached before the lazy load cannot outlive it."""
    active()
    return _generation


# ---------------------------------------------------------------------------
# observability feedback: re-probe on cost-model drift
# ---------------------------------------------------------------------------

# monotonic stamp of the last drift-triggered calibrate (None = never);
# tests reset it by assigning None
_last_refresh_t: Optional[float] = None


def refresh_if_stale(threshold: float = REFRESH_P90_THRESHOLD,
                     min_count: int = REFRESH_MIN_OBSERVATIONS, *,
                     persist: bool = True,
                     cooldown_s: float = REFRESH_COOLDOWN_S,
                     now_fn=None,
                     **calibrate_kwargs) -> Optional[TuningProfile]:
    """Re-run the autotuner when measured/predicted cost drift says the
    active constants no longer describe this device.

    Reads the ``planner.cost_model_error`` histogram (PR 6's obs
    subsystem: one measured/predicted ratio per fenced engine call).  With
    at least ``min_count`` observations and a p90 outside
    ``[1/threshold, threshold]``, runs ``planner.calibrate(persist=...)``
    — which swaps the active profile, invalidates cached plans, and (by
    default) persists the fresh profile — then clears the histogram so
    the next drift measurement starts clean.  Returns the new profile, or
    None when the constants still hold (or there is too little signal).

    Refreshes are rate-limited: after a drift-triggered calibrate, further
    triggers within ``cooldown_s`` (monotonic clock; ``now_fn`` injectable
    for tests) return None WITHOUT clearing the histogram — the drift
    evidence keeps accumulating and the refresh fires as soon as the
    cooldown lapses.  ``cooldown_s=0`` disables the limit.
    """
    global _last_refresh_t
    from repro.obs import metrics
    h = metrics.histogram("planner.cost_model_error")
    if h.count < min_count:
        return None
    p90 = h.percentile(90)
    if p90 is None or (1.0 / threshold) <= p90 <= threshold:
        return None
    # cooldown check AFTER the signal checks: the rate-limited counter
    # counts refreshes that *would* have fired, nothing else
    now = (now_fn or time.monotonic)()
    if _last_refresh_t is not None and cooldown_s > 0 \
            and now - _last_refresh_t < cooldown_s:
        metrics.counter("tuning.refreshes_rate_limited").inc()
        return None
    from repro.engine import planner
    prof = planner.calibrate(persist=persist, **calibrate_kwargs)
    _last_refresh_t = now
    h.clear()
    metrics.counter("tuning.refreshes").inc()
    from repro.obs import trace
    trace.record_event("tuning_refresh", p90=p90, threshold=threshold,
                       fingerprint=prof.fingerprint, source=prof.source)
    return prof


_autotune_live: Optional[bool] = None


def maybe_refresh() -> None:
    """Zero-cost hook the engine calls after every cost observation: a
    no-op unless ``REPRO_AUTOTUNE=1`` opts the process into closed-loop
    re-probing (calibration mid-serve is deliberate, never a surprise)."""
    global _autotune_live
    if _autotune_live is None:
        _autotune_live = os.environ.get(AUTOTUNE_ENV) == "1"
    if not _autotune_live:
        return
    refresh_if_stale()
