"""Complete in-memory binary sorting unit (paper §II-B).

The N-input sorter maps the Batcher bitonic network onto N/2 memory
partitions (FELIX-style partitioning): every stage executes its N/2 CAS
blocks *concurrently*, one per partition, and stage transitions whose operand
placement changes pay the Eq. 3-4 movement cost (N/4 temporary rows,
3N/4 cycles per exchanging transition).

Functional execution here folds the partition axis into the batch axis of the
CAS array simulator.  This is exact, not an approximation: the physical array
is 22 rows x 4*(N/2) columns and every IMC cycle operates on ALL columns of a
row pair at once, so the partitions advance in lock-step — identical to
batching independent 22 x W arrays.  Cycle accounting therefore charges each
stage ONE CAS program (28 cycles at W=4), not N/2 of them.
"""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp
import numpy as np

from repro.core import cas, network


@dataclasses.dataclass(frozen=True)
class SortResult:
    values: jnp.ndarray          # (batch, n) ascending
    cycles: int                  # total IMC cycles (compute + movement)
    compute_cycles: int          # stages * CAS program length
    movement_cycles: int         # Eq.3-4 inter-partition operand exchange
    n_partitions: int
    n_temp_rows: int
    array_rows: int
    array_cols: int
    op_counts: dict


def array_geometry(n: int, width: int = 4) -> dict:
    """Physical array footprint for an N-input sorter (paper: 16x22 for N=8)."""
    prog = cas.cached_program(width)
    return {
        "rows": prog.n_rows,
        "cols": width * (n // 2),
        "temp_rows": network.n_temp_rows(n),
        "bits": prog.n_rows * width * (n // 2),
    }


def sort_in_memory(values, width: int = 4, jit: bool = True) -> SortResult:
    """Sort (batch, n) unsigned ``width``-bit ints with the IMC bitonic unit.

    Every CAS in the schedule is executed through the full 28-cycle gate
    program on the simulated 6T SRAM array; results are bit-exact against any
    comparison sort.
    """
    v = jnp.asarray(values, dtype=jnp.uint32)
    if v.ndim == 1:
        v = v[None, :]
    batch, n = v.shape
    stages = network.bitonic_stages(n)
    plan = network.plan_partitions(n)
    prog = cas.cached_program(width)

    counter_ops = {k: c * len(stages)
                   for k, c in _static_cas_counts(width).items()}

    for stage in stages:
        idx_i = np.array([p[0] for p in stage])
        idx_j = np.array([p[1] for p in stage])
        asc = np.array([p[2] for p in stage])
        a = v[:, idx_i].reshape(-1)          # fold (batch, n/2) partitions
        b = v[:, idx_j].reshape(-1)
        res = cas.run_cas(a, b, width=width, jit=jit)
        lo = res.lo.reshape(batch, -1)
        hi = res.hi.reshape(batch, -1)
        asc_b = jnp.asarray(asc)[None, :]
        out_i = jnp.where(asc_b, lo, hi)
        out_j = jnp.where(asc_b, hi, lo)
        v = v.at[:, idx_i].set(out_i).at[:, idx_j].set(out_j)

    compute = len(stages) * prog.total_cycles
    movement = plan.extra_cycles
    geom = array_geometry(n, width)
    # movement ops are COPY-class (temp-row reads/writes)
    counter_ops = dict(counter_ops)
    counter_ops["COPY"] = counter_ops.get("COPY", 0) + movement
    counter_ops["total"] = compute + movement
    return SortResult(values=v, cycles=compute + movement,
                      compute_cycles=compute, movement_cycles=movement,
                      n_partitions=plan.n_partitions,
                      n_temp_rows=network.n_temp_rows(n),
                      array_rows=geom["rows"], array_cols=geom["cols"],
                      op_counts=counter_ops)


def _static_cas_counts(width: int) -> dict:
    prog = cas.cached_program(width)
    from repro.core.imc_array import CycleCounter
    c = CycleCounter()
    for op in prog.ops:
        c.count(op.kind)
    d = c.as_dict()
    d.pop("total")
    return d
