"""Cycle-accurate functional simulator of the 6T SRAM IMC array (paper §I-II).

The array natively computes, in ONE cycle, an elementwise two-input logic op
over *all columns* of two simultaneously-activated rows:

    AND  on the BL  (bitline)          — Fig. 1(b)
    NOR  on the BLB (complement line)  — Fig. 1(c)

Derived single-cycle ops (paper §II-A, using the constant rows):
    NOT(x)  = NOR(x, ROW_ZERO)   — row 1 stores logic 0
    COPY(x) = AND(x, ROW_ONE)    — row 2 stores logic 1

Each cycle's result is written back with ONE of four movement types
(paper §II-A, write-back taxonomy a-d):
    SAME        (a) write back column-aligned
    SHIFT_RIGHT (b) write to the adjacent right column (column 0 takes the
                    selected constant fill — the constant rows are adjacent)
    BCAST_LAST  (c) the last column's value is written to all columns
    BCAST_COL   (d) an interior column's value is written to all columns

State is a jnp bool array ``(batch, n_rows, n_cols)``; every op is batched
(this is not an approximation: bitline logic is column-parallel, and batching
over independent arrays is exact).  A :class:`CycleCounter` tallies op kinds
for validation against Table I.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import jax.numpy as jnp

ROW_ZERO = 0  # constant 0s (paper row 1)
ROW_ONE = 1   # constant 1s (paper row 2)
ROW_A = 2     # operand A   (paper row 3)
ROW_B = 3     # operand B   (paper row 4)


class OpKind(enum.Enum):
    NOR = "NOR"
    AND = "AND"
    NOT = "NOT"    # NOR with ROW_ZERO
    COPY = "COPY"  # AND with ROW_ONE


class Movement(enum.Enum):
    SAME = "same"
    SHIFT_RIGHT = "shift_right"
    BCAST_LAST = "bcast_last"
    BCAST_COL = "bcast_col"


@dataclasses.dataclass(frozen=True)
class Op:
    kind: OpKind
    src1: int
    dst: int
    src2: Optional[int] = None           # None for NOT/COPY (implicit const row)
    movement: Movement = Movement.SAME
    fill: int = 0                        # SHIFT_RIGHT column-0 fill (0 or 1)
    bcast_col: int = 0                   # BCAST_COL source column
    label: str = ""


@dataclasses.dataclass
class CycleCounter:
    nor: int = 0
    and_: int = 0
    not_: int = 0
    copy: int = 0

    @property
    def total(self) -> int:
        return self.nor + self.and_ + self.not_ + self.copy

    def count(self, kind: OpKind) -> None:
        if kind is OpKind.NOR:
            self.nor += 1
        elif kind is OpKind.AND:
            self.and_ += 1
        elif kind is OpKind.NOT:
            self.not_ += 1
        else:
            self.copy += 1

    def as_dict(self) -> dict:
        return {"NOR": self.nor, "NOT": self.not_, "AND": self.and_,
                "COPY": self.copy, "total": self.total}


def make_array(batch: int, n_rows: int, n_cols: int) -> jnp.ndarray:
    """Fresh array with the constant rows initialised (rows 0/1)."""
    state = jnp.zeros((batch, n_rows, n_cols), dtype=bool)
    state = state.at[:, ROW_ONE, :].set(True)
    return state


def write_word(state: jnp.ndarray, row: int, bits: jnp.ndarray) -> jnp.ndarray:
    """Write a (batch, n_cols) bit matrix into a row (column 0 = MSB)."""
    return state.at[:, row, :].set(bits.astype(bool))


def read_word(state: jnp.ndarray, row: int) -> jnp.ndarray:
    return state[:, row, :]


def _compute(state: jnp.ndarray, op: Op) -> jnp.ndarray:
    a = state[:, op.src1, :]
    if op.kind is OpKind.NOR:
        b = state[:, op.src2, :]
        return jnp.logical_not(jnp.logical_or(a, b))
    if op.kind is OpKind.AND:
        b = state[:, op.src2, :]
        return jnp.logical_and(a, b)
    if op.kind is OpKind.NOT:       # NOR with the constant-0 row
        b = state[:, ROW_ZERO, :]
        return jnp.logical_not(jnp.logical_or(a, b))
    # COPY: AND with the constant-1 row
    b = state[:, ROW_ONE, :]
    return jnp.logical_and(a, b)


def _move(result: jnp.ndarray, op: Op) -> jnp.ndarray:
    if op.movement is Movement.SAME:
        return result
    if op.movement is Movement.SHIFT_RIGHT:
        fill = jnp.full_like(result[:, :1], bool(op.fill))
        return jnp.concatenate([fill, result[:, :-1]], axis=1)
    if op.movement is Movement.BCAST_LAST:
        return jnp.broadcast_to(result[:, -1:], result.shape)
    # BCAST_COL
    return jnp.broadcast_to(result[:, op.bcast_col:op.bcast_col + 1],
                            result.shape)


def step(state: jnp.ndarray, op: Op,
         counter: Optional[CycleCounter] = None) -> jnp.ndarray:
    """Execute ONE IMC cycle: compute over all columns, move, write back."""
    if counter is not None:
        counter.count(op.kind)
    result = _move(_compute(state, op), op)
    return state.at[:, op.dst, :].set(result)


def run_program(state: jnp.ndarray, program: List[Op],
                counter: Optional[CycleCounter] = None) -> jnp.ndarray:
    for op in program:
        state = step(state, op, counter)
    return state


# -- word <-> bit-plane helpers (column 0 is the MSB, as in the paper) -------

def int_to_bits(x: jnp.ndarray, width: int) -> jnp.ndarray:
    """(batch,) unsigned ints -> (batch, width) bool, MSB first."""
    shifts = jnp.arange(width - 1, -1, -1, dtype=x.dtype)
    return ((x[:, None] >> shifts[None, :]) & 1).astype(bool)


def bits_to_int(bits: jnp.ndarray) -> jnp.ndarray:
    """(batch, width) bool, MSB first -> (batch,) unsigned ints."""
    width = bits.shape[-1]
    weights = (1 << jnp.arange(width - 1, -1, -1)).astype(jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) * weights[None, :], axis=-1)
