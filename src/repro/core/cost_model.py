"""Analytical cost model — reproduces the paper's Tables I/II and Fig. 8.

All REPORTED numbers use the paper's published constants:

  * Table I op mix for a W=4 CAS: NOR 14, NOT 8, AND 3, COPY 3 (28 cycles);
    single-stage totals for N=8: NOR 84, NOT 48, AND 18, COPY 42 (192).
  * 0.55 ns per IMC operation at 65 nm (=> 1.81 GHz operating frequency).
  * Fig. 8 comparison baselines: MemSort (memristive IMC, [7]) and an
    off-memory (von Neumann) path.  This paper does not reprint [7]'s raw
    tables, so the MemSort model is anchored to the ratios the paper reports
    (1.45x cycles, 3.4x latency, and 5x vs the off-memory approach) — see
    DESIGN.md §6.

The per-cycle simulator (gates.py / sorter.py) validates FUNCTIONAL
correctness and total cycle counts; this module owns every latency /
throughput / comparison number quoted in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core import cas, network
from repro.core import tuning as _tuning
# Re-export: the constants' home is the tuning layer (the cost model
# *consumes* measured profiles, it does not own them), but every historical
# consumer spells cost_model.DeviceSortConstants.
from repro.core.tuning import DeviceSortConstants  # noqa: F401

# ---- paper constants (§III, Table I/II) -------------------------------------
CYCLE_NS = 0.55                      # latency of one IMC operation, 65 nm
OPERATING_FREQ_GHZ = 1 / CYCLE_NS    # 1.81 GHz (Table II)

TABLE1_CAS_OPS: Dict[str, int] = {"NOR": 14, "NOT": 8, "AND": 3, "COPY": 3}
CAS_CYCLES_W4 = sum(TABLE1_CAS_OPS.values())            # 28

# Fig. 8 anchors (ratios as published)
MEMSORT_CYCLE_RATIO = 1.45           # Fig. 8(a): cycles(MemSort)/cycles(ours)
MEMSORT_LATENCY_RATIO = 3.4          # Fig. 8(b)
OFF_MEMORY_LATENCY_RATIO = 5.0       # §III text


def cas_cycles(width: int = 4, use_paper_counts: bool = True) -> int:
    """Cycles for one CAS block.  W=4 is the paper's 28; other widths use the
    reconstructed gate program's length (extrapolation)."""
    if width == 4 and use_paper_counts:
        return CAS_CYCLES_W4
    return cas.cached_program(width).total_cycles


def sort_cycles(n: int, width: int = 4, use_paper_counts: bool = True) -> int:
    """Total cycles to sort N unsigned W-bit values in-memory.

    stages x CAS + movement (Eq. 3-4 with the paper's fused-first-exchange
    accounting).  N=8, W=4 -> 6*28 + 24 = 192 (§III / Table I).
    """
    stages = network.n_stages(n)
    movement = network.total_extra_cycles(n)
    return stages * cas_cycles(width, use_paper_counts) + movement


def sort_latency_ns(n: int, width: int = 4) -> float:
    """N=8, W=4 -> 105.6 ns (Table II)."""
    return sort_cycles(n, width) * CYCLE_NS


def throughput_gops(n: int, width: int = 4) -> float:
    """IMC operations per second; Table II reports 1.8 GOPS for N=8, W=4
    (one op per 0.55 ns cycle)."""
    return sort_cycles(n, width) / sort_latency_ns(n, width)


def stage_op_totals(n: int = 8) -> Dict[str, int]:
    """Table I right column: per-op totals for the complete N-input unit.

    Movement cycles are COPY-class (temp-row transfers): for N=8 the paper
    reports COPY 42 = 6 stages * 3 + 24 movement cycles.
    """
    stages = network.n_stages(n)
    totals = {k: v * stages for k, v in TABLE1_CAS_OPS.items()}
    totals["COPY"] += network.total_extra_cycles(n)
    return totals


# ---- comparison baselines (Fig. 8) ------------------------------------------

def memsort_cycles(n: int = 8, width: int = 4) -> float:
    return sort_cycles(n, width) * MEMSORT_CYCLE_RATIO


def memsort_latency_ns(n: int = 8, width: int = 4) -> float:
    return sort_latency_ns(n, width) * MEMSORT_LATENCY_RATIO


def off_memory_latency_ns(n: int = 8, width: int = 4) -> float:
    return sort_latency_ns(n, width) * OFF_MEMORY_LATENCY_RATIO


def bubble_sort_comparisons(n: int = 8) -> int:
    """Software baseline the paper uses (8-bit masked to 4-bit, bubble sort):
    worst-case compare-swap count."""
    return n * (n - 1) // 2


def memory_bits(n: int = 8, width: int = 4) -> int:
    """Fig. 8(c): array bits used, with CAS-row reuse (22-row array)."""
    from repro.core import sorter
    return sorter.array_geometry(n, width)["bits"]


# ---- device-level cost model (engine auto-dispatch) --------------------------
#
# The paper's model prices one SRAM macro; the engine's planner needs the same
# kind of closed form one level up: how long does each *device* backend take
# to sort (batch, n)?  Asymptotics are fixed per backend; the per-element
# constants and kernel shape parameters (radix digit width, histogram tile)
# live in the active ``repro.core.tuning`` profile — coarse per-platform
# defaults until ``repro.engine.planner.calibrate()`` measures and persists
# real ones — and every cost function below resolves them from there when
# the caller does not pin them explicitly.


def _radix_digit_bits(digit_bits: Optional[int]) -> int:
    return digit_bits if digit_bits is not None \
        else _tuning.active().digit_bits


def _radix_tile(tile: Optional[int]) -> int:
    return tile if tile is not None else _tuning.active().radix_tile


def _log2(v: float) -> float:
    return math.log2(max(2.0, v))


def device_sort_cost_ns(method: str, n: int, batch: int = 1, *,
                        run_len: Optional[int] = None,
                        consts: DeviceSortConstants = None,
                        pallas_interpreted: bool = False,
                        key_bits: int = 32,
                        digit_bits: Optional[int] = None,
                        tile: Optional[int] = None) -> float:
    """Estimated ns to sort ``batch`` rows of ``n`` with a software backend.

    ``n`` is priced at its padded (power-of-two / tiled) size, matching what
    each backend actually executes.  ``key_bits`` is the encoded key width
    (keycodec) — only the radix backend's pass count depends on it.
    ``digit_bits`` / ``tile`` default to the active tuning profile's values,
    i.e. exactly what the radix kernel will run with.
    """
    c = consts or _tuning.active().constants
    m = 1 << max(0, (n - 1).bit_length())
    if method == "xla":
        return c.xla * batch * n * _log2(n)
    if method == "bitonic":
        return c.bitonic * batch * m * _log2(m) ** 2
    if method == "pallas":
        pen = c.pallas_interpret_penalty if pallas_interpreted else 1.0
        return pen * c.pallas * batch * m * _log2(m) ** 2
    if method == "radix":
        # O(n·b): ceil(b/digit_bits) digit passes, each touching every
        # element once (histogram + rank + scatter); Pallas kernels, so
        # interpret mode pays the same penalty as the bitonic kernel path
        passes = -(-key_bits // _radix_digit_bits(digit_bits))
        rt = _radix_tile(tile)
        tiled = -(-n // rt) * rt
        pen = c.pallas_interpret_penalty if pallas_interpreted else 1.0
        return pen * c.radix * batch * tiled * passes
    if method == "merge":
        run_len = min(run_len if run_len is not None
                      else _tuning.active().run_len, m)
        tiles = 1 << max(0, (-(-n // run_len) - 1).bit_length())
        padded = tiles * run_len
        gen = c.merge_run * batch * padded * _log2(run_len)
        levels = _log2(tiles) if tiles > 1 else 0.0
        return gen + c.merge_level * batch * padded * levels
    raise ValueError(f"no device cost model for method {method!r}")


def selection_cost_ns(n: int, k: int, key_bits: int = 32, batch: int = 1, *,
                      consts: DeviceSortConstants = None,
                      digit_bits: Optional[int] = None,
                      tile: Optional[int] = None) -> float:
    """Estimated ns for an exact top-k *selection* of ``(batch, n)`` rows —
    the partial-sort operating mode the hardware-sorting survey treats as
    first-class, priced so the planner can weigh it against sort-prefix:

      ceil(b/digit_bits) MSD digit-refinement passes, each one O(n)
      counting work over the (tile-padded) row, plus the O(k log k)
      two-key ordering of the k survivors.

    No interpret penalty: off-TPU the select runs its jnp scatter-add
    histogram (kernels/radix_select.py), not an interpreted Pallas kernel
    — selection is exactly the radix path that stays fast on hosts.
    """
    c = consts or _tuning.active().constants
    passes = -(-key_bits // _radix_digit_bits(digit_bits))
    rt = _radix_tile(tile)
    tiled = -(-n // rt) * rt
    return c.select * batch * tiled * passes + c.xla * batch * k * _log2(k)


def xla_topk_cost_ns(n: int, k: int, batch: int = 1, *,
                     consts: DeviceSortConstants = None) -> float:
    """Estimated ns for the native ``jax.lax.top_k`` lowering on substrates
    where it is a tuned O(n) selection (XLA:CPU): one linear scan plus the
    O(k log k) ordering of the survivors.

    This is the price whose *absence* caused the ROADMAP-flagged ~90x
    auto-dispatch inversion: with the xla candidate priced at the full
    sort-prefix contract, ``auto`` preferred radix-select at n=1M/k=64
    (313ms measured) over the native path (3.4ms).  The k-aware planner
    now asks each backend for its top-k price
    (``SortBackend.topk_cost_ns``) and the xla backend answers with this
    model off-TPU.
    """
    c = consts or _tuning.active().constants
    return c.xla_topk * batch * n + c.xla * batch * k * _log2(k)


def bytes_moved(method: str, n: int, itemsize: int = 4, *,
                key_bits: int = 32, k: int = None,
                run_len: Optional[int] = None,
                digit_bits: Optional[int] = None) -> int:
    """Analytic off-chip bytes one backend moves sorting ``n`` elements —
    the paper's data-movement accounting (Tables I/II count temp-row COPY
    cycles; this counts the software analogue: element reads+writes that
    leave the compute unit's resident tile).

    Comparison sorts move every element once per level; the radix path
    once per digit pass; the VMEM-resident network loads and stores the
    tile exactly once (the in-memory argument); selection's counting
    passes are read-only.  Used by ``benchmarks/emit_bench.py`` to put a
    ``bytes_moved`` column next to every measured ns in BENCH_sort.json.
    """
    if k is not None:
        passes = -(-key_bits // _radix_digit_bits(digit_bits))
        if method == "select":
            return n * itemsize * passes + 2 * k * itemsize
        if method == "xla":            # native scan: one read, k writes
            return n * itemsize + 2 * k * itemsize
        # sort-prefix on any sort backend: full sort + one k-slice read
        return bytes_moved(method, n, itemsize, key_bits=key_bits,
                           run_len=run_len, digit_bits=digit_bits) \
            + k * itemsize
    lvl = _log2(n)
    if method in ("xla", "merge"):
        # merge family: each level reads and writes every element; the
        # engine pays log2(tiles) levels + run generation, ~log2(n) total
        return int(2 * n * itemsize * lvl)
    if method == "bitonic":
        return int(2 * n * itemsize * lvl * lvl)
    if method == "pallas":
        return 2 * n * itemsize        # VMEM-resident: in once, out once
    if method == "radix":
        passes = -(-key_bits // _radix_digit_bits(digit_bits))
        return 2 * n * itemsize * passes
    raise ValueError(f"no bytes-moved model for method {method!r}")


# ---- relational kernels (repro.relational auto-dispatch) ---------------------
#
# Every relational op is priced as (sort backbone) + (O(n) post-pass): the
# survey's framing — group-by/join/dedup are a sorter plus a scan.  The
# post-pass is a handful of elementwise/searchsorted sweeps over the sorted
# column, so its unit price is the measured one-merge-level constant
# (``merge_level``: one O(n) gather-bound pass) times a per-op pass count.
# No new tuning-profile fields: relational pricing reuses the calibrated
# sort constants, so persisted profiles stay schema-stable.

REL_POST_PASSES: Dict[str, float] = {
    "unique": 3.0,     # boundary mask + compaction search + pad
    "group_by": 4.0,   # boundary + compaction + segment reduce (per agg ~1)
    "join": 6.0,       # 2x searchsorted runs + offset scan + pair expansion
    "rle": 3.0,        # boundary + compaction + segment lengths
    "delta": 1.0,      # one adjacent-diff sweep
}

# ops that sort more than one column (join sorts both sides)
REL_SORT_COLUMNS: Dict[str, float] = {"join": 2.0}


def relational_cost_ns(op: str, method: str, n: int, batch: int = 1, *,
                       run_len: Optional[int] = None,
                       key_bits: int = 32,
                       consts: DeviceSortConstants = None,
                       pallas_interpreted: bool = False) -> float:
    """Estimated ns for relational ``op`` over an ``n``-element column with
    its sort backbone on ``method``.

    The planner's ``choose_relational`` prices every auto candidate with
    this — substituting the forced-stable merge pipeline for non-stable
    backends on order-sensitive ops (join pair order, group-by arrival
    ranks) BEFORE calling, since that is what the engine actually executes.
    The sketches are priced too (quantile at its selection contract,
    histogram at one binary-search sweep) so bench tooling can put a
    predicted column next to every measured row, but they take no backend
    override — there is nothing to dispatch.
    """
    c = consts or _tuning.active().constants
    if op == "quantile":
        # bottom-k selection at the median contract (k grows with the
        # highest requested fraction; n/2 is the representative price)
        return selection_cost_ns(n, max(1, n // 2), key_bits, batch,
                                 consts=c)
    if op == "histogram":
        # one searchsorted sweep over the edges + a bincount scatter
        return c.xla * batch * n * _log2(n)
    if op not in REL_POST_PASSES:
        raise ValueError(f"no relational cost model for op {op!r}")
    sort_ns = device_sort_cost_ns(method, n, batch, run_len=run_len,
                                  consts=c, key_bits=key_bits,
                                  pallas_interpreted=pallas_interpreted)
    post = c.merge_level * batch * n * REL_POST_PASSES[op]
    return REL_SORT_COLUMNS.get(op, 1.0) * sort_ns + post


def spill_sort_cost_ns(n: int, batch: int = 1, itemsize: int = 4, *,
                       chunk_bytes: Optional[int] = None,
                       key_bits: int = 32,
                       overlap: bool = True,
                       consts: DeviceSortConstants = None) -> float:
    """Estimated ns for the out-of-core spill tier (``repro.engine.spill``)
    over ``batch`` rows of ``n`` elements.

    Three terms, mirroring the paper's accounting that off-chip movement —
    not compute — dominates once data outgrows the compute unit's memory:

      chunk sorts   ceil(total/chunk) device sorts at the chunk size,
                    priced at the registry's comparison-sort contract
      link transfer every element crosses the host<->device link four
                    times (chunk H2D, run D2H, merge-block H2D, merged
                    D2H) at ``pcie_per_byte``; with double buffering the
                    *spill phase's* half overlaps the chunk sorts, so
                    the overlapped pipeline pays max(sorts, spill-xfer)
                    instead of their sum
      host merge    ceil(log2(chunks)) effective fan-in levels of host
                    cursor partitioning + device block merges at
                    ``host_merge_level`` per element

    ``chunk_bytes`` defaults to the active profile's
    ``spill_threshold_bytes`` — the same knob the planner routes on.
    """
    c = consts or _tuning.active().constants
    cb = chunk_bytes if chunk_bytes is not None \
        else _tuning.active().spill_threshold_bytes
    chunk = max(1, cb // max(1, itemsize))
    total = n * batch
    n_chunks = max(1, -(-total // chunk))
    per_chunk = device_sort_cost_ns("xla", min(chunk, total), consts=c,
                                    key_bits=key_bits)
    sort_ns = n_chunks * per_chunk
    spill_xfer = 2.0 * total * itemsize * c.pcie_per_byte   # H2D + D2H
    merge_xfer = 2.0 * total * itemsize * c.pcie_per_byte   # blocks in/out
    pipeline = max(sort_ns, spill_xfer) if overlap else sort_ns + spill_xfer
    levels = _log2(n_chunks) if n_chunks > 1 else 0.0
    merge_ns = c.host_merge_level * total * levels
    return pipeline + merge_xfer + merge_ns


def collective_cost_ns(n_dev: int, m: int, itemsize: int,
                       consts: DeviceSortConstants = None, *,
                       alpha: Optional[float] = None,
                       per_byte: Optional[float] = None) -> float:
    """Estimated ns for ONE collective round in which every device
    exchanges ``n_dev`` shards of ``m`` elements.

    ``n_dev=1`` prices a neighbour ppermute (odd-even transposition pays D
    of these); ``n_dev=D`` prices a capacity-padded all-to-all (sample-sort
    pays two: the bucket exchange and the rank rebalance).  This is the
    cluster-scale Eq. 3-4 term: temp-row operand movement priced per
    exchange, with the strategy choice reducing to *how many exchanges*.

    ``alpha``/``per_byte`` override the link rates per call — this is the
    two-tier hook: the planner prices ICI-only rounds with the profile's
    default rates and DCN / mixed rounds with a ``Topology`` axis's
    measured ones (see :func:`flat_collective_rates` and
    :func:`hierarchical_sort_cost_ns`).
    """
    c = consts or _tuning.active().constants
    a = alpha if alpha is not None else c.collective_alpha
    b = per_byte if per_byte is not None else c.collective_per_byte
    return a + b * n_dev * m * itemsize


def flat_collective_rates(inner: int, outer: int, *,
                          consts: DeviceSortConstants = None,
                          ici_alpha: Optional[float] = None,
                          ici_per_byte: Optional[float] = None,
                          dcn_alpha: Optional[float] = None,
                          dcn_per_byte: Optional[float] = None
                          ) -> Tuple[float, float]:
    """(alpha, per_byte) a FLAT all-to-all effectively pays on a two-tier
    ``outer x inner`` mesh.

    With destinations spread uniformly over ``D = outer*inner`` devices, a
    fraction ``(outer-1)/outer`` of every device's exchanged bytes crosses
    the slow outer (DCN) tier and the rest stays on ICI — so the flat
    round runs at the traffic-weighted blend of the two per-byte rates,
    and its launch latency is the slower tier's (the round completes when
    the slowest link does).  ``outer <= 1`` degrades to pure ICI.
    """
    c = consts or _tuning.active().constants
    ia = ici_alpha if ici_alpha is not None else c.collective_alpha
    ib = ici_per_byte if ici_per_byte is not None else c.collective_per_byte
    da = dcn_alpha if dcn_alpha is not None else c.dcn_alpha
    db = dcn_per_byte if dcn_per_byte is not None else c.dcn_per_byte
    if outer <= 1:
        return ia, ib
    f_dcn = (outer - 1) / outer
    return max(ia, da), ib * (1.0 - f_dcn) + db * f_dcn


def distributed_sort_cost_ns(strategy: str, n: int, n_dev: int,
                             itemsize: int = 4, *,
                             consts: DeviceSortConstants = None,
                             alpha: Optional[float] = None,
                             per_byte: Optional[float] = None) -> float:
    """Estimated ns to globally sort ``n`` elements over ``n_dev`` devices.

    Both strategies pay the same local shard sort; they differ in movement
    and merge structure:

      oddeven   D rounds x (one shard ppermute + a 2m bitonic merge box)
      sample    2 all-to-alls + one merge-path tree over the received runs

    so odd-even wins at small (n, D) on collective launch count and sample
    wins once the per-round merge work dominates — the planner picks the
    winner per workload (``planner.choose_distributed``).

    ``alpha``/``per_byte`` override the collective link rates (see
    :func:`collective_cost_ns`): on a hierarchical mesh the planner prices
    the flat strategies at the blended two-tier rate from
    :func:`flat_collective_rates`.
    """
    c = consts or _tuning.active().constants
    m = -(-n // n_dev)
    local = c.xla * m * _log2(m)
    if strategy == "oddeven":
        round_merge = c.bitonic * (2 * m) * _log2(2 * m)
        return local + n_dev * (
            collective_cost_ns(1, m, itemsize, c,
                               alpha=alpha, per_byte=per_byte)
            + round_merge)
    if strategy == "sample":
        # r*m·log r aggregates the capacity-padded exchange staging and
        # merge tree over received runs; + m covers the rank-rebalance
        # shard materialisation — fitted so the modeled crossover matches
        # the measured one (README §Distributed sort)
        r = 1 << max(0, (n_dev - 1).bit_length())
        merge = c.merge_level * ((r * m) * (_log2(r) if r > 1 else 0.0) + m)
        return local + 2 * collective_cost_ns(n_dev, m, itemsize, c,
                                              alpha=alpha,
                                              per_byte=per_byte) + merge
    raise ValueError(
        f"no distributed cost model for strategy {strategy!r}")


def hierarchical_sort_cost_ns(n: int, inner: int, outer: int,
                              itemsize: int = 4, *,
                              consts: DeviceSortConstants = None,
                              ici_alpha: Optional[float] = None,
                              ici_per_byte: Optional[float] = None,
                              dcn_alpha: Optional[float] = None,
                              dcn_per_byte: Optional[float] = None) -> float:
    """Estimated ns for the two-level hierarchical sample-sort over an
    ``outer x inner`` mesh (``outer`` hosts on DCN, ``inner`` devices per
    host on ICI) — the distributed analogue of the paper's partition /
    temp-row structure, restructured around the link hierarchy the way
    Mutlu et al. prescribe.

    Four terms:

      local        one m·log m shard sort (identical to the flat path's)
      merge        the SAME fitted r·m·log r staging/merge aggregate the
                   flat ``sample`` strategy pays: both schedules merge
                   every element through ~log D tree levels in total —
                   the hierarchy redistributes the levels across rounds,
                   it does not add asymptotic merge work.  Pricing it
                   identically makes the flat-vs-hier decision hinge on
                   MOVEMENT, the paper's actual claim.
      intra rounds ICI confinement costs three inner-way all-to-alls:
                   the opening exchange, the intra-host rebalance, and
                   the finalize exchange after the DCN round (each host
                   receives its key range spread over its devices with
                   no inter-device order, so one more splitter round
                   must restore it).
      inter round  ONE outer-way bucket all-to-all at the DCN rate (the
                   second splitter round — splitters travel by
                   all-gather, priced into the launch term).
      rebalance    the final rank-directed shard materialisation.  With
                   balanced global splitters almost every element's final
                   rank lands on its own host, so the exchange volume runs
                   at the ICI rate plus an O(m) cross-host spill at the
                   DCN rate — this locality is exactly why the
                   hierarchical structure beats the flat all-to-all when
                   DCN is the bottleneck, and why it loses (three ICI
                   rounds of pure overhead) when the tiers are uniform.
    """
    c = consts or _tuning.active().constants
    ia = ici_alpha if ici_alpha is not None else c.collective_alpha
    ib = ici_per_byte if ici_per_byte is not None else c.collective_per_byte
    da = dcn_alpha if dcn_alpha is not None else c.dcn_alpha
    db = dcn_per_byte if dcn_per_byte is not None else c.dcn_per_byte
    d = max(1, inner) * max(1, outer)
    m = -(-n // d)
    local = c.xla * m * _log2(m)
    r = 1 << max(0, (d - 1).bit_length())
    merge = c.merge_level * ((r * m) * (_log2(r) if r > 1 else 0.0) + m)
    intra = 3 * collective_cost_ns(inner, m, itemsize, c,
                                   alpha=ia, per_byte=ib)
    inter = collective_cost_ns(outer, m, itemsize, c,
                               alpha=da, per_byte=db)
    rebalance = max(ia, da) + ib * d * m * itemsize + db * m * itemsize
    return local + merge + intra + inter + rebalance


# ---- report helpers ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PaperClaims:
    """Every quantitative claim we validate, with model + paper values."""
    rows: tuple

    def all_pass(self) -> bool:
        return all(abs(m - p) <= tol for (_, m, p, tol) in self.rows)


def validate_claims() -> PaperClaims:
    rows = (
        ("Eq1 N_CAS(8)", network.n_cas_blocks(8), 24, 0),
        ("Eq2 N_stages(8)", network.n_stages(8), 6, 0),
        ("Eq3 temp rows(8)", network.n_temp_rows(8), 2, 0),
        ("Eq4 movement cycles per exchange(8)", network.movement_cycles(8), 6, 0),
        ("CAS cycles (W=4)", cas_cycles(4), 28, 0),
        ("reconstructed CAS program cycles (W=4)",
         cas.cached_program(4).total_cycles, 28, 0),
        ("total movement cycles (N=8)", network.total_extra_cycles(8), 24, 0),
        ("sort cycles (N=8, W=4)", sort_cycles(8), 192, 0),
        ("Table I NOR total (N=8)", stage_op_totals(8)["NOR"], 84, 0),
        ("Table I NOT total (N=8)", stage_op_totals(8)["NOT"], 48, 0),
        ("Table I AND total (N=8)", stage_op_totals(8)["AND"], 18, 0),
        ("Table I COPY total (N=8)", stage_op_totals(8)["COPY"], 42, 0),
        ("Table II latency ns", sort_latency_ns(8), 105.6, 1e-9),
        ("Table II throughput GOPS", throughput_gops(8), 1.8, 0.02),
        ("Table II frequency GHz", OPERATING_FREQ_GHZ, 1.81, 0.01),
        ("array geometry rows (W=4)", cas.cached_program(4).n_rows, 22, 0),
        ("Fig8a MemSort cycle ratio", memsort_cycles(8) / sort_cycles(8), 1.45, 1e-12),
        ("Fig8b MemSort latency ratio",
         memsort_latency_ns(8) / sort_latency_ns(8), 3.4, 1e-12),
        ("off-memory latency ratio",
         off_memory_latency_ns(8) / sort_latency_ns(8), 5.0, 1e-12),
    )
    return PaperClaims(rows=rows)
