"""Order-preserving key codec — the radix front-end every ordered path shares.

The paper sorts "in the standard weighted binary radix format" (§II), which
is only directly true of *unsigned* integers: two's-complement negatives and
IEEE-754 floats compare differently from their raw bit patterns.  The fix is
the classic pair of monotone bijections (the same front-end MemSort-style
designs and the hardware-sorting literature assume):

  signed int   flip the sign bit          (biased / excess-2^(b-1) code)
  float        sign-magnitude -> lexicographic: negative values flip ALL
               bits, non-negative values flip only the sign bit

Both are bijections on the b-bit patterns, so ``decode(encode(x)) == x``
bit-exactly, and both are strictly monotone:

  x < y  (in the source dtype's order)  <=>  encode(x) < encode(y)  (unsigned)

which is exactly what a radix / bit-serial comparator needs.  ``descending``
complements the encoded key — an order-*reversing* bijection — so a single
ascending, stable radix sort serves both directions while ties keep
ascending index order (the engine's tie convention).

Supported dtypes: uint8/16/32, int8/16/32, float16, bfloat16, float32.

Caveats (matching the repo's kernel conventions):
  * NaN-free floats assumed (like the bitonic / merge-path kernels).  If
    present, positive NaNs encode above +inf and negative NaNs below -inf,
    not to one end like ``jnp.sort``.
  * The float code is a *total* order refining IEEE equality: -0.0 encodes
    strictly below +0.0 (numerically equal either way).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# source dtype name -> (bits, unsigned carrier dtype name, kind)
_TABLE = {
    "uint8": (8, "uint8", "u"),
    "uint16": (16, "uint16", "u"),
    "uint32": (32, "uint32", "u"),
    "int8": (8, "uint8", "i"),
    "int16": (16, "uint16", "i"),
    "int32": (32, "uint32", "i"),
    "float16": (16, "uint16", "f"),
    "bfloat16": (16, "uint16", "f"),
    "float32": (32, "uint32", "f"),
}

SUPPORTED = tuple(_TABLE)


def supports(dtype) -> bool:
    """True if ``dtype`` has an order-preserving unsigned encoding here."""
    return jnp.dtype(dtype).name in _TABLE


def key_bits(dtype) -> int:
    """Radix key width in bits for ``dtype`` (== its storage width)."""
    return _entry(dtype)[0]


def key_dtype(dtype):
    """The unsigned carrier dtype the encoded keys live in."""
    return jnp.dtype(_entry(dtype)[1])


def _entry(dtype):
    name = jnp.dtype(dtype).name
    if name not in _TABLE:
        raise ValueError(
            f"keycodec supports {SUPPORTED}, got {name!r}")
    return _TABLE[name]


def _masks(bits: int, udtype):
    sign = jnp.array(1 << (bits - 1), udtype)
    full = jnp.array((1 << bits) - 1, udtype)
    return sign, full


def encode(x: jnp.ndarray, *, descending: bool = False) -> jnp.ndarray:
    """Map ``x`` to unsigned keys whose ``<`` matches the source order.

    With ``descending=True`` the key is complemented, so ascending key order
    is descending source order (stability / tie order is unaffected: equal
    inputs still map to equal keys).
    """
    bits, uname, kind = _entry(x.dtype)
    udtype = jnp.dtype(uname)
    u = x if x.dtype == udtype else jax.lax.bitcast_convert_type(x, udtype)
    sign, full = _masks(bits, udtype)
    if kind == "i":
        u = u ^ sign
    elif kind == "f":
        neg = jax.lax.shift_right_logical(u, jnp.array(bits - 1, udtype)) != 0
        u = u ^ jnp.where(neg, full, sign)
    if descending:
        u = u ^ full
    return u


def composite_index_bits(n: int) -> int:
    """Index bits an argsort composite needs for row length ``n``."""
    return max(1, (n - 1).bit_length())


def composite_fits(dtype, n: int) -> bool:
    """Can an (encoded key, index) composite for ``dtype`` rows of length
    ``n`` pack into one 32-bit word?"""
    return key_bits(dtype) + composite_index_bits(n) <= 32


def argsort_composite(x: jnp.ndarray, *, descending: bool = False):
    """Pack ``x`` into unique uint32 (encoded key << idx_bits) | index
    composites -> ``(composite, idx_bits)``.

    Sorting the composites ascending yields the engine's argsort tie
    convention on any *unstable* value sorter — ties keep ascending index
    order in both directions, because ``descending`` complements only the
    key bits while the index bits always ascend.  Shared by the imc
    bit-serial path and the distributed backend (both sort values, not
    permutations); the sorted composite's low bits are the permutation.
    """
    n = x.shape[-1]
    idx_bits = composite_index_bits(n)
    if not composite_fits(x.dtype, n):
        raise ValueError(
            f"argsort (key, index) composite packs into one 32-bit word: "
            f"key_bits({jnp.dtype(x.dtype).name})={key_bits(x.dtype)} + "
            f"index bits({n})={idx_bits} exceeds 32; use a narrower key "
            f"dtype or a smaller n")
    enc = encode(x, descending=descending).astype(jnp.uint32)
    idx = jnp.arange(n, dtype=jnp.uint32)
    return (enc << idx_bits) | jnp.broadcast_to(idx, enc.shape), idx_bits


def decode(keys: jnp.ndarray, dtype, *, descending: bool = False
           ) -> jnp.ndarray:
    """Inverse of :func:`encode`: unsigned keys back to ``dtype``, bit-exact."""
    bits, uname, kind = _entry(dtype)
    udtype = jnp.dtype(uname)
    if keys.dtype != udtype:
        raise ValueError(
            f"keys for {jnp.dtype(dtype).name} must be {uname}, "
            f"got {keys.dtype.name}")
    sign, full = _masks(bits, udtype)
    u = keys ^ full if descending else keys
    if kind == "i":
        u = u ^ sign
    elif kind == "f":
        # encoded non-negatives have the top bit set; negatives had all
        # bits flipped, so their encoded top bit is clear
        top = jax.lax.shift_right_logical(u, jnp.array(bits - 1, udtype)) != 0
        u = u ^ jnp.where(top, sign, full)
    dtype = jnp.dtype(dtype)
    return u if dtype == udtype else jax.lax.bitcast_convert_type(u, dtype)
