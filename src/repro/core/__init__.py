"""ADS-IMC core: in-memory sorting as a composable JAX feature.

API v2 lives in :mod:`repro.core.sortspec` (SortSpec + backend registry)
with the front door in :mod:`repro.sort`; the re-exported ``sort`` /
``argsort`` / ``topk`` here are the v1 shims kept for compatibility.
"""
from repro.core.sort_api import sort, argsort, topk, top_p_mask, bitonic_sort
from repro.core.sortspec import (Capabilities, SortBackend, SortSpec,
                                 register_backend, sort_defaults)
from repro.core import network, cost_model

__all__ = ["sort", "argsort", "topk", "top_p_mask", "bitonic_sort",
           "Capabilities", "SortBackend", "SortSpec", "register_backend",
           "sort_defaults", "network", "cost_model"]
