"""ADS-IMC core: in-memory sorting as a composable JAX feature."""
from repro.core.sort_api import sort, argsort, topk, top_p_mask, bitonic_sort
from repro.core import network, cost_model

__all__ = ["sort", "argsort", "topk", "top_p_mask", "bitonic_sort",
           "network", "cost_model"]
