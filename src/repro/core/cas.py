"""Execute the in-memory Compare-And-Swap block on the simulated array.

``run_cas`` is the faithful path: operands are written into rows A/B of a
fresh IMC array, the 28-cycle gate program of :mod:`repro.core.gates` runs
one op per cycle, and (min, max) are read back from rows A/B — exactly the
paper's §II-A contract (min in row 3 at cycle 28, max in row 4 at cycle 27).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import gates, imc_array


@dataclasses.dataclass(frozen=True)
class CASResult:
    lo: jnp.ndarray           # elementwise min(a, b)
    hi: jnp.ndarray           # elementwise max(a, b)
    cycles: int
    op_counts: dict


@functools.lru_cache(maxsize=None)
def cached_program(width: int) -> gates.CASProgram:
    return gates.build_cas_program(width)


def _run(a: jnp.ndarray, b: jnp.ndarray, width: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    prog = cached_program(width)
    batch = a.shape[0]
    state = imc_array.make_array(batch, prog.n_rows, width)
    state = imc_array.write_word(state, imc_array.ROW_A,
                                 imc_array.int_to_bits(a, width))
    state = imc_array.write_word(state, imc_array.ROW_B,
                                 imc_array.int_to_bits(b, width))
    state = imc_array.run_program(state, prog.ops)
    lo = imc_array.bits_to_int(imc_array.read_word(state, imc_array.ROW_A))
    hi = imc_array.bits_to_int(imc_array.read_word(state, imc_array.ROW_B))
    return lo, hi


_run_jit = jax.jit(_run, static_argnums=2)


def run_cas(a, b, width: int = 4, jit: bool = True) -> CASResult:
    """Compare-and-swap batches of unsigned ``width``-bit ints in-memory.

    Args:
      a, b: (batch,) unsigned integer arrays, values < 2**width.
    Returns:
      CASResult with lo=min, hi=max per element plus exact cycle accounting.
    """
    a = jnp.asarray(a, dtype=jnp.uint32)
    b = jnp.asarray(b, dtype=jnp.uint32)
    prog = cached_program(width)
    counter = imc_array.CycleCounter()
    for op in prog.ops:           # static accounting (data-independent)
        counter.count(op.kind)
    lo, hi = (_run_jit if jit else _run)(a, b, width)
    return CASResult(lo=lo, hi=hi, cycles=counter.total,
                     op_counts=counter.as_dict())
