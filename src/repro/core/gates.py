"""Two-input gate programs for the in-memory CAS block (paper Fig. 3-5).

The paper's 6T SRAM IMC substrate cannot run 3/4-input gates (data-flipping
issue, [13]), so the comparator and multiplexers are compiled to two-input
NOR/AND plus derived NOT/COPY, executed one op per cycle over all columns.

This is a *reconstruction*: the text names gate outputs (G29,16 / G30,17 /
G31,18) and gives phase totals, but not the full netlist.  The program built
here matches the paper's structure exactly for W=4 (see DESIGN.md §6):

  * 22 rows   (constants in rows 1-2, A/B in rows 3-4 — paper Fig. 5 is 4x22)
  * compare phase = 18 cycles; the comparison result is broadcast to all
    columns in cycle 17 (paper: G30,17) and its inverse — the mux select —
    is produced in cycle 18 (paper: G31,18)
  * mux phase = 8 cycles (cycles 19-26), reusing compare-phase rows
  * max written to row B in cycle 27, min to row A in cycle 28 (paper §II-A)
  * 28 cycles total (Table I)

Our op MIX differs from Table I (we count NOR 11 / NOT 4 / AND 4 / COPY 9 vs
the paper's 14/8/3/3) because the netlist is under-specified; every REPORTED
number in the cost model uses the paper's published counts (cost_model.py),
and the delta is recorded in EXPERIMENTS.md.

Widths other than 4 are supported as clearly-marked extrapolations: the
comparator prefix/reduction depth grows with W under the paper's
adjacent-column-copy constraint.

Comparator math (column 0 = MSB, as in the paper's A = A0 A1 A2 A3):

    e_i  = XNOR(A_i, B_i)                 bitwise equality
    l_i  = ~A_i & B_i                     A < B decided at bit i
    P_i  = prod_{j<i} e_j                 all more-significant bits equal
    s    = OR_i (l_i & P_i)  =  (A < B)

    min  = NOR(NOR(A, ~s), NOR(B, s))     3-NOR mux (select = s)
    max  = NOR(NOR(A, s), NOR(B, ~s))
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

from repro.core.imc_array import Movement, Op, OpKind, ROW_A, ROW_B


@dataclasses.dataclass(frozen=True)
class CASProgram:
    width: int
    ops: List[Op]
    n_rows: int
    compare_cycles: int      # cycles until both s and ~s rows are final
    mux_cycles: int
    writeback_cycles: int
    row_s: int               # row holding s = (A < B), broadcast to all cols
    row_ns: int              # row holding ~s

    @property
    def total_cycles(self) -> int:
        return len(self.ops)


class _RowAlloc:
    """Sequential scratch-row allocator starting after the 4 base rows."""

    def __init__(self) -> None:
        self.next = ROW_B + 1
        self.high_water = self.next

    def new(self) -> int:
        row = self.next
        self.next += 1
        self.high_water = max(self.high_water, self.next)
        return row


def build_cas_program(width: int = 4) -> CASProgram:
    if width < 2 or (width & (width - 1)) != 0:
        raise ValueError(f"width must be a power of two >= 2, got {width}")
    ops: List[Op] = []
    rows = _RowAlloc()

    def emit(kind: OpKind, src1: int, src2=None, movement=Movement.SAME,
             fill: int = 0, bcast_col: int = 0, label: str = "",
             dst=None) -> int:
        d = rows.new() if dst is None else dst
        ops.append(Op(kind=kind, src1=src1, src2=src2, dst=d,
                      movement=movement, fill=fill, bcast_col=bcast_col,
                      label=label))
        return d

    # ---- compare phase -----------------------------------------------------
    nab = emit(OpKind.NOR, ROW_A, ROW_B, label="nab = ~(A|B)")
    aab = emit(OpKind.AND, ROW_A, ROW_B, label="aab = A&B")
    x = emit(OpKind.NOR, nab, aab, label="x = XOR(A,B)")
    e = emit(OpKind.NOT, x, label="e = XNOR(A,B)")
    nb = emit(OpKind.NOT, ROW_B, label="nb = ~B")
    l = emit(OpKind.NOR, ROW_A, nb, label="l = ~A & B")

    # exclusive prefix-AND of e via adjacent right-copies (movement type b)
    cur = emit(OpKind.COPY, e, movement=Movement.SHIFT_RIGHT, fill=1,
               label="t = e >> 1 (fill 1)")
    for r in range(width - 2):
        shifted = emit(OpKind.COPY, cur, movement=Movement.SHIFT_RIGHT,
                       fill=1, label=f"prefix shift r{r}")
        cur = emit(OpKind.AND, cur, shifted, label=f"prefix and r{r}")
    prefix = cur

    lt = emit(OpKind.AND, l, prefix, label="lt_i = l_i & P_i")

    # OR-reduce lt over columns; result (inverted) broadcast in the final NOR.
    levels = int(math.log2(width))
    cur = lt
    for k in range(levels - 1):
        part = cur
        for _ in range(1 << k):
            part = emit(OpKind.COPY, part, movement=Movement.SHIFT_RIGHT,
                        fill=0, label=f"or-reduce shift k{k}")
        inv = emit(OpKind.NOR, cur, part, label=f"or-reduce nor k{k}")
        cur = emit(OpKind.NOT, inv, label=f"or-reduce restore k{k}")
    if levels >= 1:
        if width == 2:
            # single final combine straight from lt's two columns
            part = emit(OpKind.COPY, cur, movement=Movement.SHIFT_RIGHT,
                        fill=0, label="final shift (W=2)")
        else:
            # the other half's OR sits in column W/2 - 1: movement type (d)
            part = emit(OpKind.COPY, cur, movement=Movement.BCAST_COL,
                        bcast_col=width // 2 - 1,
                        label="bcast interior column (movement d)")
        row_ns = emit(OpKind.NOR, cur, part, movement=Movement.BCAST_LAST,
                      label="~s broadcast to all columns (G30)")
    row_s = emit(OpKind.NOT, row_ns, label="s = A<B (G31)")
    compare_cycles = len(ops)

    # ---- mux phase (reuses compare scratch rows, paper §II-A) --------------
    mux_rows = iter(range(ROW_B + 1, ROW_B + 1 + 8))

    def memit(kind, src1, src2=None, label="") -> int:
        d = next(mux_rows)
        assert d not in (row_s, row_ns), "mux must not clobber select rows"
        ops.append(Op(kind=kind, src1=src1, src2=src2, dst=d, label=label))
        return d

    u = memit(OpKind.NOR, ROW_A, row_ns, label="u = NOR(A,~s)")
    v = memit(OpKind.NOR, ROW_B, row_s, label="v = NOR(B,s)")
    mn = memit(OpKind.NOR, u, v, label="min = NOR(u,v)")
    u2 = memit(OpKind.NOR, ROW_A, row_s, label="u2 = NOR(A,s)")
    v2 = memit(OpKind.NOR, ROW_B, row_ns, label="v2 = NOR(B,~s)")
    mx = memit(OpKind.NOR, u2, v2, label="max = NOR(u2,v2)")
    stg_mx = memit(OpKind.COPY, mx, label="stage max")
    stg_mn = memit(OpKind.COPY, mn, label="stage min")
    mux_cycles = len(ops) - compare_cycles

    # ---- write-back (paper: max -> row 4 @ cycle 27, min -> row 3 @ 28) ----
    ops.append(Op(OpKind.COPY, stg_mx, dst=ROW_B, label="max -> row B (c27)"))
    ops.append(Op(OpKind.COPY, stg_mn, dst=ROW_A, label="min -> row A (c28)"))
    writeback_cycles = 2

    return CASProgram(width=width, ops=ops, n_rows=rows.high_water,
                      compare_cycles=compare_cycles, mux_cycles=mux_cycles,
                      writeback_cycles=writeback_cycles,
                      row_s=row_s, row_ns=row_ns)
