"""Public sorting API — the paper's technique as a composable JAX feature.

One entry point, six interchangeable backends:

  ``xla``      jnp.sort / jax.lax.top_k — the "off-memory" reference point.
  ``bitonic``  the paper's Batcher network executed word-parallel in pure
               jnp (every CAS = vector min/max). Beyond-paper: lifts the
               bit-serial constraint, keeps the oblivious schedule.
  ``pallas``   the in-VMEM Pallas kernel (kernels/bitonic_sort.py): tiles are
               read from HBM once, the whole network runs on VMEM-resident
               data — the TPU analogue of "sorting inside the memory array".
  ``imc``      the faithful bit-serial simulation (core/sorter.py): the
               28-cycle gate program on the simulated 6T SRAM array.
               Small unsigned ints only; used for validation and benchmarks.
  ``merge``    the hierarchical out-of-core engine (repro.engine): tiled run
               generation + merge-path merge tree for arrays bigger than one
               VMEM tile — O(n log n) work where the whole-array network
               pays O(n log^2 n).
  ``auto``     cost-model dispatch (repro.engine.planner): picks the
               cheapest *valid* backend from (n, batch, dtype).

Everything downstream (MoE routing, sampling, serving schedulers) calls
through this module, so the paper's contribution is a first-class,
swappable component of the framework.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

METHODS = ("xla", "bitonic", "pallas", "imc", "merge", "auto")


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _pad_value(dtype, descending: bool):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if descending else jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if descending else info.max, dtype)


def bitonic_sort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
                 values: Optional[jnp.ndarray] = None):
    """Word-parallel bitonic sort along ``axis`` (optionally carrying a
    values array, sorted by the keys — used for argsort / routing).

    Runs the reshape-addressed network (kernels/bitonic_sort.py) rather than
    a gather-per-substage formulation: long chains of 1-D gathers send XLA's
    CPU pipeline into a pathological simplification loop (minutes-to-never
    compiles for n as small as 256), while the (n/(2j), 2, j) reshape view
    compiles in seconds and is exactly what the Pallas kernel executes.
    """
    from repro.kernels.bitonic_sort import _apply_network, _apply_network_kv
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    if values is not None:
        values = jnp.moveaxis(values, axis, -1)
    lead = x.shape[:-1]
    n = x.shape[-1]
    m = _next_pow2(n)
    if m != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, m - n)]
        x = jnp.pad(x, pad, constant_values=_pad_value(x.dtype, descending))
        if values is not None:
            values = jnp.pad(values, pad)
    rows = x.reshape(-1, m)
    if values is not None:
        sk, sv = _apply_network_kv(rows, values.reshape(-1, m), descending)
        sk = sk.reshape(*lead, m)[..., :n]
        sv = sv.reshape(*lead, m)[..., :n]
        return jnp.moveaxis(sk, -1, axis), jnp.moveaxis(sv, -1, axis)
    out = _apply_network(rows, descending).reshape(*lead, m)[..., :n]
    return jnp.moveaxis(out, -1, axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _xla_sort(x, axis: int, descending: bool):
    """jnp.sort with a permutation-transpose VJP.

    This environment's jax build has a broken `_sort_jvp` (constructs
    GatherDimensionNumbers with batching fields its NamedTuple lacks), so
    differentiating through raw lax.sort raises.  A sort is a permutation,
    so the correct cotangent is a scatter by the argsort order — implemented
    here with flat indices, bypassing the broken path entirely.
    """
    out = jnp.sort(x, axis=axis)
    return jnp.flip(out, axis=axis) if descending else out


def _xla_sort_fwd(x, axis, descending):
    order = jnp.argsort(x, axis=axis, stable=True)
    if descending:
        order = jnp.flip(order, axis=axis)
    out = jnp.take_along_axis(x, order, axis=axis)
    return out, order


def _xla_sort_bwd(axis, descending, order, g):
    ax = axis % order.ndim
    go = jnp.moveaxis(g, ax, -1)
    oo = jnp.moveaxis(order, ax, -1)
    lead = go.shape[:-1]
    n = go.shape[-1]
    go2 = go.reshape(-1, n)
    oo2 = oo.reshape(-1, n)
    rows = go2.shape[0]
    flat_idx = (jnp.arange(rows, dtype=jnp.int32)[:, None] * n + oo2).reshape(-1)
    gx = jnp.zeros(rows * n, dtype=g.dtype).at[flat_idx].add(go2.reshape(-1))
    return (jnp.moveaxis(gx.reshape(*lead, n), -1, ax),)


_xla_sort.defvjp(_xla_sort_fwd, _xla_sort_bwd)


def sort(x: jnp.ndarray, *, axis: int = -1, method: str = "xla",
         descending: bool = False) -> jnp.ndarray:
    """Sort along ``axis`` with the selected backend."""
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if method == "xla":
        return _xla_sort(x, axis, descending)
    if method == "bitonic":
        return bitonic_sort(x, axis=axis, descending=descending)
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.bitonic_sort(x, axis=axis, descending=descending)
    if method in ("merge", "auto"):
        from repro import engine
        return engine.sort(x, axis=axis, descending=descending, method=method)
    # method == "imc": faithful bit-serial simulation, unsigned ints only
    from repro.core import sorter
    if axis not in (-1, x.ndim - 1):
        raise ValueError("imc method sorts along the last axis only")
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise ValueError("imc method requires unsigned integer inputs")
    width = _imc_width(x)
    lead = x.shape[:-1]
    res = sorter.sort_in_memory(x.reshape(-1, x.shape[-1]), width=width)
    out = res.values.reshape(*lead, x.shape[-1]).astype(x.dtype)
    return jnp.flip(out, axis=-1) if descending else out


def _imc_width(x) -> int:
    bits = jnp.iinfo(x.dtype).bits if jnp.issubdtype(x.dtype, jnp.integer) else 32
    return min(bits, 32)


def argsort(x: jnp.ndarray, *, axis: int = -1, method: str = "xla",
            descending: bool = False) -> jnp.ndarray:
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if method == "xla":
        order = jnp.argsort(x, axis=axis)
        return jnp.flip(order, axis=axis) if descending else order
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.bitonic_argsort(x, axis, descending)
    if method == "imc":
        raise NotImplementedError(
            "imc is a bit-serial validation backend; use sort() on ints")
    if method in ("merge", "auto"):
        from repro import engine
        return engine.argsort(x, axis=axis, descending=descending,
                              method=method)
    n = x.shape[axis % x.ndim]
    idx = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32).reshape(
            (1,) * (axis % x.ndim) + (n,) + (1,) * (x.ndim - 1 - axis % x.ndim)),
        x.shape)
    _, order = bitonic_sort(x, axis=axis, descending=descending, values=idx)
    return order


def topk(x: jnp.ndarray, k: int, *, method: str = "xla",
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k along the last axis -> (values, indices), descending.

    This is the routing/sampling entry point: MoE expert selection and
    top-k sampling both come through here.
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if method == "xla":
        return jax.lax.top_k(x, k)
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.bitonic_topk(x, k)
    if method == "imc":
        raise NotImplementedError(
            "imc is a bit-serial validation backend; use sort() on ints")
    if method in ("merge", "auto"):
        from repro import engine
        return engine.topk(x, k, method=method)
    n = x.shape[-1]
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), x.shape)
    sx, si = bitonic_sort(x, axis=-1, descending=True, values=idx)
    return sx[..., :k], si[..., :k]


def top_p_mask(logits: jnp.ndarray, p: float, *, method: str = "bitonic"
               ) -> jnp.ndarray:
    """Nucleus-sampling mask: True for logits inside the top-p mass.

    Requires a descending sort of the probabilities — i.e. the paper's
    workload sitting directly on the serving path.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    sorted_probs = sort(probs, axis=-1, method=method, descending=True)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # number of entries needed to reach mass p
    keep_sorted = cum - sorted_probs < p
    kth = jnp.sum(keep_sorted, axis=-1, keepdims=True)  # count kept
    threshold = jnp.take_along_axis(sorted_probs, jnp.maximum(kth - 1, 0),
                                    axis=-1)
    return probs >= threshold
