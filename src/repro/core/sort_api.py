"""Legacy public sorting API — deprecation shims over ``repro.sort``.

This module was the original string-dispatched front door.  The system's
API v2 (see README §API v2) replaces it with one spec-driven entry point:

  * :mod:`repro.core.sortspec` — ``SortSpec`` + the ``SortBackend``
    registry (``@register_backend``): backends declare Capabilities and the
    planner dispatches from those declarations alone.
  * :mod:`repro.sort` — ``run(spec, x)`` plus ``sort`` / ``argsort`` /
    ``topk`` / ``sort_kv`` / ``segment_sort`` wrappers and the
    ``sort_defaults`` ambient-configuration context.

Every historical call form here still works and forwards to a spec, so
downstream code migrates at its own pace; new code should import
``repro.sort`` directly.  The implementation pieces other modules share —
the word-parallel bitonic network entry and the grad-safe XLA sort — stay
here, un-deprecated (kernels and backends import them).

Tie convention (unchanged): ``argsort`` ties keep *ascending* index order
in both directions on every backend.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# kept for backwards compatibility: the v1 method strings ("auto" plus the
# built-in backends).  The live list is repro.core.sortspec.backend_names().
METHODS = ("xla", "bitonic", "pallas", "imc", "merge", "radix", "auto")

_warned: set = set()


def _deprecated(name: str) -> None:
    if name not in _warned:
        _warned.add(name)
        warnings.warn(
            f"repro.core.sort_api.{name} is deprecated; use "
            f"repro.sort.{name} (SortSpec front door) instead",
            DeprecationWarning, stacklevel=3)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _pad_value(dtype, descending: bool):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if descending else jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if descending else info.max, dtype)


def bitonic_sort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
                 values: Optional[jnp.ndarray] = None):
    """Word-parallel bitonic sort along ``axis`` (optionally carrying a
    values array, sorted by the keys — used for argsort / routing).

    Runs the reshape-addressed network (kernels/bitonic_sort.py) rather than
    a gather-per-substage formulation: long chains of 1-D gathers send XLA's
    CPU pipeline into a pathological simplification loop (minutes-to-never
    compiles for n as small as 256), while the (n/(2j), 2, j) reshape view
    compiles in seconds and is exactly what the Pallas kernel executes.
    """
    from repro.kernels.bitonic_sort import _apply_network, _apply_network_kv
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    if values is not None:
        values = jnp.moveaxis(values, axis, -1)
    lead = x.shape[:-1]
    n = x.shape[-1]
    m = _next_pow2(n)
    if m != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, m - n)]
        x = jnp.pad(x, pad, constant_values=_pad_value(x.dtype, descending))
        if values is not None:
            # out-of-range marker: pad keys can tie genuine extreme keys,
            # and the kv network tie-breaks on ascending payload, so the
            # pad payload must sort after every real one
            values = jnp.pad(values, pad, constant_values=n)
    rows = x.reshape(-1, m)
    if values is not None:
        sk, sv = _apply_network_kv(rows, values.reshape(-1, m), descending)
        sk = sk.reshape(*lead, m)[..., :n]
        sv = sv.reshape(*lead, m)[..., :n]
        return jnp.moveaxis(sk, -1, axis), jnp.moveaxis(sv, -1, axis)
    out = _apply_network(rows, descending).reshape(*lead, m)[..., :n]
    return jnp.moveaxis(out, -1, axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _xla_sort(x, axis: int, descending: bool):
    """jnp.sort with a permutation-transpose VJP.

    This environment's jax build has a broken `_sort_jvp` (constructs
    GatherDimensionNumbers with batching fields its NamedTuple lacks), so
    differentiating through raw lax.sort raises.  A sort is a permutation,
    so the correct cotangent is a scatter by the argsort order — implemented
    here with flat indices, bypassing the broken path entirely.
    """
    out = jnp.sort(x, axis=axis)
    return jnp.flip(out, axis=axis) if descending else out


def _xla_sort_fwd(x, axis, descending):
    order = jnp.argsort(x, axis=axis, stable=True)
    if descending:
        order = jnp.flip(order, axis=axis)
    out = jnp.take_along_axis(x, order, axis=axis)
    return out, order


def _xla_sort_bwd(axis, descending, order, g):
    ax = axis % order.ndim
    go = jnp.moveaxis(g, ax, -1)
    oo = jnp.moveaxis(order, ax, -1)
    lead = go.shape[:-1]
    n = go.shape[-1]
    go2 = go.reshape(-1, n)
    oo2 = oo.reshape(-1, n)
    rows = go2.shape[0]
    flat_idx = (jnp.arange(rows, dtype=jnp.int32)[:, None] * n + oo2).reshape(-1)
    gx = jnp.zeros(rows * n, dtype=g.dtype).at[flat_idx].add(go2.reshape(-1))
    return (jnp.moveaxis(gx.reshape(*lead, n), -1, ax),)


_xla_sort.defvjp(_xla_sort_fwd, _xla_sort_bwd)


# ---------------------------------------------------------------------------
# deprecation shims — every v1 call form forwards to a SortSpec
# ---------------------------------------------------------------------------

def sort(x: jnp.ndarray, *, axis: int = -1, method: str = "xla",
         descending: bool = False) -> jnp.ndarray:
    """Sort along ``axis`` with the selected backend (shim over
    ``repro.sort.sort``)."""
    _deprecated("sort")
    from repro import sort as _front
    return _front.sort(x, axis=axis, method=method, descending=descending)


def argsort(x: jnp.ndarray, *, axis: int = -1, method: str = "xla",
            descending: bool = False) -> jnp.ndarray:
    _deprecated("argsort")
    from repro import sort as _front
    return _front.argsort(x, axis=axis, method=method, descending=descending)


def topk(x: jnp.ndarray, k: int, *, method: str = "xla",
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k along the last axis -> (values, indices), descending (shim
    over ``repro.sort.topk``; k is validated at the spec layer)."""
    _deprecated("topk")
    from repro import sort as _front
    return _front.topk(x, k, method=method)


def top_p_mask(logits: jnp.ndarray, p: float, *, axis: int = -1,
               method: str = "auto") -> jnp.ndarray:
    """Nucleus-sampling mask: True for logits inside the top-p mass.

    Requires a descending sort of the probabilities — i.e. the paper's
    workload sitting directly on the serving path.  ``method`` defaults to
    "auto" so large-vocab serving gets cost-model dispatch; ``axis`` and
    ``method`` pass straight through the spec front door.
    """
    from repro import sort as _front
    probs = jax.nn.softmax(logits, axis=axis)
    sorted_probs = _front.sort(probs, axis=axis, method=method,
                               descending=True)
    cum = jnp.cumsum(sorted_probs, axis=axis)
    # number of entries needed to reach mass p
    keep_sorted = cum - sorted_probs < p
    kth = jnp.sum(keep_sorted, axis=axis, keepdims=True)  # count kept
    threshold = jnp.take_along_axis(sorted_probs, jnp.maximum(kth - 1, 0),
                                    axis=axis)
    return probs >= threshold
