"""Public sorting API — the paper's technique as a composable JAX feature.

One entry point, seven interchangeable backends:

  ``xla``      jnp.sort / jax.lax.top_k — the "off-memory" reference point.
  ``bitonic``  the paper's Batcher network executed word-parallel in pure
               jnp (every CAS = vector min/max). Beyond-paper: lifts the
               bit-serial constraint, keeps the oblivious schedule.
  ``pallas``   the in-VMEM Pallas kernel (kernels/bitonic_sort.py): tiles are
               read from HBM once, the whole network runs on VMEM-resident
               data — the TPU analogue of "sorting inside the memory array".
  ``imc``      the faithful bit-serial simulation (core/sorter.py): the
               28-cycle gate program on the simulated 6T SRAM array.
               Small integer keys (any signedness via keycodec); used for
               validation and benchmarks.
  ``merge``    the hierarchical out-of-core engine (repro.engine): tiled run
               generation + merge-path merge tree for arrays bigger than one
               VMEM tile — O(n log n) work where the whole-array network
               pays O(n log^2 n).
  ``radix``    digit-serial LSD radix sort (kernels/radix_sort.py) over
               keycodec-encoded keys — the VMEM analogue of the paper's
               bit-serial CAS program, O(n·b) work, stable.
  ``auto``     cost-model dispatch (repro.engine.planner): picks the
               cheapest *valid* backend from (n, batch, dtype).

Key encoding (core/keycodec.py) is shared plumbing: ``imc`` and ``radix``
both route keys through the same order-preserving unsigned encoding
(sign-bit flip for ints, sign-magnitude -> lexicographic for floats), so
signed and float keys sort correctly on every radix-ordered path.

Supported key dtypes by backend:

  xla / bitonic / pallas / merge   any comparable dtype (NaN-free floats)
  radix                            uint8/16/32, int8/16/32, f16, bf16, f32
  imc                              int8/16/32, uint8/16/32

Tie convention: ``argsort`` ties keep *ascending* index order in both
directions on every backend that defines tie order (xla, radix, and the
engine's stable pipeline; the kv bitonic network tie-breaks on its payload,
which is an index everywhere in this repo, so bitonic/pallas follow too).

Everything downstream (MoE routing, sampling, serving schedulers) calls
through this module, so the paper's contribution is a first-class,
swappable component of the framework.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

METHODS = ("xla", "bitonic", "pallas", "imc", "merge", "radix", "auto")


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _pad_value(dtype, descending: bool):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if descending else jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if descending else info.max, dtype)


def bitonic_sort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
                 values: Optional[jnp.ndarray] = None):
    """Word-parallel bitonic sort along ``axis`` (optionally carrying a
    values array, sorted by the keys — used for argsort / routing).

    Runs the reshape-addressed network (kernels/bitonic_sort.py) rather than
    a gather-per-substage formulation: long chains of 1-D gathers send XLA's
    CPU pipeline into a pathological simplification loop (minutes-to-never
    compiles for n as small as 256), while the (n/(2j), 2, j) reshape view
    compiles in seconds and is exactly what the Pallas kernel executes.
    """
    from repro.kernels.bitonic_sort import _apply_network, _apply_network_kv
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    if values is not None:
        values = jnp.moveaxis(values, axis, -1)
    lead = x.shape[:-1]
    n = x.shape[-1]
    m = _next_pow2(n)
    if m != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, m - n)]
        x = jnp.pad(x, pad, constant_values=_pad_value(x.dtype, descending))
        if values is not None:
            # out-of-range marker: pad keys can tie genuine extreme keys,
            # and the kv network tie-breaks on ascending payload, so the
            # pad payload must sort after every real one
            values = jnp.pad(values, pad, constant_values=n)
    rows = x.reshape(-1, m)
    if values is not None:
        sk, sv = _apply_network_kv(rows, values.reshape(-1, m), descending)
        sk = sk.reshape(*lead, m)[..., :n]
        sv = sv.reshape(*lead, m)[..., :n]
        return jnp.moveaxis(sk, -1, axis), jnp.moveaxis(sv, -1, axis)
    out = _apply_network(rows, descending).reshape(*lead, m)[..., :n]
    return jnp.moveaxis(out, -1, axis)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _xla_sort(x, axis: int, descending: bool):
    """jnp.sort with a permutation-transpose VJP.

    This environment's jax build has a broken `_sort_jvp` (constructs
    GatherDimensionNumbers with batching fields its NamedTuple lacks), so
    differentiating through raw lax.sort raises.  A sort is a permutation,
    so the correct cotangent is a scatter by the argsort order — implemented
    here with flat indices, bypassing the broken path entirely.
    """
    out = jnp.sort(x, axis=axis)
    return jnp.flip(out, axis=axis) if descending else out


def _xla_sort_fwd(x, axis, descending):
    order = jnp.argsort(x, axis=axis, stable=True)
    if descending:
        order = jnp.flip(order, axis=axis)
    out = jnp.take_along_axis(x, order, axis=axis)
    return out, order


def _xla_sort_bwd(axis, descending, order, g):
    ax = axis % order.ndim
    go = jnp.moveaxis(g, ax, -1)
    oo = jnp.moveaxis(order, ax, -1)
    lead = go.shape[:-1]
    n = go.shape[-1]
    go2 = go.reshape(-1, n)
    oo2 = oo.reshape(-1, n)
    rows = go2.shape[0]
    flat_idx = (jnp.arange(rows, dtype=jnp.int32)[:, None] * n + oo2).reshape(-1)
    gx = jnp.zeros(rows * n, dtype=g.dtype).at[flat_idx].add(go2.reshape(-1))
    return (jnp.moveaxis(gx.reshape(*lead, n), -1, ax),)


_xla_sort.defvjp(_xla_sort_fwd, _xla_sort_bwd)


def sort(x: jnp.ndarray, *, axis: int = -1, method: str = "xla",
         descending: bool = False) -> jnp.ndarray:
    """Sort along ``axis`` with the selected backend."""
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if method == "xla":
        return _xla_sort(x, axis, descending)
    if method == "bitonic":
        return bitonic_sort(x, axis=axis, descending=descending)
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.bitonic_sort(x, axis=axis, descending=descending)
    if method in ("merge", "auto"):
        from repro import engine
        return engine.sort(x, axis=axis, descending=descending, method=method)
    if method == "radix":
        return _radix_sort(x, axis=axis, descending=descending)
    # method == "imc": faithful bit-serial simulation on radix-encoded keys
    from repro.core import keycodec, sorter
    if axis not in (-1, x.ndim - 1):
        raise ValueError("imc method sorts along the last axis only")
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise ValueError("imc method requires integer inputs")
    # signed keys mis-sort in raw two's complement (the bit-serial compare
    # reads the sign bit as the top magnitude bit): encode to the biased
    # unsigned code first, sort, decode back
    enc = keycodec.encode(x)
    width = keycodec.key_bits(x.dtype)
    lead = x.shape[:-1]
    res = sorter.sort_in_memory(enc.reshape(-1, x.shape[-1]), width=width)
    out = keycodec.decode(
        res.values.astype(keycodec.key_dtype(x.dtype)), x.dtype
    ).reshape(*lead, x.shape[-1])
    return jnp.flip(out, axis=-1) if descending else out


def _radix_sort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
                values: Optional[jnp.ndarray] = None,
                interpret: Optional[bool] = None):
    """Stable LSD radix sort via the order-preserving key codec.

    Descending order complements the encoded key, so ties still keep
    ascending index order — the engine's tie convention — in both
    directions.  With ``values`` the payload follows its key (argsort/topk).
    """
    from repro.core import keycodec
    from repro.kernels import radix_sort as _rs
    from repro.kernels.ops import _from_rows, _to_rows
    if not keycodec.supports(x.dtype):
        raise ValueError(
            f"radix method supports {keycodec.SUPPORTED}, got {x.dtype.name}")
    x2, lead, ax = _to_rows(x, axis)
    enc = keycodec.encode(x2, descending=descending)
    if values is None:
        out = _rs.sort_blocks(enc, interpret=interpret)
        return _from_rows(keycodec.decode(out, x.dtype,
                                          descending=descending), lead, ax)
    v2, _, _ = _to_rows(values, ax)
    sk, sv = _rs.sort_kv_blocks(enc, v2, interpret=interpret)
    return (_from_rows(keycodec.decode(sk, x.dtype, descending=descending),
                       lead, ax),
            _from_rows(sv, lead, ax))


def _index_payload(x: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Positions along ``axis`` broadcast to ``x.shape`` (argsort payload)."""
    ax = axis % x.ndim
    n = x.shape[ax]
    return jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32).reshape(
            (1,) * ax + (n,) + (1,) * (x.ndim - 1 - ax)), x.shape)


def argsort(x: jnp.ndarray, *, axis: int = -1, method: str = "xla",
            descending: bool = False) -> jnp.ndarray:
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if method == "xla":
        # ties keep ascending index order in BOTH directions (the engine's
        # convention): a flipped stable ascending argsort would reverse tie
        # order, and jnp's descending comparator matches the flip-remap form
        return jnp.argsort(x, axis=axis, stable=True, descending=descending)
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.bitonic_argsort(x, axis, descending)
    if method == "imc":
        raise NotImplementedError(
            "imc is a bit-serial validation backend; use sort() on ints")
    if method in ("merge", "auto"):
        from repro import engine
        return engine.argsort(x, axis=axis, descending=descending,
                              method=method)
    idx = _index_payload(x, axis)
    if method == "radix":
        _, order = _radix_sort(x, axis=axis, descending=descending,
                               values=idx)
        return order
    _, order = bitonic_sort(x, axis=axis, descending=descending, values=idx)
    return order


def topk(x: jnp.ndarray, k: int, *, method: str = "xla",
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k along the last axis -> (values, indices), descending.

    This is the routing/sampling entry point: MoE expert selection and
    top-k sampling both come through here.
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if method == "xla":
        return jax.lax.top_k(x, k)
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.bitonic_topk(x, k)
    if method == "imc":
        raise NotImplementedError(
            "imc is a bit-serial validation backend; use sort() on ints")
    if method in ("merge", "auto"):
        from repro import engine
        return engine.topk(x, k, method=method)
    idx = _index_payload(x, -1)
    if method == "radix":
        sx, si = _radix_sort(x, axis=-1, descending=True, values=idx)
        return sx[..., :k], si[..., :k]
    sx, si = bitonic_sort(x, axis=-1, descending=True, values=idx)
    return sx[..., :k], si[..., :k]


def top_p_mask(logits: jnp.ndarray, p: float, *, method: str = "bitonic"
               ) -> jnp.ndarray:
    """Nucleus-sampling mask: True for logits inside the top-p mass.

    Requires a descending sort of the probabilities — i.e. the paper's
    workload sitting directly on the serving path.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    sorted_probs = sort(probs, axis=-1, method=method, descending=True)
    cum = jnp.cumsum(sorted_probs, axis=-1)
    # number of entries needed to reach mass p
    keep_sorted = cum - sorted_probs < p
    kth = jnp.sum(keep_sorted, axis=-1, keepdims=True)  # count kept
    threshold = jnp.take_along_axis(sorted_probs, jnp.maximum(kth - 1, 0),
                                    axis=-1)
    return probs >= threshold
