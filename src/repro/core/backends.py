"""The built-in SortBackend implementations.

Each backend is a thin adapter from the registry's rows-form contract
(``(rows, n)``, last axis) onto an existing engine: the jnp/XLA reference,
the word-parallel bitonic network, the in-VMEM Pallas kernel, the
cycle-accurate bit-serial simulator, the out-of-core run/merge hierarchy,
and the LSD radix kernels.  Kernel modules are imported lazily inside the
methods so importing the registry stays cheap and cycle-free.

Capability declarations here are load-bearing: ``repro.engine.planner``
derives *all* auto-dispatch eligibility from them (no per-backend rules in
the planner), and tests/test_sortspec.py sweeps every claim for truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import keycodec as _keycodec
from repro.core.sortspec import (Capabilities, SortBackend, next_pow2,
                                 register_backend)

# whole-array network caps: beyond these the power-of-two padded row stops
# being a reasonable VMEM-resident tile and the hierarchy should take over
MAX_BITONIC_N = 1 << 14
MAX_PALLAS_N = 1 << 16

# dtypes every comparison backend's min/max handles (NaN-free floats assumed)
COMPARABLE_DTYPES = frozenset({
    "float32", "bfloat16", "float16", "int32", "uint32",
    "int16", "uint16", "int8", "uint8"})

_INT_DTYPES = frozenset({"int8", "int16", "int32",
                         "uint8", "uint16", "uint32"})


def _gather_kv(keys, values, order):
    """(sorted keys, permuted payload) from an argsort permutation.

    The bitonic/pallas kv networks pad with (sentinel key, position ``n``)
    pairs, which only sort *after* every genuine element when the payload is
    an index array — an arbitrary user payload can tie or exceed the pad
    marker and be displaced by it.  So the kv front doors of those backends
    sort a (key, index) composite and gather both sides instead.
    """
    return (jnp.take_along_axis(keys, order, axis=-1),
            jnp.take_along_axis(values, order, axis=-1))


# ---------------------------------------------------------------------------
# xla — the "off-memory" reference point
# ---------------------------------------------------------------------------

@register_backend
class XlaBackend(SortBackend):
    """jnp.sort / lax.top_k with the repo's grad-safe VJP and the unified
    tie convention (ties keep ascending index order in both directions)."""
    name = "xla"
    capabilities = Capabilities(dtypes=None, stable=True, substrate="host")

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.core.sort_api import _xla_sort
        return _xla_sort(rows, -1, descending)

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        order = self.argsort(keys, descending=descending)
        return (jnp.take_along_axis(keys, order, axis=-1),
                jnp.take_along_axis(values, order, axis=-1))

    def argsort(self, rows, *, descending=False, plan=None, interpret=None):
        # jnp's descending comparator == the flip-remap stable form: ties
        # keep ascending index order in BOTH directions
        return jnp.argsort(rows, axis=-1, stable=True, descending=descending)

    def topk(self, rows, k, *, plan=None, interpret=None):
        return jax.lax.top_k(rows, k)

    def topk_cost_ns(self, n, k, batch, dtype, *, run_len, consts=None,
                     interpreted=False):
        """Off-TPU ``lax.top_k`` lowers to XLA:CPU's tuned O(n) native
        selection — price it as one (the ROADMAP-flagged ~90x inversion
        was exactly this candidate priced at the sort-prefix contract).
        On TPU the lowering is sort-based, so the sort-prefix default
        stays the honest price there."""
        from repro.core import cost_model
        if jax.default_backend() == "tpu":
            return super().topk_cost_ns(n, k, batch, dtype, run_len=run_len,
                                        consts=consts,
                                        interpreted=interpreted)
        return cost_model.xla_topk_cost_ns(n, k, batch, consts=consts)


# ---------------------------------------------------------------------------
# bitonic — the paper's network, word-parallel in pure jnp
# ---------------------------------------------------------------------------

@register_backend
class BitonicBackend(SortBackend):
    name = "bitonic"
    capabilities = Capabilities(dtypes=COMPARABLE_DTYPES, stable=False,
                                max_n=MAX_BITONIC_N, substrate="host")

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.core.sort_api import bitonic_sort
        return bitonic_sort(rows, axis=-1, descending=descending)

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        return _gather_kv(keys, values,
                          self.argsort(keys, descending=descending))

    def argsort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.core.sort_api import bitonic_sort
        idx = jnp.broadcast_to(
            jnp.arange(rows.shape[-1], dtype=jnp.int32), rows.shape)
        _, order = bitonic_sort(rows, axis=-1, descending=descending,
                                values=idx)
        return order


# ---------------------------------------------------------------------------
# pallas — the whole network on VMEM-resident tiles
# ---------------------------------------------------------------------------

@register_backend
class PallasBackend(SortBackend):
    name = "pallas"
    capabilities = Capabilities(dtypes=COMPARABLE_DTYPES, stable=False,
                                max_n=MAX_PALLAS_N, substrate="vmem")

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.kernels import ops as kops
        return kops.bitonic_sort(rows, -1, descending, interpret)

    def argsort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.kernels import ops as kops
        return kops.bitonic_argsort(rows, -1, descending, interpret)

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        return _gather_kv(keys, values,
                          self.argsort(keys, descending=descending,
                                       interpret=interpret))

    def topk(self, rows, k, *, plan=None, interpret=None):
        from repro.kernels import ops as kops
        # positional: custom_vjp entry points don't take keyword args
        return kops.bitonic_topk(rows, k, kops._TOPK_CHUNK, interpret)


# ---------------------------------------------------------------------------
# imc — the faithful bit-serial simulation
# ---------------------------------------------------------------------------

@register_backend
class ImcBackend(SortBackend):
    """The 28-cycle gate program on the simulated 6T SRAM array.  Validation
    and benchmarking only (never auto-dispatched); keys go through the
    order-preserving codec so signed ints sort correctly."""
    name = "imc"
    capabilities = Capabilities(dtypes=_INT_DTYPES, stable=False,
                                supports_kv=False, supports_topk=False,
                                supports_segments=False, auto_dispatch=False,
                                substrate="sram")

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.core import keycodec, sorter
        self.check_dtype(rows.dtype)
        enc = keycodec.encode(rows)
        res = sorter.sort_in_memory(enc, width=keycodec.key_bits(rows.dtype))
        out = keycodec.decode(
            res.values.astype(keycodec.key_dtype(rows.dtype)), rows.dtype)
        return jnp.flip(out, axis=-1) if descending else out

    def argsort(self, rows, *, descending=False, plan=None, interpret=None):
        """Argsort on the bit-serial sorter via the shared
        ``keycodec.argsort_composite`` packing: unique composites give the
        (unstable) network the engine's tie convention — ties keep
        ascending index order in both directions."""
        from repro.core import keycodec, sorter
        self.check_dtype(rows.dtype)
        comp, idx_bits = keycodec.argsort_composite(rows,
                                                    descending=descending)
        # the CAS gate program is built for power-of-two word widths
        width = next_pow2(keycodec.key_bits(rows.dtype) + idx_bits)
        res = sorter.sort_in_memory(comp, width=width)
        return (res.values & ((1 << idx_bits) - 1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# merge — the hierarchical out-of-core engine
# ---------------------------------------------------------------------------

@register_backend
class MergeBackend(SortBackend):
    """Tiled run generation + merge-path merge tree (repro.engine)."""
    name = "merge"
    capabilities = Capabilities(dtypes=COMPARABLE_DTYPES, stable=False,
                                substrate="hierarchy")

    def eligible(self, n, dtype, run_len=None):
        # a single run degenerates to "sort one tile and merge nothing"
        if run_len is not None and n <= run_len:
            return False
        return super().eligible(n, dtype, run_len)

    def _plan(self, rows, plan, run_len=None):
        if plan is not None:
            return plan
        from repro.engine import planner
        return planner.choose_cached(rows.shape[-1], rows.shape[0],
                                     rows.dtype, requested="merge",
                                     run_len=run_len)

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro import engine
        return engine.merge_sort_rows(rows, descending=descending,
                                      plan=self._plan(rows, plan),
                                      interpret=interpret)

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        from repro import engine
        return engine.merge_sort_rows_kv(keys, values, descending=descending,
                                         plan=self._plan(keys, plan),
                                         interpret=interpret)


# ---------------------------------------------------------------------------
# radix — digit-serial LSD radix sort over encoded keys
# ---------------------------------------------------------------------------

@register_backend
class RadixBackend(SortBackend):
    """Stable LSD radix sort (kernels/radix_sort.py) through the
    order-preserving key codec; ``descending`` complements the encoded key,
    so ties keep ascending index order in both directions."""
    name = "radix"
    capabilities = Capabilities(dtypes=frozenset(_keycodec.SUPPORTED),
                                stable=True, substrate="vmem")

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.core import keycodec
        from repro.kernels import radix_sort as _rs
        from repro.obs import trace as _obs
        self.check_dtype(rows.dtype)
        n = rows.shape[-1]
        passes, tiles = _rs.pass_tile_counts(n, rows.dtype)
        sp = _obs.trace("radix.sort", n=n, passes=passes, tiles=tiles)
        with sp:
            enc = keycodec.encode(rows, descending=descending)
            out = _rs.sort_blocks(enc, interpret=interpret)
            out = keycodec.decode(out, rows.dtype, descending=descending)
            sp.fence(out)
        return out

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        from repro.core import keycodec
        from repro.kernels import radix_sort as _rs
        from repro.obs import trace as _obs
        self.check_dtype(keys.dtype)
        n = keys.shape[-1]
        passes, tiles = _rs.pass_tile_counts(n, keys.dtype)
        sp = _obs.trace("radix.sort_kv", n=n, passes=passes, tiles=tiles)
        with sp:
            enc = keycodec.encode(keys, descending=descending)
            sk, sv = _rs.sort_kv_blocks(enc, values, interpret=interpret)
            sk = keycodec.decode(sk, keys.dtype, descending=descending)
            sp.fence((sk, sv))
        return sk, sv


# ---------------------------------------------------------------------------
# select — MSD radix-select, the O(n) partial-sort mode
# ---------------------------------------------------------------------------

@register_backend
class SelectBackend(SortBackend):
    """MSD radix-select (kernels/radix_select.py): top-k via keycodec
    digit histograms + threshold refinement — O(n·b/8) counting passes,
    never a sort.  Selection-only (``supports_sort=False``): plain sort
    specs are rejected at the spec layer; the planner prices its top-k
    specs with ``cost_model.selection_cost_ns`` and auto-dispatches it
    once ``k ≪ n`` makes selection cheaper than sort-prefix.  Exact-k
    with ``jax.lax.top_k``'s tie rule (ties keep ascending index)."""
    name = "select"
    capabilities = Capabilities(dtypes=frozenset(_keycodec.SUPPORTED),
                                stable=False, supports_kv=False,
                                supports_segments=False, supports_sort=False,
                                selection=True, substrate="vmem")

    def topk(self, rows, k, *, plan=None, interpret=None):
        from repro.kernels import radix_select as _sel
        from repro.obs import trace as _obs
        self.check_dtype(rows.dtype)
        n = rows.shape[-1]
        passes, tiles = _sel.pass_tile_counts(n, rows.dtype)
        sp = _obs.trace("select.topk", n=n, k=k, passes=passes, tiles=tiles)
        with sp:
            out = _sel.select_topk(rows, k, interpret=interpret)
            sp.fence(out)
        return out


# ---------------------------------------------------------------------------
# distributed — mesh-global sorting (sample-sort + odd-even fallback)
# ---------------------------------------------------------------------------

@register_backend
class DistributedBackend(SortBackend):
    """Mesh-global sorting behind the registry: the sample-sort
    (engine/samplesort.py — single-round flat, or the two-level ICI/DCN
    hierarchical schedule on multi-axis meshes) with odd-even
    transposition as the small-(n, D) single-axis fallback, strategy
    priced by ``planner.choose_distributed`` against the active
    ``core.topology``.

    The natural entry is a spec carrying mesh fields —
    ``SortSpec(mesh=..., axis_name=...)`` through ``repro.sort`` — which
    lands on :meth:`sort_mesh`.  The rows-form methods keep the backend an
    honest registry citizen (capability sweeps, single-host use): each row
    is sorted globally over whatever device mesh this host offers, which
    on one device degenerates to the local registered-backend sort.
    Never auto-dispatched by the single-device planner; the mesh path has
    its own cost model.
    """
    name = "distributed"
    capabilities = Capabilities(dtypes=frozenset(_keycodec.SUPPORTED),
                                stable=False, supports_segments=False,
                                selection=True, auto_dispatch=False,
                                substrate="mesh")

    @staticmethod
    def _host_mesh():
        return jax.make_mesh((len(jax.devices()),), ("data",))

    # -- mesh execution (what SortSpec.mesh routes to) ----------------------
    def sort_mesh(self, x, mesh, axis_name, *, values=None, descending=False,
                  local_method=None, interpret=None):
        from repro.core import distributed_sort as _ds
        return _ds.distributed_sort(x, mesh, axis_name,
                                    local_method=local_method,
                                    strategy="auto", descending=descending,
                                    values=values, interpret=interpret)

    def topk_mesh(self, x, k, mesh, axis_name, *, interpret=None):
        """Mesh-global top-k: local radix-select per shard, ONE candidate
        all-gather of D·min(k, m) (key, index) pairs, tiny lexicographic
        merge — no full-array sort ever runs."""
        from repro.core import distributed_sort as _ds
        return _ds.distributed_topk(x, k, mesh, axis_name,
                                    interpret=interpret)

    # -- rows form ----------------------------------------------------------
    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.engine import samplesort
        self.check_dtype(rows.dtype)
        mesh = self._host_mesh()
        return jnp.stack([
            samplesort.sample_sort(r, mesh, "data", descending=descending,
                                   interpret=interpret) for r in rows])

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        from repro.engine import samplesort
        self.check_dtype(keys.dtype)
        mesh = self._host_mesh()
        outs = [samplesort.sample_sort(k, mesh, "data", values=v,
                                       descending=descending,
                                       interpret=interpret)
                for k, v in zip(keys, values)]
        return (jnp.stack([k for k, _ in outs]),
                jnp.stack([v for _, v in outs]))

    def topk(self, rows, k, *, plan=None, interpret=None):
        """Rows form of the mesh top-k: each row runs the candidate path
        over whatever device mesh this host offers (on one device it
        degenerates to the local radix-select)."""
        from repro.engine import samplesort
        self.check_dtype(rows.dtype)
        mesh = self._host_mesh()
        outs = [samplesort.sample_topk(r, k, mesh, "data",
                                       interpret=interpret) for r in rows]
        return (jnp.stack([v for v, _ in outs]),
                jnp.stack([i for _, i in outs]))

    def argsort(self, rows, *, descending=False, plan=None, interpret=None):
        """Engine tie convention (ties keep ascending index order) on an
        unstable distributed sort, via the shared
        ``keycodec.argsort_composite`` packing (same width limit as the
        imc composite path)."""
        from repro.engine import samplesort
        self.check_dtype(rows.dtype)
        comp, idx_bits = _keycodec.argsort_composite(rows,
                                                     descending=descending)
        mesh = self._host_mesh()
        out = jnp.stack([samplesort.sample_sort(c, mesh, "data",
                                                interpret=interpret)
                         for c in comp])
        return (out & ((1 << idx_bits) - 1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# spill — out-of-core: chunked device sorts + host-resident k-way merge
# ---------------------------------------------------------------------------

@register_backend
class SpillBackend(SortBackend):
    """Out-of-core spill-to-host tier (``repro.engine.spill``): the input
    is cut into ``spill_threshold_bytes`` chunks, each chunk sorted on
    device through the registry (``method="auto"``), sorted runs streamed
    to host with double-buffered transfers, and a k-way merge-path
    combines the host-resident runs block by block.

    Never auto-*priced* (``auto_dispatch=False``): the planner routes to
    it by *feasibility* — any workload whose key bytes exceed the active
    profile's ``spill_threshold_bytes`` spills, everything below never
    does — rather than by cost comparison against backends that could not
    hold the array anyway.  Host-driven and eager-only: under an outer
    ``jit`` the engine falls back to the on-device merge pipeline.

    The kv path is always stable (stable chunk sorts + run-index tie
    breaks in both merge stages), so the capability claim is honest for
    the sweep tests.  No top-k/segmented paths (a dataset-scale top-k
    wants per-chunk selection + candidate merge — ROADMAP follow-through,
    not a sort-everything fallback).
    """
    name = "spill"
    # numpy owns the host half (searchsorted cursors, run storage);
    # bfloat16 — which numpy's comparators don't know — rides the
    # pipeline as its uint16 keycodec encoding (spill._bf16_encode), so
    # the full COMPARABLE_DTYPES set is honest
    capabilities = Capabilities(
        dtypes=frozenset({"float32", "float16", "bfloat16", "int32",
                          "uint32", "int16", "uint16", "int8", "uint8"}),
        stable=True, supports_kv=True, supports_topk=False,
        supports_segments=False, auto_dispatch=False, substrate="host")

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.engine import spill
        self.check_dtype(rows.dtype)
        return spill.sort_rows(rows, descending=descending,
                               interpret=interpret)

    def sort_kv(self, keys, values, *, descending=False, plan=None,
                interpret=None):
        from repro.engine import spill
        self.check_dtype(keys.dtype)
        return spill.sort_rows_kv(keys, values, descending=descending,
                                  interpret=interpret)

    def argsort(self, rows, *, descending=False, plan=None, interpret=None):
        from repro.engine import spill
        self.check_dtype(rows.dtype)
        return spill.argsort_rows(rows, descending=descending,
                                  interpret=interpret)
