"""Batcher bitonic sorting networks (paper §II-B, Eq. 1-4).

A bitonic network over N = 2^k inputs is an *oblivious* schedule of
compare-and-swap (CAS) pairs: the pair list of every stage is fixed at
network-construction time and independent of the data.  This is exactly what
makes it the right algorithm for an in-memory substrate (paper) and for a SIMD
substrate (our TPU adaptation): every stage is a data-independent vector op.

This module is pure Python/metadata — no jax.  It produces:
  * the stage schedule (list of stages; each stage a list of (i, j, ascending))
  * the analytic counts of Eq. 1-2 and checks them against the generated net
  * the partition residency plan of §II-B: which partition holds which element
    at each stage, and which stage transitions require inter-partition operand
    movement (Eq. 3-4 cost accounting).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

CASPair = Tuple[int, int, bool]  # (low index, high index, sort-ascending?)


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def n_cas_blocks(n: int) -> int:
    """Eq. 1:  N_CAS = N * log2(N) * (1 + log2(N)) / 4."""
    k = int(math.log2(n))
    return n * k * (1 + k) // 4


def n_stages(n: int) -> int:
    """Eq. 2:  N_stages = log2(N) * (1 + log2(N)) / 2."""
    k = int(math.log2(n))
    return k * (1 + k) // 2


def n_temp_rows(n: int) -> int:
    """Eq. 3:  temporary rows used for inter-partition movement."""
    return n // 4


def movement_cycles(n: int) -> int:
    """Eq. 4:  extra cycles charged per exchanging stage transition."""
    return 3 * n // 4


def bitonic_stages(n: int) -> List[List[CASPair]]:
    """Standard Batcher bitonic network, ascending overall sort.

    Returns ``stages`` where ``stages[s]`` is the list of CAS pairs executed
    concurrently in stage ``s`` (each element index appears in exactly one
    pair per stage; there are n/2 pairs per stage).
    """
    if not is_pow2(n) or n < 2:
        raise ValueError(f"bitonic network requires power-of-two n >= 2, got {n}")
    stages: List[List[CASPair]] = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            pairs: List[CASPair] = []
            for i in range(n):
                partner = i ^ j
                if partner > i:
                    ascending = (i & k) == 0
                    pairs.append((i, partner, ascending))
            stages.append(pairs)
            j //= 2
        k *= 2
    # Self-check against the paper's closed forms (Eq. 1-2).
    assert len(stages) == n_stages(n), (len(stages), n_stages(n))
    assert sum(len(s) for s in stages) == n_cas_blocks(n)
    return stages


def apply_network(values: Sequence, stages: List[List[CASPair]]) -> list:
    """Reference (python-level) execution of the network — test oracle glue."""
    v = list(values)
    for stage in stages:
        for (i, j, asc) in stage:
            lo, hi = (v[i], v[j]) if v[i] <= v[j] else (v[j], v[i])
            v[i], v[j] = (lo, hi) if asc else (hi, lo)
    return v


# ---------------------------------------------------------------------------
# Partition residency planning (§II-B)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Residency of the N elements across the N/2 memory partitions.

    ``residency[s]`` maps element index -> partition index during stage s.
    ``moving_transitions`` counts stage transitions whose operand placement
    requires inter-partition movement, with the paper's fused-first-exchange
    accounting (DESIGN.md §6): the first exchange is absorbed into the
    broadcast-writeback of the previous stage (movement types c/d write a row
    across *all* partitions' columns), so it is not charged.
    """
    n: int
    residency: List[dict]
    raw_moving_transitions: int
    moving_transitions: int

    @property
    def extra_cycles(self) -> int:
        return self.moving_transitions * movement_cycles(self.n)

    @property
    def n_partitions(self) -> int:
        return self.n // 2


def plan_partitions(n: int) -> PartitionPlan:
    stages = bitonic_stages(n)
    # Initial residency: partition p holds elements (2p, 2p+1) — the stage-1
    # pairs, which by construction are (2p, 2p+1), so stage 1 is always local.
    residency: List[dict] = []
    current = {e: e // 2 for e in range(n)}
    raw_moves = 0
    for s, stage in enumerate(stages):
        # Assign each pair to a partition, preferring partitions already
        # holding one of the operands (greedy, keeps moves minimal).
        target: dict = {}
        taken = set()
        # First pass: pairs that can stay where (at least) one operand lives.
        pending = []
        for (i, j, _) in stage:
            pi, pj = current[i], current[j]
            if pi == pj and pi not in taken:
                target[(i, j)] = pi
                taken.add(pi)
            elif pi not in taken:
                target[(i, j)] = pi
                taken.add(pi)
            elif pj not in taken:
                target[(i, j)] = pj
                taken.add(pj)
            else:
                pending.append((i, j))
        free = [p for p in range(n // 2) if p not in taken]
        for pair, p in zip(pending, free):
            target[pair] = p
        new = {}
        moved = False
        for (i, j), p in target.items():
            if current[i] != p or current[j] != p:
                moved = True
            new[i] = p
            new[j] = p
        if s > 0 and moved:
            raw_moves += 1
        current = new
        residency.append(dict(current))
    # Paper accounting: first exchange fused with previous writeback broadcast.
    charged = max(0, raw_moves - 1)
    return PartitionPlan(n=n, residency=residency,
                         raw_moving_transitions=raw_moves,
                         moving_transitions=charged)


def total_extra_cycles(n: int) -> int:
    """Total inter-stage movement cycles for an N-input sort (24 for N=8)."""
    return plan_partitions(n).extra_cycles
