"""Unified sort problem description + pluggable backend registry.

This module is the system's *one front door contract*: every sort in the
repo — flat, key-value, top-k, segmented/ragged, padded-row — is described
by a single frozen :class:`SortSpec` value, and every engine that can
execute one is a :class:`SortBackend` announcing what it can do through a
declared :class:`Capabilities` record.

The design follows the hardware-sorting survey's framing (sorters are
characterized by declared capabilities — stability, key width, capacity —
not by their call sites) and the PIM-practicality argument that in-memory
engines need a clean host-side abstraction: the planner and the public API
never special-case a backend by name.  ``repro.engine.planner`` asks the
registry which backends are *eligible* for a workload and prices the
survivors; adding a new engine is one ``@register_backend`` class — no
dispatch code changes anywhere.

Layering (no heavy imports here; backends lazy-import their kernels):

    repro.sort          front door: run(spec, x) + sort/argsort/topk/...
    repro.core.sortspec THIS — SortSpec, Capabilities, registry, defaults
    repro.core.backends the six built-in SortBackend implementations
    repro.engine        out-of-core pipeline + cost-model planner
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Dict, FrozenSet, Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "Capabilities", "SortSpec", "SortBackend", "register_backend",
    "unregister_backend", "get_backend", "registered_backends",
    "backend_names", "registry_generation", "sort_defaults", "default",
]


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


# ---------------------------------------------------------------------------
# capabilities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend declares it can do.  The planner trusts this record —
    tests/test_sortspec.py sweeps every registered backend and fails CI if a
    claim is untruthful (wrong-dtype sorts, fake stability).

    ``dtypes`` is the set of dtype *names* the backend sorts correctly;
    ``None`` means "any comparable dtype" (the backend is a comparison sort
    with no encoding step).  ``max_n`` caps the power-of-two padded row size
    the *planner* may hand the backend under ``method="auto"`` — explicit
    requests are still honoured beyond it (benchmarks do exactly that).
    ``auto_dispatch=False`` removes the backend from auto dispatch entirely
    (e.g. the cycle-accurate bit-serial simulator).

    ``selection=True`` declares an O(n·passes) top-k *selection* engine
    (partial sort — the survey's min/max-search operating mode): the
    planner prices its top-k specs with ``cost_model.selection_cost_ns``
    instead of the full-sort model.  ``supports_sort=False`` marks a
    selection-only engine: plain sort/argsort specs are rejected at the
    spec layer and the planner never hands it a sort workload.
    """
    dtypes: Optional[FrozenSet[str]] = None
    stable: bool = False
    max_n: Optional[int] = None
    supports_kv: bool = True
    supports_topk: bool = True
    supports_segments: bool = True
    supports_sort: bool = True
    selection: bool = False
    auto_dispatch: bool = True
    substrate: str = "host"        # "host" | "vmem" | "sram" | "hierarchy"


# ---------------------------------------------------------------------------
# backend protocol + registry
# ---------------------------------------------------------------------------

class SortBackend:
    """Base class every sorting engine plugs in through.

    Concrete backends implement ``sort`` (and optionally ``sort_kv`` /
    ``argsort`` / ``topk``) over *rows form*: a 2-D ``(rows, n)`` array,
    sorting along the last axis.  Axis handling, flattening, padding policy
    and spec validation all live above this layer (repro.sort), so a new
    backend is nothing but its kernel call plus a Capabilities record:

        @register_backend
        class SampleSortBackend(SortBackend):
            name = "sample"
            capabilities = Capabilities(stable=False, substrate="vmem")
            def sort(self, rows, *, descending=False, plan=None,
                     interpret=None):
                return my_kernel(rows, descending)
    """

    name: str = "?"
    capabilities: Capabilities = Capabilities()

    # -- planner queries ----------------------------------------------------
    def eligible(self, n: int, dtype, run_len: Optional[int] = None) -> bool:
        """Generic capability query: may ``auto`` hand (n, dtype) to us?"""
        caps = self.capabilities
        if caps.dtypes is not None and jnp.dtype(dtype).name not in caps.dtypes:
            return False
        if caps.max_n is not None and next_pow2(n) > caps.max_n:
            return False
        return True

    def cost_ns(self, n: int, batch: int, dtype, *, run_len: int,
                consts=None, interpreted: bool = False) -> float:
        """Estimated ns for (batch, n); default defers to the analytic cost
        model and prices unknown backends at +inf (never auto-picked until
        they override this or teach the model their asymptotics)."""
        from repro.core import cost_model, keycodec
        kb = keycodec.key_bits(dtype) if keycodec.supports(dtype) else 32
        try:
            return cost_model.device_sort_cost_ns(
                self.name, n, batch, run_len=run_len, consts=consts,
                pallas_interpreted=interpreted, key_bits=kb)
        except ValueError:
            return float("inf")

    def topk_cost_ns(self, n: int, k: int, batch: int, dtype, *, run_len: int,
                     consts=None, interpreted: bool = False) -> float:
        """Estimated ns for a top-k of (batch, n).  Default contracts:
        selection engines (``capabilities.selection``) price the
        O(n·passes) partial-sort model; sort engines price the sort-prefix
        path (full sort, then slice k).  Backends with a genuinely
        different top-k lowering override this — the xla backend prices
        native ``lax.top_k`` off-TPU, which is how the planner's k-aware
        ``auto`` can never again lose to an unpriced native path."""
        from repro.core import cost_model, keycodec
        if self.capabilities.selection:
            kb = keycodec.key_bits(dtype) if keycodec.supports(dtype) else 32
            return cost_model.selection_cost_ns(n, k, kb, batch,
                                                consts=consts)
        return self.cost_ns(n, batch, dtype, run_len=run_len, consts=consts,
                            interpreted=interpreted)

    # -- execution (rows form: (rows, n), last axis) ------------------------
    def sort(self, rows: jnp.ndarray, *, descending: bool = False,
             plan=None, interpret: Optional[bool] = None) -> jnp.ndarray:
        raise NotImplementedError(f"{self.name} backend implements no sort")

    def sort_kv(self, keys: jnp.ndarray, values: jnp.ndarray, *,
                descending: bool = False, plan=None,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        raise NotImplementedError(
            f"{self.name} backend has no key-value path "
            f"(capabilities.supports_kv={self.capabilities.supports_kv})")

    def argsort(self, rows: jnp.ndarray, *, descending: bool = False,
                plan=None, interpret: Optional[bool] = None) -> jnp.ndarray:
        idx = jnp.broadcast_to(
            jnp.arange(rows.shape[-1], dtype=jnp.int32), rows.shape)
        _, order = self.sort_kv(rows, idx, descending=descending, plan=plan,
                                interpret=interpret)
        return order

    def topk(self, rows: jnp.ndarray, k: int, *, plan=None,
             interpret: Optional[bool] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        idx = jnp.broadcast_to(
            jnp.arange(rows.shape[-1], dtype=jnp.int32), rows.shape)
        sk, sv = self.sort_kv(rows, idx, descending=True, plan=plan,
                              interpret=interpret)
        return sk[..., :k], sv[..., :k]

    # -- shared validation helper -------------------------------------------
    def check_dtype(self, dtype) -> None:
        caps = self.capabilities
        name = jnp.dtype(dtype).name
        if caps.dtypes is not None and name not in caps.dtypes:
            raise ValueError(
                f"{self.name} method supports {tuple(sorted(caps.dtypes))}, "
                f"got {name!r}")


_REGISTRY: Dict[str, SortBackend] = {}
_GENERATION: int = 0


def register_backend(cls):
    """Class decorator: instantiate ``cls`` and register it under
    ``cls.name``.  Re-registering a name replaces the previous backend (so
    notebooks can iterate) and invalidates cached plans."""
    global _GENERATION
    backend = cls() if isinstance(cls, type) else cls
    if not backend.name or backend.name in ("?", "auto"):
        raise ValueError(f"backend needs a usable name, got {backend.name!r}")
    _REGISTRY[backend.name] = backend
    _GENERATION += 1
    return cls


def unregister_backend(name: str) -> None:
    global _GENERATION
    _REGISTRY.pop(name, None)
    _GENERATION += 1


_builtins_loaded = False


def _bootstrap() -> None:
    # flag-gated (not `if not _REGISTRY`): registering a third-party backend
    # before first lookup must not suppress built-in registration
    global _builtins_loaded
    if not _builtins_loaded:
        _builtins_loaded = True
        from repro.core import backends  # noqa: F401  (registers built-ins)


def registered_backends() -> Dict[str, SortBackend]:
    _bootstrap()
    return dict(_REGISTRY)


def backend_names() -> Tuple[str, ...]:
    _bootstrap()
    return tuple(_REGISTRY)


def get_backend(name: str) -> SortBackend:
    _bootstrap()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {backend_names() + ('auto',)}, "
            f"got {name!r}") from None


def registry_generation() -> int:
    """Bumped on every (un)registration — plan caches key on this."""
    return _GENERATION


# ---------------------------------------------------------------------------
# ambient defaults
# ---------------------------------------------------------------------------

_DEFAULT_KEYS = ("method", "run_len", "interpret")
# contextvar (not a module global): a `with sort_defaults(...)` entered on
# one serving thread must not change dispatch for concurrent callers
_DEFAULTS: contextvars.ContextVar[Dict[str, Any]] = contextvars.ContextVar(
    "repro_sort_defaults", default={"method": "auto"})


@contextlib.contextmanager
def sort_defaults(**overrides):
    """Ambient configuration for specs that leave fields unset::

        with sort_defaults(method="merge", run_len=4096):
            repro.sort.sort(x)        # runs the engine with 4K runs

    Nests (inner contexts shadow outer), restores on exit, and is scoped to
    the current thread/context (contextvars)."""
    unknown = set(overrides) - set(_DEFAULT_KEYS)
    if unknown:
        raise ValueError(
            f"sort_defaults accepts {_DEFAULT_KEYS}, got {sorted(unknown)}")
    token = _DEFAULTS.set({**_DEFAULTS.get(), **overrides})
    try:
        yield
    finally:
        _DEFAULTS.reset(token)


def default(key: str):
    """Current ambient default for ``key`` (None if unset)."""
    return _DEFAULTS.get().get(key)


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class SortSpec:
    """The full sort problem in one value.

    Field groups (all optional beyond the defaults):

      axis / descending / stable   ordering contract
      k                            top-k selection (1 <= k <= n, validated)
      values                       payload array carried with the keys
      indices                      return the sorting permutation (argsort)
      segment_ids / row_splits     ragged: sort within each segment
      valid_lengths                padded rows: sort each row's valid prefix
      fill_value                   what overwrites the padded tail
      mesh / axis_name             distributed: sort globally over a mesh
                                   axis (single-round sample-sort / odd-even
                                   fallback, planner-priced); with ``k`` the
                                   spec is a mesh-global top-k (local select
                                   + one candidate all-gather)
      method / run_len / interpret execution knobs (None -> ambient default)

    ``eq=False`` keeps the dataclass hashable-by-identity even though it may
    carry arrays; :meth:`static_key` reduces the spec to its hashable
    statics plus the operand's (shape, dtype) for caching layers.
    """
    axis: int = -1
    descending: bool = False
    stable: bool = False
    k: Optional[int] = None
    values: Optional[jnp.ndarray] = None
    indices: bool = False
    segment_ids: Optional[jnp.ndarray] = None
    row_splits: Optional[jnp.ndarray] = None
    valid_lengths: Optional[jnp.ndarray] = None
    fill_value: Any = 0
    mesh: Any = None               # jax.sharding.Mesh for distributed sorts
    axis_name: Optional[str] = None
    method: Optional[str] = None
    run_len: Optional[int] = None
    interpret: Optional[bool] = None

    # -- validation + canonicalization (the one place it happens) -----------
    def canonical(self, x: jnp.ndarray) -> "SortSpec":
        """Resolve ambient defaults, normalize the axis, and validate the
        whole problem against ``x`` — every front-door error is raised here,
        not deep inside a kernel."""
        ndim = x.ndim
        if ndim == 0:
            raise ValueError("cannot sort a 0-d array")
        if not -ndim <= self.axis < ndim:
            raise ValueError(
                f"axis {self.axis} out of range for {ndim}-d input")
        axis = self.axis % ndim
        method = self.method if self.method is not None else default("method")
        names = backend_names() + ("auto",)
        if method not in names:
            raise ValueError(
                f"method must be one of {names}, got {method!r}")
        axis_name = self.axis_name
        if axis_name is not None and self.mesh is None:
            raise ValueError("axis_name requires a mesh")
        if self.mesh is not None:
            # one axis name, a tuple of axes (hierarchical meshes), or
            # None -> the whole mesh; normalised to a validated tuple by
            # the same helper every distributed consumer uses
            from repro.engine.samplesort import _axes_tuple
            axis_name = _axes_tuple(self.mesh, axis_name)
            if ndim != 1:
                raise ValueError(
                    "mesh-distributed specs sort flat 1-D arrays; "
                    f"got a {ndim}-d input")
            if (self.indices or self.stable
                    or self.segment_ids is not None
                    or self.row_splits is not None
                    or self.valid_lengths is not None):
                raise ValueError(
                    "mesh-distributed specs support plain and key-value "
                    "sorts plus top-k selection (no indices/stable/"
                    "segments/valid_lengths)")
            if method not in ("auto", "distributed"):
                raise ValueError(
                    f"mesh-distributed specs run the 'distributed' "
                    f"backend; method must be 'auto' or 'distributed', "
                    f"got {method!r}")
            method = "distributed"
        k = self.k
        n = x.shape[axis]
        if k is not None:
            k = int(k)
            if not 1 <= k <= n:
                raise ValueError(
                    f"topk k must satisfy 1 <= k <= n (n={n}); got k={k}")
        if self.segment_ids is not None and self.row_splits is not None:
            raise ValueError("pass segment_ids or row_splits, not both")
        ragged = self.segment_ids is not None or self.row_splits is not None
        if self.valid_lengths is not None and ragged:
            raise ValueError(
                "valid_lengths (padded rows) and segment_ids/row_splits "
                "(ragged) are mutually exclusive")
        if k is not None and (ragged or self.valid_lengths is not None):
            raise ValueError("top-k over segmented/padded specs is not "
                             "supported; sort then slice per segment")
        if k is not None and (self.values is not None or self.indices
                              or self.stable):
            raise ValueError("top-k specs return (values, indices) on their "
                             "own; values/indices/stable do not combine "
                             "with k")
        if self.values is not None and self.indices:
            raise ValueError("indices=True builds its own index payload; "
                             "pass either values or indices, not both")
        if self.values is not None and self.values.shape != x.shape:
            raise ValueError(
                f"values shape {self.values.shape} must match keys shape "
                f"{x.shape}")
        if method != "auto":
            # one-place validation: an op the backend declares unsupported
            # fails here, not deep inside a kernel ("auto" only ever
            # resolves to capability-eligible backends)
            caps = get_backend(method).capabilities
            if k is not None and not caps.supports_topk:
                raise ValueError(
                    f"{method} backend does not support top-k "
                    f"(capabilities.supports_topk=False)")
            if k is None and not caps.supports_sort:
                raise ValueError(
                    f"{method} backend is selection-only "
                    f"(capabilities.supports_sort=False); it runs top-k "
                    f"specs (k=...), not full sorts")
            if self.values is not None and not caps.supports_kv:
                raise ValueError(
                    f"{method} backend does not support key-value payloads "
                    f"(capabilities.supports_kv=False)")
            if ragged and not caps.supports_segments:
                raise ValueError(
                    f"{method} backend does not support segmented sorts "
                    f"(capabilities.supports_segments=False)")
        run_len = self.run_len if self.run_len is not None \
            else default("run_len")
        interpret = self.interpret if self.interpret is not None \
            else default("interpret")
        # top-k is inherently a descending selection (largest k)
        descending = True if k is not None else self.descending
        return dataclasses.replace(self, axis=axis, method=method, k=k,
                                   descending=descending, run_len=run_len,
                                   axis_name=axis_name, interpret=interpret)

    def static_key(self, shape, dtype) -> tuple:
        """Hashable reduction of the spec to its statics + the operand's
        (shape, dtype) — array-valued fields contribute only their presence,
        since a plan never depends on payload *values*.  The built-in plan
        cache (``planner.choose_cached``) keys on the statics it derives
        from the spec; this method is the equivalent key for external
        caching layers (e.g. a serving tier memoizing compiled steps)."""
        # axis layout AND device identity: two same-shape submeshes over
        # disjoint devices must not share an externally cached executable
        mesh_key = None if self.mesh is None else (
            tuple(zip(self.mesh.axis_names, self.mesh.devices.shape)),
            tuple(d.id for d in self.mesh.devices.flat))
        return (self.axis, self.descending, self.stable, self.k,
                self.values is not None, self.indices,
                self.segment_ids is not None, self.row_splits is not None,
                self.valid_lengths is not None, self.fill_value, self.method,
                mesh_key, self.axis_name,
                self.run_len, self.interpret, tuple(shape),
                jnp.dtype(dtype).name)
