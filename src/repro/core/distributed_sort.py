"""Mesh-distributed sorting — the paper's partitioning scaled to devices.

§II-B partitions one SRAM macro so N/2 CAS blocks run concurrently, paying
Eq. 3-4 temp-row cycles to exchange operands between partitions.  At cluster
scale the same structure maps 1:1 onto a device mesh:

    memory partition        ->  TPU chip (sorts its shard in-VMEM)
    intra-stage parallelism ->  SPMD over the mesh axis
    temp-row exchange       ->  jax.lax.ppermute shard exchange (ICI)

Algorithm: odd-even transposition merge over D devices.  Each device first
sorts its local shard (any registered backend), then D rounds of
neighbour-exchange + bitonic-merge-split.  After D rounds the concatenation
of shards in device order is globally sorted — the standard block-sorting
correctness result.

The collective cost is exactly one shard (m elements) over ICI per round per
device pair: ``collective_bytes(D, m) = D * m * itemsize`` per device — the
Eq. 3-4 analogue that shows up in the §Roofline collective term.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


def bitonic_merge_halves(lo_sorted: jnp.ndarray, hi_sorted: jnp.ndarray):
    """Merge two ascending arrays (each length m) and return the ascending
    (low half, high half).  Uses the bitonic merge box: concat(a, reverse(b))
    is bitonic, so only the merge substages of the network are needed."""
    m = lo_sorted.shape[-1]
    z = jnp.concatenate([lo_sorted, jnp.flip(hi_sorted, -1)], axis=-1)
    n = 2 * m
    ix = jnp.arange(n)
    j = n // 2
    while j >= 1:
        partner = ix ^ j
        pz = jnp.take(z, partner, axis=-1)
        keep_min = ix < partner
        z = jnp.where(keep_min, jnp.minimum(z, pz), jnp.maximum(z, pz))
        j //= 2
    return z[..., :m], z[..., m:]


def _round_permutation(n_dev: int, even_round: bool):
    """Partner index per device for one odd-even transposition round.

    A device paired with itself idles that round: the last device on even
    rounds when the count is odd, and the edge devices on odd rounds
    (device 0 always; the last device when the count is even).
    """
    perm = []
    for i in range(n_dev):
        if even_round:
            partner = i ^ 1
            if partner >= n_dev:
                partner = i  # odd device count: last device idles
        else:
            if i == 0 or (i == n_dev - 1 and n_dev % 2 == 0):
                partner = i  # edge devices idle this round
            else:
                partner = i + 1 if i % 2 == 1 else i - 1
        perm.append((i, partner))
    return perm


def distributed_sort(x: jnp.ndarray, mesh: Mesh, axis_name: str = "data",
                     local_method: Optional[str] = "xla") -> jnp.ndarray:
    """Globally sort a 1-D array sharded over ``axis_name`` of ``mesh``.

    Length must divide evenly by the axis size.  Returns the globally-sorted
    array with the same sharding.

    ``local_method`` accepts every registered backend name including
    ``"merge"`` and ``"auto"`` (or ``None`` for the ambient ``sort_defaults``
    method): the mesh path composes with the out-of-core engine, whose
    planner prices the *shard* size it sees inside the shard_map — so a
    vocab-scale shard gets tiled run generation + merge tree while a small
    one stays on a single-tile backend.
    """
    from repro import sort as _front
    n_dev = mesh.shape[axis_name]
    if x.shape[-1] % n_dev:
        raise ValueError(f"array length {x.shape[-1]} must divide {n_dev}")

    def local(xs):
        xs = _front.sort(xs, method=local_method)
        my = jax.lax.axis_index(axis_name)
        for r in range(n_dev):
            pairs = _round_permutation(n_dev, r % 2 == 0)
            send = [(i, p) for (i, p) in pairs]
            theirs = jax.lax.ppermute(xs, axis_name, send)
            partner = jnp.asarray([p for (_, p) in pairs])[my]
            lo, hi = bitonic_merge_halves(
                jnp.where(my < partner, xs, theirs),
                jnp.where(my < partner, theirs, xs))
            merged = jnp.where(my < partner, lo, hi)
            xs = jnp.where(my == partner, xs, merged)  # edges idle this round
        return xs

    spec = P(axis_name)
    fn = _shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec)
    return fn(x)


def collective_bytes_per_device(n_dev: int, local_elems: int,
                                itemsize: int) -> int:
    """Analytic ICI volume of the merge phase (per device)."""
    return n_dev * local_elems * itemsize
