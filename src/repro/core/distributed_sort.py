"""Mesh-distributed sorting — the paper's partitioning scaled to devices.

§II-B partitions one SRAM macro so N/2 CAS blocks run concurrently, paying
Eq. 3-4 temp-row cycles to exchange operands between partitions.  At cluster
scale the same structure maps 1:1 onto a device mesh:

    memory partition        ->  TPU chip (sorts its shard in-VMEM)
    intra-stage parallelism ->  SPMD over the mesh axis
    temp-row exchange       ->  shard exchange over ICI

One entry point, three strategies behind it (``strategy="auto"`` prices
them with ``planner.choose_distributed``, on two-axis meshes against the
link rates of the mesh's ``core.topology.Topology``):

  ``oddeven``  odd-even transposition merge: D rounds of neighbour
               ppermute + bitonic merge-split.  Minimal per-round state,
               but every shard moves D times — the repeated
               cross-partition traffic in-memory designs exist to avoid.
               Kept as the small-(n, D) fallback (fewer collective
               launches than an all-to-all when shards are tiny);
               ascending, evenly divisible, value-only.
  ``sample``   single-round splitter-based sample-sort
               (``engine/samplesort.py``): local sort, one bucket
               all-to-all, merge-path merge, rank rebalance.  Handles
               uneven lengths, descending, and key-value payloads (the
               keycodec reduces them all to one ascending unsigned sort),
               so any request odd-even cannot express routes here
               regardless of the cost model.
  ``hier``     two-level hierarchical sample-sort (same module): intra-host
               round over the fast inner tier, ONE chunked cross-host
               exchange over the slow outer tier, intra-host finalize.
               Needs a two-axis ``(outer, inner)`` mesh; auto picks it
               when the topology's tier rates say the slow tier dominates.

The odd-even collective cost is one shard (m elements) over ICI per round
per device pair: ``collective_bytes(D, m) = D * m * itemsize`` per device —
the Eq. 3-4 analogue priced by ``cost_model.collective_cost_ns``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


def bitonic_merge_halves(lo_sorted: jnp.ndarray, hi_sorted: jnp.ndarray):
    """Merge two ascending arrays (each length m) and return the ascending
    (low half, high half).  Uses the bitonic merge box: concat(a, reverse(b))
    is bitonic, so only the merge substages of the network are needed.

    Substages use the reshape-addressed form (a (n/(2j), 2, j) view pairs
    index i with i^j) rather than per-substage gathers: chained 1-D gathers
    send XLA's CPU pipeline into pathological compile times once shards
    reach engine scale (the same failure mode PR 1 fixed in
    ``sort_api.bitonic_sort``), while the reshape view compiles flat.
    """
    m = lo_sorted.shape[-1]
    z = jnp.concatenate([lo_sorted, jnp.flip(hi_sorted, -1)], axis=-1)
    n = 2 * m
    lead = z.shape[:-1]
    j = n // 2
    while j >= 1:
        v = z.reshape(*lead, n // (2 * j), 2, j)
        lo, hi = v[..., 0, :], v[..., 1, :]
        z = jnp.stack([jnp.minimum(lo, hi), jnp.maximum(lo, hi)],
                      axis=-2).reshape(*lead, n)
        j //= 2
    return z[..., :m], z[..., m:]


def _round_permutation(n_dev: int, even_round: bool):
    """Partner index per device for one odd-even transposition round.

    A device paired with itself idles that round: the last device on even
    rounds when the count is odd, and the edge devices on odd rounds
    (device 0 always; the last device when the count is even).
    """
    perm = []
    for i in range(n_dev):
        if even_round:
            partner = i ^ 1
            if partner >= n_dev:
                partner = i  # odd device count: last device idles
        else:
            if i == 0 or (i == n_dev - 1 and n_dev % 2 == 0):
                partner = i  # edge devices idle this round
            else:
                partner = i + 1 if i % 2 == 1 else i - 1
        perm.append((i, partner))
    return perm


def distributed_sort(x: jnp.ndarray, mesh: Mesh, axis_name=None,
                     local_method: Optional[str] = "xla", *,
                     strategy: str = "auto", descending: bool = False,
                     values: Optional[jnp.ndarray] = None,
                     interpret: Optional[bool] = None):
    """Globally sort a 1-D array sharded over ``axis_name`` of ``mesh`` —
    one axis name, a tuple of axes, or ``None`` for the whole mesh.

    Returns the globally-sorted array with the same sharding (or
    ``(keys, values)`` when a payload rides along).

    ``strategy`` is ``"auto"`` (cost-model pick via
    ``planner.choose_distributed`` — on a two-axis mesh the candidates
    are priced against the mesh's topology tier rates), ``"sample"``
    (single-round flat sample-sort), ``"hier"`` (two-level hierarchical
    sample-sort; needs a two-axis mesh) or ``"oddeven"`` (D-round
    transposition merge; single-axis only).  Requests odd-even cannot
    express — uneven lengths, ``descending``, payloads — always route to
    sample-sort; forcing ``strategy="oddeven"`` for one of those raises.

    ``local_method`` accepts every registered backend name including
    ``"merge"`` and ``"auto"`` (or ``None`` for the ambient ``sort_defaults``
    method): the mesh path composes with the out-of-core engine, whose
    planner prices the *shard* size it sees inside the shard_map — so a
    vocab-scale shard gets tiled run generation + merge tree while a small
    one stays on a single-tile backend.
    """
    from repro.core import topology as _topology
    from repro.engine import planner, samplesort
    axes = samplesort._axes_tuple(mesh, axis_name)
    n_dev = samplesort._n_dev(mesh, axes)
    multi = len(axes) > 1
    n = x.shape[-1]
    needs_sample = bool(descending or values is not None or n % n_dev)
    if strategy == "auto":
        topo = _topology.for_mesh(mesh, axes) if multi else None
        plan = planner.choose_distributed_cached(n, n_dev, x.dtype,
                                                 topology=topo)
        # odd-even is a single-axis, even-length, ascending, value-only
        # schedule — drop it from the running when the request (or the
        # mesh shape) rules it out and take the cheapest remaining
        usable = {s: c for s, c in plan.costs.items()
                  if s != "oddeven" or not (needs_sample or multi)}
        strategy = min(usable, key=usable.__getitem__)
    if strategy not in ("sample", "oddeven", "hier"):
        raise ValueError(
            f"strategy must be 'auto', 'sample', 'hier' or 'oddeven', "
            f"got {strategy!r}")
    if strategy == "hier" and len(axes) != 2:
        raise ValueError(
            f"strategy='hier' needs a two-axis (outer, inner) mesh; "
            f"got axes {axes}")
    if strategy in ("sample", "hier"):
        return samplesort.sample_sort(x, mesh, axes, values=values,
                                      descending=descending,
                                      local_method=local_method,
                                      hierarchical=(strategy == "hier"),
                                      interpret=interpret)
    if multi:
        raise ValueError(
            "oddeven transposition runs over ONE mesh axis; pass a single "
            f"axis name or use strategy='sample'/'hier' (got axes {axes})")
    axis_name = axes[0]
    if needs_sample:
        raise ValueError(
            "oddeven strategy needs an evenly divisible, ascending, "
            "value-only sort (length % n_dev == 0, descending=False, "
            "values=None); use strategy='sample' or 'auto'")
    from repro.obs import metrics as _metrics, trace as _obs
    coll_bytes = 0
    if _obs.enabled():
        coll_bytes = n_dev * collective_bytes_per_device(
            n_dev, -(-n // n_dev), jnp.dtype(x.dtype).itemsize)
        _metrics.counter("distsort.oddeven_bytes").inc(coll_bytes)
        _metrics.counter("distsort.oddeven_sorts").inc()
    sp = _obs.trace("distsort.oddeven", n=n, n_dev=n_dev, bytes=coll_bytes)
    with sp:
        out = _oddeven_fn(mesh, axis_name, local_method, interpret)(x)
        sp.fence(out)
    return out


@functools.lru_cache(maxsize=64)
def _oddeven_fn(mesh: Mesh, axis_name: str, local_method: Optional[str],
                interpret: Optional[bool] = None):
    """Cached jitted odd-even program — eagerly re-tracing the D-round
    loop per call costs orders of magnitude more than running it."""
    n_dev = mesh.shape[axis_name]

    def local(xs):
        from repro import sort as _front
        xs = _front.sort(xs, method=local_method, interpret=interpret)
        my = jax.lax.axis_index(axis_name)
        for r in range(n_dev):
            pairs = _round_permutation(n_dev, r % 2 == 0)
            send = [(i, p) for (i, p) in pairs]
            theirs = jax.lax.ppermute(xs, axis_name, send)
            partner = jnp.asarray([p for (_, p) in pairs])[my]
            lo, hi = bitonic_merge_halves(
                jnp.where(my < partner, xs, theirs),
                jnp.where(my < partner, theirs, xs))
            merged = jnp.where(my < partner, lo, hi)
            xs = jnp.where(my == partner, xs, merged)  # edges idle this round
        return xs

    spec = P(axis_name)
    fn = _shard_map(local, mesh=mesh, in_specs=(spec,), out_specs=spec)
    return jax.jit(fn)


def distributed_topk(x: jnp.ndarray, k: int, mesh: Mesh,
                     axis_name=None, *,
                     interpret: Optional[bool] = None):
    """Mesh-global top-k -> ``(values, indices)``, bit-exact with
    ``jax.lax.top_k`` (values descending, ties keep the lowest global
    index).  ``axis_name`` follows ``distributed_sort``: one axis, a
    tuple, or ``None`` for the whole mesh (the candidate all-gather is
    tiny, so there is no hierarchical variant to pick).

    There is only one strategy here on purpose: selection makes the
    strategy question moot.  Both full-sort strategies move O(m) per
    device (odd-even D times over); the candidate path
    (``engine/samplesort.sample_topk``) moves O(D·k) in ONE all-gather —
    local radix-select per shard, tiny lexicographic candidate merge, no
    full-array sort.  That is the paper's partial-movement argument
    (§II-B: only candidates cross partitions) at mesh scale.
    """
    from repro.engine import samplesort
    return samplesort.sample_topk(x, k, mesh, axis_name,
                                  interpret=interpret)


def collective_bytes_per_device(n_dev: int, local_elems: int,
                                itemsize: int) -> int:
    """Analytic ICI volume of the merge phase (per device)."""
    return n_dev * local_elems * itemsize
