"""Interconnect topology — the tiered link structure under a device mesh.

The paper's accounting (Eq. 3-4, Tables I/II) prices *operand movement*
per link crossed, and Mutlu et al. (PAPERS.md) generalise the lesson: the
win comes from restructuring computation around the memory/interconnect
hierarchy instead of treating it as flat.  PR 4's distributed sample-sort
still assumed exactly that flat picture — one axis of D devices with a
uniform per-byte link cost — which production meshes violate: intra-host
ICI runs ~10x faster than the inter-host DCN.

This module is the explicit model of that hierarchy, mirroring the
``repro.core.tuning`` layer one concern over:

  * :class:`TopologyAxis` — one mesh axis with its tier (``"ici"`` or
    ``"dcn"``), measured/assumed ``bandwidth_bytes_per_s`` and
    ``latency_ns``.
  * :class:`Topology` — a frozen, schema-versioned record of the axes of
    one mesh, keyed by the device fingerprint + mesh signature and
    JSON-persistable exactly like a ``TuningProfile``.
  * ``from_mesh`` / ``for_mesh`` — derive a default topology from a
    ``jax.sharding.Mesh`` (outermost axis = DCN when the mesh is
    multi-axis, everything inside it = ICI), or resolve the active /
    persisted one matching the mesh signature.
  * ``calibrate`` — a ping/all-to-all microbenchmark that probes each
    axis's launch latency and per-byte rate from two transfer sizes.
  * an **active topology** ambient with a generation counter folded into
    the planner's distributed-plan cache keys, so swapping topologies
    transparently re-plans flat-vs-hierarchical decisions.

Layering: sits beside ``tuning`` at the bottom of the stack.  It imports
only ``tuning`` (for the fingerprint and the default link constants) and
jax lazily inside the mesh/probe helpers; ``cost_model``, ``planner``,
``engine.collectives`` and ``engine.samplesort`` all consume it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import threading
from typing import Dict, Optional, Sequence, Tuple

from repro.core import tuning as _tuning

__all__ = [
    "SCHEMA", "TIER_ICI", "TIER_DCN", "TopologyAxis", "Topology",
    "TopologyError", "from_mesh", "for_mesh", "calibrate", "active",
    "set_active", "generation", "save", "load", "load_for_mesh",
    "persisted_path", "topology_path", "search_dirs", "cache_dir",
]

SCHEMA = "repro.topology/v1"

TOPOLOGY_DIR_ENV = "REPRO_TOPOLOGY_DIR"   # highest-priority topology dir

TIER_ICI = "ici"    # fast intra-host interconnect
TIER_DCN = "dcn"    # slow inter-host data-center network
_VALID_TIERS = (TIER_ICI, TIER_DCN)

# DCN defaults relative to the tuning layer's ICI link constants: the
# motivating production skew is ~10x slower per byte and ~10x the launch
# latency (collective_per_byte=0.02 ns/B ~ 50 GB/s ICI => 5 GB/s DCN).
DCN_SLOWDOWN = 10.0


class TopologyError(ValueError):
    """A topology that cannot be trusted: wrong schema version, malformed
    JSON, or axis values outside the validated ranges."""


@dataclasses.dataclass(frozen=True)
class TopologyAxis:
    """One mesh axis and the link tier its collectives run over."""
    name: str
    size: int
    tier: str
    bandwidth_bytes_per_s: float
    latency_ns: float

    def __post_init__(self):
        if not self.name:
            raise TopologyError("axis name must be non-empty")
        if self.size < 1:
            raise TopologyError(f"axis {self.name!r} size must be >= 1, "
                                f"got {self.size}")
        if self.tier not in _VALID_TIERS:
            raise TopologyError(f"axis {self.name!r} tier must be one of "
                                f"{_VALID_TIERS}, got {self.tier!r}")
        if not self.bandwidth_bytes_per_s > 0:
            raise TopologyError(f"axis {self.name!r} bandwidth must be > 0, "
                                f"got {self.bandwidth_bytes_per_s}")
        if self.latency_ns < 0:
            raise TopologyError(f"axis {self.name!r} latency must be >= 0, "
                                f"got {self.latency_ns}")

    @property
    def per_byte_ns(self) -> float:
        """The cost-model form of the bandwidth: ns per byte moved."""
        return 1e9 / self.bandwidth_bytes_per_s


@dataclasses.dataclass(frozen=True)
class Topology:
    """The tiered link structure of one device mesh.

    ``axes`` are ordered outermost-first, matching the mesh's axis order:
    for a 2x4 ``("host", "device")`` mesh the DCN axis comes first.
    ``source`` records provenance (``"default"`` / ``"calibrated"`` /
    ``"persisted"``) and ``probe_ns`` keeps the raw microbenchmark table a
    calibrated topology was fitted from, so a persisted file is auditable.
    """
    fingerprint: str
    axes: Tuple[TopologyAxis, ...]
    source: str = "default"
    probe_ns: Optional[Dict[str, float]] = None
    schema: str = SCHEMA

    def __post_init__(self):
        if self.schema != SCHEMA:
            raise TopologyError(
                f"unknown topology schema {self.schema!r} "
                f"(expected {SCHEMA!r})")
        axes = tuple(a if isinstance(a, TopologyAxis) else TopologyAxis(**a)
                     for a in self.axes)
        object.__setattr__(self, "axes", axes)
        if not axes:
            raise TopologyError("topology must have at least one axis")
        names = [a.name for a in axes]
        if len(set(names)) != len(names):
            raise TopologyError(f"duplicate axis names: {names}")

    # -- structure ----------------------------------------------------------
    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for a in self.axes:
            n *= a.size
        return n

    @property
    def is_hierarchical(self) -> bool:
        """True when the mesh has >= 2 non-degenerate axes — i.e. a second
        splitter round across the outer tier is even expressible."""
        return sum(1 for a in self.axes if a.size > 1) >= 2

    def axis(self, name: str) -> TopologyAxis:
        for a in self.axes:
            if a.name == name:
                return a
        raise KeyError(f"no axis {name!r} in topology "
                       f"{self.axis_names}")

    def signature(self) -> Tuple[Tuple[str, int], ...]:
        """The (name, size) shape a mesh must match to use this topology."""
        return tuple((a.name, a.size) for a in self.axes)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Topology":
        if not isinstance(d, dict):
            raise TopologyError(f"topology document must be an object, "
                                f"got {type(d).__name__}")
        if d.get("schema") != SCHEMA:
            raise TopologyError(f"unknown topology schema {d.get('schema')!r} "
                                f"(expected {SCHEMA!r})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise TopologyError(
                f"unknown topology fields {sorted(unknown)} "
                f"(schema {SCHEMA})")
        if "fingerprint" not in d or not isinstance(d["fingerprint"], str):
            raise TopologyError("topology is missing its device fingerprint")
        d = dict(d)
        axes = d.get("axes")
        if not isinstance(axes, (list, tuple)):
            raise TopologyError("topology axes must be a list")
        afields = {f.name for f in dataclasses.fields(TopologyAxis)}
        built = []
        for a in axes:
            if not isinstance(a, dict):
                raise TopologyError("each topology axis must be an object")
            bad = set(a) - afields
            if bad:
                raise TopologyError(
                    f"unknown axis fields {sorted(bad)} (schema {SCHEMA})")
            try:
                built.append(TopologyAxis(**a))
            except TypeError as e:
                raise TopologyError(f"malformed topology axis: {e}") from e
        d["axes"] = tuple(built)
        try:
            return cls(**d)
        except TypeError as e:
            raise TopologyError(f"malformed topology: {e}") from e


# ---------------------------------------------------------------------------
# mesh derivation
# ---------------------------------------------------------------------------

def _default_rates(tier: str) -> Tuple[float, float]:
    """(bandwidth B/s, latency ns) defaults per tier, derived from the
    active tuning profile's collective constants so a calibrated profile's
    link fit flows into default topologies too."""
    c = _tuning.active().constants
    bw = 1e9 / c.collective_per_byte
    lat = c.collective_alpha
    if tier == TIER_DCN:
        return bw / DCN_SLOWDOWN, lat * DCN_SLOWDOWN
    return bw, lat


def _mesh_signature(mesh, axis_names=None) -> Tuple[Tuple[str, int], ...]:
    names = tuple(axis_names) if axis_names is not None \
        else tuple(mesh.axis_names)
    for nm in names:
        if nm not in mesh.axis_names:
            raise TopologyError(f"axis {nm!r} not in mesh axes "
                                f"{tuple(mesh.axis_names)}")
    return tuple((nm, int(mesh.shape[nm])) for nm in names)


def from_mesh(mesh, axis_names: Optional[Sequence[str]] = None,
              *, fingerprint: Optional[str] = None) -> Topology:
    """The default topology for ``mesh``: outermost axis is the DCN tier
    when the mesh is multi-axis (matching ``jax.make_mesh``'s convention of
    hosts-outermost), every inner axis is ICI; a single-axis mesh is pure
    ICI.  ``axis_names`` restricts/reorders to a subset of the mesh axes
    (outer first), defaulting to all of them in mesh order."""
    sig = _mesh_signature(mesh, axis_names)
    axes = []
    for i, (nm, size) in enumerate(sig):
        tier = TIER_DCN if (i == 0 and len(sig) > 1) else TIER_ICI
        bw, lat = _default_rates(tier)
        axes.append(TopologyAxis(name=nm, size=size, tier=tier,
                                 bandwidth_bytes_per_s=bw, latency_ns=lat))
    return Topology(fingerprint=fingerprint or _tuning.device_fingerprint(),
                    axes=tuple(axes), source="default")


def for_mesh(mesh, axis_names: Optional[Sequence[str]] = None) -> Topology:
    """Resolve the topology the stack should price ``mesh`` with: the
    active ambient one when its signature matches, else a persisted file
    keyed by (fingerprint, signature), else the ``from_mesh`` default.
    Never returns None — there is always at least the default picture."""
    sig = _mesh_signature(mesh, axis_names)
    act = active()
    if act is not None and act.signature() == sig:
        return act
    persisted = load_for_mesh(sig)
    if persisted is not None:
        return persisted
    return from_mesh(mesh, axis_names)


# ---------------------------------------------------------------------------
# persistence (mirrors tuning.py: env dir -> user cache -> repo baselines)
# ---------------------------------------------------------------------------

def _repo_topology_dir() -> pathlib.Path:
    # src/repro/core/topology.py -> repo root / benchmarks / topologies
    return pathlib.Path(__file__).resolve().parents[3] / "benchmarks" \
        / "topologies"


def cache_dir() -> pathlib.Path:
    env = os.environ.get(TOPOLOGY_DIR_ENV)
    if env:
        return pathlib.Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = pathlib.Path(xdg) if xdg else pathlib.Path.home() / ".cache"
    return base / "repro" / "topologies"


def search_dirs() -> Tuple[pathlib.Path, ...]:
    dirs = []
    env = os.environ.get(TOPOLOGY_DIR_ENV)
    if env:
        dirs.append(pathlib.Path(env))
    else:
        dirs.append(cache_dir())
    dirs.append(_repo_topology_dir())
    return tuple(dirs)


def _filename(fingerprint: str,
              signature: Tuple[Tuple[str, int], ...]) -> str:
    # one file per (device fingerprint, mesh signature): the same machine
    # legitimately hosts many mesh shapes, each with its own calibration
    shape = "-".join(f"{nm}{sz}" for nm, sz in signature)
    return re.sub(r"[^A-Za-z0-9._-]+", "_", f"{fingerprint}.{shape}") \
        + ".json"


def topology_path(topology: Topology,
                  directory: Optional[os.PathLike] = None) -> pathlib.Path:
    d = pathlib.Path(directory) if directory is not None else cache_dir()
    return d / _filename(topology.fingerprint, topology.signature())


def save(topology: Topology,
         path: Optional[os.PathLike] = None) -> pathlib.Path:
    """Persist ``topology`` as schema-versioned JSON; returns the path."""
    p = pathlib.Path(path) if path is not None else topology_path(topology)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(topology.to_dict(), indent=2, allow_nan=False,
                            sort_keys=True) + "\n")
    return p


def load(path: os.PathLike) -> Topology:
    """Load one topology file.  Raises :class:`TopologyError` on schema
    mismatch or a malformed document (never silently trusts stale data)."""
    try:
        doc = json.loads(pathlib.Path(path).read_text())
    except (OSError, ValueError) as e:
        raise TopologyError(f"cannot read topology {path}: {e}") from e
    return Topology.from_dict(doc)


def persisted_path(signature: Tuple[Tuple[str, int], ...],
                   fingerprint: Optional[str] = None
                   ) -> Optional[pathlib.Path]:
    fp = fingerprint or _tuning.device_fingerprint()
    for d in search_dirs():
        p = d / _filename(fp, tuple(signature))
        if not p.is_file():
            continue
        try:
            t = load(p)
            if t.fingerprint == fp and t.signature() == tuple(signature):
                return p
        except TopologyError:
            continue
    return None


def load_for_mesh(signature: Tuple[Tuple[str, int], ...],
                  fingerprint: Optional[str] = None) -> Optional[Topology]:
    """The persisted topology matching (fingerprint, mesh signature), or
    None.  A file whose stored identity does not match is rejected — the
    planner falls back to defaults rather than mispricing every plan."""
    p = persisted_path(tuple(signature), fingerprint)
    if p is None:
        return None
    return dataclasses.replace(load(p), source="persisted")


# ---------------------------------------------------------------------------
# active-topology ambient (generation feeds the planner's dist-plan cache)
# ---------------------------------------------------------------------------

_LOCK = threading.Lock()
_active: Optional[Topology] = None
_generation = 0


def active() -> Optional[Topology]:
    """The ambient topology, or None.  Unlike the tuning profile there is
    no lazy default — a topology only means something relative to a mesh,
    so resolution happens per-mesh in :func:`for_mesh`."""
    return _active


def set_active(topology: Optional[Topology]) -> None:
    """Swap the ambient topology (``None`` = forget).  Bumps the
    generation counter the planner folds into distributed plan-cache keys,
    so flat-vs-hierarchical decisions priced under the old link rates
    die with it."""
    global _active, _generation
    with _LOCK:
        _active = topology
        _generation += 1


def generation() -> int:
    """Monotonic counter for plan-cache keys."""
    return _generation


# ---------------------------------------------------------------------------
# calibration: ping / all-to-all microbenchmark
# ---------------------------------------------------------------------------

def _time_ns(fn, *args, reps: int = 3) -> float:
    import time
    import jax
    out = fn(*args)
    jax.block_until_ready(out)          # compile + warm outside the clock
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e9


def calibrate(mesh, axis_names: Optional[Sequence[str]] = None, *,
              small_bytes: int = 1 << 10, large_bytes: int = 1 << 20,
              reps: int = 3, persist: bool = False,
              set_as_active: bool = True) -> Topology:
    """Probe each mesh axis's link tier with a two-point all-to-all
    microbenchmark and fit (latency_ns, bandwidth_bytes_per_s) per axis.

    For every non-degenerate axis the probe times a tiled all-to-all at a
    small and a large per-device payload; the slope between the two points
    is the per-byte rate and the intercept the launch latency (the
    ping half of ping/all-to-all).  Degenerate (size-1) axes keep the
    tier defaults — there is no link to measure.  The raw timings land in
    ``probe_ns`` so a persisted calibration is auditable.

    On a simulated mesh (forced host-platform device count) the numbers
    describe the simulation, not real links — still useful for exercising
    the machinery, not for real dispatch decisions.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    try:
        _shard_map = jax.shard_map
    except AttributeError:              # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map as _shard_map

    base = from_mesh(mesh, axis_names)
    probe: Dict[str, float] = {}
    axes_out = []
    for i, ax in enumerate(base.axes):
        if ax.size <= 1:
            axes_out.append(ax)
            continue

        def probe_bytes(nbytes: int, name=ax.name, size=ax.size) -> float:
            # per-device payload: `size` rows of nbytes/size each, f32
            per_row = max(1, nbytes // (4 * size))

            def body(v):
                return jax.lax.all_to_all(v, name, split_axis=0,
                                          concat_axis=0, tiled=True)
            try:
                fn = _shard_map(body, mesh=mesh, in_specs=(P(name),),
                                out_specs=P(name), check_rep=False)
            except TypeError:
                fn = _shard_map(body, mesh=mesh, in_specs=(P(name),),
                                out_specs=P(name), check_vma=False)
            x = jnp.zeros((size * size * per_row,), jnp.float32)
            return _time_ns(jax.jit(fn), x, reps=reps), 4 * size * per_row

        (t0, b0), (t1, b1) = probe_bytes(small_bytes), \
            probe_bytes(large_bytes)
        probe[f"{ax.name}.alltoall_{b0}B_ns"] = t0
        probe[f"{ax.name}.alltoall_{b1}B_ns"] = t1
        if b1 > b0 and t1 > t0:
            per_byte = (t1 - t0) / (b1 - b0)
            lat = max(0.0, t0 - per_byte * b0)
        else:                           # degenerate fit: keep defaults
            per_byte = ax.per_byte_ns
            lat = ax.latency_ns
        axes_out.append(dataclasses.replace(
            ax, bandwidth_bytes_per_s=1e9 / per_byte, latency_ns=lat))

    topo = dataclasses.replace(base, axes=tuple(axes_out),
                               source="calibrated", probe_ns=probe or None)
    if persist:
        save(topo)
    if set_as_active:
        set_active(topo)
    return topo
