"""Sharded optimizers: AdamW and Adafactor, mixed-precision, ZeRO-style.

Model parameters live in bf16; the optimizer state carries the fp32 master
copy plus moments.  Every state tensor inherits the parameter's
PartitionSpec (``state_specs``), so under the 2-D mesh the optimizer state is
fully sharded across data x model — ZeRO-3-equivalent memory scaling.

Adafactor (factored second moments, no first moment) is the default for the
340B-class configs where AdamW's 12 bytes/param does not fit a v5e pod
(napkin math in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Schedule(NamedTuple):
    fn: Callable[[jnp.ndarray], jnp.ndarray]

    def __call__(self, step):
        return self.fn(step)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Schedule:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return Schedule(fn)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # (param specs tree, abstract params tree) -> state specs tree
    state_specs: Callable[[Any, Any], Any]


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(schedule: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        m = jax.tree.map(jnp.zeros_like, master)
        v = jax.tree.map(jnp.zeros_like, master)
        return {"master": master, "m": m, "v": v}

    def update(grads, state, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(step)
        t = (step + 1).astype(jnp.float32)
        c1 = 1 - b1 ** t
        c2 = 1 - b2 ** t

        def upd(g, mst, m, v):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / c1
            vhat = v2 / c2
            new = mst - lr * (mhat / (jnp.sqrt(vhat) + eps)
                              + weight_decay * mst)
            return new, m2, v2

        out = jax.tree.map(upd, grads, state["master"], state["m"],
                           state["v"])
        master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return ({"master": master, "m": m, "v": v},
                {"grad_norm": gnorm, "lr": lr})

    def state_specs(param_specs, abstract_params=None):
        return {"master": param_specs, "m": param_specs, "v": param_specs}

    return Optimizer(init=init, update=update, state_specs=state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------

def adafactor(schedule: Schedule, eps: float = 1e-30,
              clip_norm: float = 1.0, weight_decay: float = 0.0,
              min_dim_factored: int = 128) -> Optimizer:
    def _factored(shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= min_dim_factored
                and shape[-2] >= min_dim_factored)

    def init(params):
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)

        def moments(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"master": master,
                "v": jax.tree.map(moments, master)}

    def update(grads, state, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(step)
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - t ** -0.8

        def upd(g, mst, mom):
            g2 = g * g + eps
            if "vr" in mom:
                vr = beta2 * mom["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * mom["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                pre = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = g * jax.lax.rsqrt(pre + eps)
                new_mom = {"vr": vr, "vc": vc}
            else:
                v = beta2 * mom["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_mom = {"v": v}
            # relative step clipping (RMS(u) <= 1)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u)
            new = mst - lr * (u + weight_decay * mst)
            return new, new_mom

        flat_p, treedef = jax.tree.flatten(state["master"])
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        new_p, new_v = [], []
        for g, p, v in zip(flat_g, flat_p, flat_v):
            np_, nv = upd(g, p, v)
            new_p.append(np_)
            new_v.append(nv)
        return ({"master": jax.tree.unflatten(treedef, new_p),
                 "v": jax.tree.unflatten(treedef, new_v)},
                {"grad_norm": gnorm, "lr": lr})

    def state_specs(param_specs, abstract_params):
        def moments_spec(spec, p):
            if _factored(p.shape):
                axes = tuple(spec)
                # pad spec to rank (specs may be shorter than the shape)
                axes = axes + (None,) * (len(p.shape) - len(axes))
                return {"vr": P(*axes[:-1]),
                        "vc": P(*(axes[:-2] + axes[-1:]))}
            return {"v": spec}

        return {"master": param_specs,
                "v": jax.tree.map(moments_spec, param_specs, abstract_params,
                                  is_leaf=lambda x: isinstance(x, P))}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def cast_like_params(master, params):
    return jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
