"""Error-feedback gradient compression for the cross-pod (DCN) all-reduce.

Napkin math for WHERE compression belongs (EXPERIMENTS.md §Perf): in-pod
ICI moves a 340B model's sharded grads in ~10s of ms; the cross-pod DCN
all-reduce of the same gradients is 25-100x slower per byte, so pod-level
DP is the only link where 8x compression buys wall-clock.  Therefore the
compressor is applied to the POD-DP gradient contribution only, with error
feedback (Karimireddy et al. 2019) so the compression bias does not
accumulate: e_{t+1} = g_t + e_t - D(C(g_t + e_t)).

Two codecs:
  * int8 — per-tensor scale, 4x over fp32 wire format
  * topk — keep the largest-|g| fraction per tensor (sort courtesy of the
    paper's kernels), zero the rest; error feedback catches the tail

Under pjit the actual wire collective is XLA's; the codec runs
compress->decompress around the optimizer so the *numerics* of the
compressed all-reduce are exactly reproduced and unit-testable; the wire
saving itself is realised when the pod axis all-reduce is lowered through
a custom collective (documented, out of scope for the CPU dry-run).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    codec: str = "int8"          # int8 | topk
    topk_frac: float = 0.125
    # "auto" lets the k-aware planner pick radix selection over
    # sort-prefix — gradient tensors are exactly the k << n regime
    sort_method: str = "auto"


def _int8_roundtrip(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def topk_budget(n: int, frac: float) -> int:
    """The exact element budget the top-k codec keeps (and prices)."""
    return max(1, int(n * frac))


def _topk_roundtrip(g, frac: float, method: str):
    """Keep exactly k = max(1, floor(n*frac)) largest-|g| lanes.

    Exact-k scatter from the top-k *indices* — never a threshold compare.
    The old ``|g| >= vals[-1]`` mask had two failure modes: a zero k-th
    magnitude made the mask all-true (|g| >= 0.0 — compression silently
    OFF for sparse gradients), and ties at the threshold kept every tied
    lane (frac=0.25 of 8 equal values kept all 8).  Scattering through
    the indices keeps exactly k lanes under both, matching what
    ``wire_bytes`` bills for.
    """
    flat = g.reshape(-1)
    k = topk_budget(flat.shape[0], frac)
    from repro import sort as sorting
    _, idx = sorting.topk(jnp.abs(flat), k, method=method)
    return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(g.shape)


def make_compressor(cfg: CompressorConfig):
    """Returns (init_state, apply) for use as steps.build_train_step's
    grad_compressor hook: grads', opt_state' = apply(grads, opt_state).

    The error buffer lives inside opt_state under key '_ef' (sharded like
    the gradients)."""

    def init_state(params):
        return {"_ef": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def roundtrip(g):
        if cfg.codec == "int8":
            return _int8_roundtrip(g)
        return _topk_roundtrip(g, cfg.topk_frac, cfg.sort_method)

    def apply(grads, opt_state):
        ef = opt_state["_ef"]
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, ef)
        sent = jax.tree.map(roundtrip, corrected)
        new_ef = jax.tree.map(lambda c, s: c - s, corrected, sent)
        new_state = dict(opt_state)
        new_state["_ef"] = new_ef
        return sent, new_state

    return init_state, apply


def wire_bytes(n_params: int, codec: str, topk_frac: float = 0.125) -> int:
    """Bytes on the DCN per step per pod-pair for the gradient all-reduce.

    The top-k bill uses the same ``topk_budget`` the codec enforces, so
    the wire accounting matches the exact-k guarantee (never the old
    threshold mask's "maybe everything" worst case)."""
    if codec == "int8":
        return n_params * 1 + 4  # values + scale
    return topk_budget(n_params, topk_frac) * (4 + 4)   # value + index
