"""repro.obs — tracing, metrics, and perf-trajectory observability.

The measurement layer under every other subsystem: a contextvar-scoped
span tracer (``trace``), process-local counters/gauges/histograms
(``metrics``), and markdown/JSON reporting (``report``).  One master
switch governs all recording::

    import repro.obs as obs

    obs.enable()                     # or REPRO_OBS=1 in the environment
    repro.sort.sort(x)
    print(obs.report.render_markdown())
    obs.disable()

Disabled (the default) the whole layer is a single flag check per call
site — no spans, no events, no metric writes, bit-identical outputs.
See README "Observability" for the metric catalog.
"""
from __future__ import annotations

from repro.obs import metrics, report, trace  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    counter, gauge, histogram, snapshot)
from repro.obs.trace import (  # noqa: F401
    Span, enable, disable, enabled, events, record_event, spans, tracing)

__all__ = [
    "trace", "metrics", "report",
    "enable", "disable", "enabled", "tracing",
    "span", "Span", "spans", "events", "record_event",
    "counter", "gauge", "histogram", "snapshot",
    "clear",
]

# ``obs.span("name", ...)`` opens a span; ``obs.trace`` stays the module so
# call sites can do ``from repro.obs import trace`` and ``trace.trace(...)``
span = trace.trace


def clear() -> None:
    """Reset every recorded span, event, and metric (the enabled flag is
    left as-is)."""
    trace.clear()
    metrics.reset()
