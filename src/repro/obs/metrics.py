"""Process-local counters, gauges, and fixed-bucket histograms.

No dependencies beyond the standard library: histograms use fixed
geometric buckets (``_BPD`` buckets per decade over ``[_LO, _HI)``), so an
``observe`` is one ``log10`` + an integer increment and percentile queries
(p50/p90/p99) resolve by walking the cumulative counts with log-linear
interpolation inside the crossing bucket — accurate to roughly one bucket
width (~7%% relative with 32 buckets/decade), which tests/test_obs.py
checks against numpy on lognormal samples.

Recording respects the observability master switch
(:func:`repro.obs.trace.enabled`): with obs disabled every ``inc`` /
``set`` / ``observe`` returns immediately, so instrumented hot paths pay
one flag check.  Reads (``snapshot``, ``percentile``) always work.

Units are by convention in the metric name (``serve.e2e_ms``,
``samplesort.alltoall_bytes``); the registry does not interpret them.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional

from repro.obs import trace as _trace

__all__ = ["Counter", "Gauge", "Histogram", "counter", "gauge", "histogram",
           "snapshot", "to_json", "reset"]

_LOCK = threading.Lock()
_REGISTRY: Dict[str, object] = {}

# histogram geometry: 32 geometric buckets per decade over [1e-9, 1e12)
_BPD = 32
_LO = 1e-9
_DECADES = 21
_NBUCKETS = _BPD * _DECADES


class Counter:
    """Monotonic accumulator (events, bytes, cache hits)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if not _trace.enabled():
            return
        with _LOCK:
            self.value += v

    def _snap(self):
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins value (queue depth, bucket skew)."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        if not _trace.enabled():
            return
        with _LOCK:
            self.value = float(v)

    def _snap(self):
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-geometric-bucket histogram with percentile queries."""

    def __init__(self, name: str):
        self.name = name
        self.buckets: List[int] = [0] * _NBUCKETS
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    @staticmethod
    def _bucket_of(v: float) -> int:
        if v <= _LO:
            return 0
        i = int(math.log10(v / _LO) * _BPD)
        return min(i, _NBUCKETS - 1)

    @staticmethod
    def _edges(i: int):
        lo = _LO * 10.0 ** (i / _BPD)
        return lo, lo * 10.0 ** (1.0 / _BPD)

    def observe(self, v: float) -> None:
        if not _trace.enabled():
            return
        v = float(v)
        with _LOCK:
            self.buckets[self._bucket_of(v)] += 1
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def percentile(self, p: float) -> Optional[float]:
        """p in [0, 100] -> log-interpolated value, None when empty."""
        if self.count == 0:
            return None
        target = (p / 100.0) * self.count
        seen = 0
        for i, c in enumerate(self.buckets):
            if c == 0:
                continue
            if seen + c >= target:
                lo, hi = self._edges(i)
                frac = (target - seen) / c
                est = lo * (hi / lo) ** frac
                # never extrapolate past the observed extremes
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def clear(self) -> None:
        """Zero this histogram in place (the registry keeps the instance).
        Drift detectors (``tuning.refresh_if_stale``) clear the error
        histogram after acting on it so the next decision starts from
        fresh observations instead of re-counting the stale ones."""
        with _LOCK:
            self.buckets = [0] * _NBUCKETS
            self.count = 0
            self.sum = 0.0
            self.min = None
            self.max = None

    def _snap(self):
        return {"type": "histogram", "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


def _get(name: str, cls):
    with _LOCK:
        m = _REGISTRY.get(name)
        if m is None:
            m = _REGISTRY[name] = cls(name)
    if not isinstance(m, cls):
        raise TypeError(f"metric {name!r} already registered as "
                        f"{type(m).__name__}, requested {cls.__name__}")
    return m


def counter(name: str) -> Counter:
    return _get(name, Counter)


def gauge(name: str) -> Gauge:
    return _get(name, Gauge)


def histogram(name: str) -> Histogram:
    return _get(name, Histogram)


def snapshot() -> Dict[str, dict]:
    """{name: {type, ...summary...}} for every registered metric."""
    with _LOCK:
        metrics = dict(_REGISTRY)
    return {name: m._snap() for name, m in sorted(metrics.items())}


def to_json(indent: Optional[int] = None) -> str:
    return json.dumps(snapshot(), indent=indent)


def reset() -> None:
    with _LOCK:
        _REGISTRY.clear()
