"""Contextvar-scoped span tracer with a zero-overhead disabled mode.

The paper's whole argument is an accounting argument: ADS-IMC wins because
it *counts* data movement per sort stage (Tables I/II).  This module is the
software stack's counting instrument — a lightweight span tracer every hot
path threads through:

    from repro.obs import trace

    with trace.trace("samplesort.all_to_all", bytes=nbytes) as sp:
        out = exchange(...)
        sp.fence(out)          # block_until_ready outside jit, no-op inside

Design contract (enforced by tests/test_obs.py):

  * **Zero overhead when disabled.**  ``trace(...)`` checks one module-level
    flag before any allocation and returns a shared no-op singleton; nothing
    is recorded, no span objects are built, and traced functions return
    bit-identical outputs.  Hot paths that would compute expensive span
    attributes guard on :func:`enabled` first.
  * **jit-safe.**  :meth:`Span.fence` only calls ``block_until_ready`` on
    concrete arrays; under a trace (inside ``jax.jit``/``shard_map``) it is
    a no-op, so instrumented functions stay traceable.  Wall time is always
    recorded; device time (``device_ms``) only exists when a fence actually
    ran, so timings are never silently trace-time garbage.
  * **Nested.**  The active span stack lives in a contextvar, so spans nest
    per thread/async context and each finished record carries its depth and
    parent name.

Events (``record_event``) are the structured, non-timing side of the same
log: the planner appends one ``plan_decision`` event per cache miss with the
full candidate cost table, and the engine appends ``cost_observation``
events pairing predicted with measured ns — the raw series behind the
``cost_model_error`` metric.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "enable", "disable", "enabled", "tracing", "trace", "Span",
    "record_event", "events", "spans", "clear", "to_json",
]

# THE flag: every entry point checks it before allocating anything
_ENABLED = bool(os.environ.get("REPRO_OBS"))

_LOCK = threading.Lock()
_SPANS: List[Dict[str, Any]] = []          # finished spans, completion order
_EVENTS: List[Dict[str, Any]] = []         # structured events, append order
_STACK: contextvars.ContextVar[Tuple["Span", ...]] = contextvars.ContextVar(
    "repro_obs_span_stack", default=())


def enabled() -> bool:
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


@contextlib.contextmanager
def tracing(on: bool = True):
    """Scoped enable/disable (tests, one-off profiled sections)::

        with trace.tracing():
            repro.sort.sort(x)
        print(trace.spans())
    """
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    try:
        yield
    finally:
        _ENABLED = prev


def clear() -> None:
    """Drop every recorded span and event (the stack is left alone)."""
    with _LOCK:
        _SPANS.clear()
        _EVENTS.clear()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def _concrete(value: Any) -> bool:
    """True iff no leaf of ``value`` is a jax tracer (safe to block on)."""
    import jax
    return not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree_util.tree_leaves(value))


class Span:
    """One timed region.  Wall time always; device time when fenced."""

    __slots__ = ("name", "attrs", "depth", "parent", "_t0",
                 "wall_ms", "device_ms")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.depth = 0
        self.parent: Optional[str] = None
        self._t0 = 0.0
        self.wall_ms: Optional[float] = None
        self.device_ms: Optional[float] = None

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (bucket counts, plans)."""
        self.attrs.update(attrs)
        return self

    def fence(self, value):
        """Block until ``value`` is device-complete and record the span's
        device time.  No-op on tracers (inside jit) — returns ``value``
        unchanged either way, so call sites can fence their return."""
        if _concrete(value):
            import jax
            jax.block_until_ready(value)
            self.device_ms = (time.perf_counter() - self._t0) * 1e3
        return value

    def __enter__(self) -> "Span":
        stack = _STACK.get()
        self.depth = len(stack)
        self.parent = stack[-1].name if stack else None
        _STACK.set(stack + (self,))
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.wall_ms = (time.perf_counter() - self._t0) * 1e3
        stack = _STACK.get()
        if stack and stack[-1] is self:
            _STACK.set(stack[:-1])
        with _LOCK:
            _SPANS.append({
                "name": self.name, "parent": self.parent,
                "depth": self.depth, "wall_ms": self.wall_ms,
                "device_ms": self.device_ms, "attrs": dict(self.attrs),
            })


class _NoopSpan:
    """The shared disabled-mode span: every method is a no-op and
    ``trace(...)`` hands out this one instance — no per-call allocation."""

    __slots__ = ()
    name = None
    wall_ms = None
    device_ms = None
    attrs: Dict[str, Any] = {}

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def fence(self, value):
        return value

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP = _NoopSpan()


def trace(name: str, **attrs):
    """Open a span (use as a context manager).  Disabled -> the shared
    no-op singleton; nothing is allocated or recorded."""
    if not _ENABLED:
        return _NOOP
    return Span(name, attrs)


def spans() -> List[Dict[str, Any]]:
    """Finished span records (completion order — children before parents)."""
    with _LOCK:
        return list(_SPANS)


# ---------------------------------------------------------------------------
# structured events
# ---------------------------------------------------------------------------

def record_event(kind: str, **fields) -> None:
    """Append one structured event (no-op when disabled)."""
    if not _ENABLED:
        return
    with _LOCK:
        _EVENTS.append({"kind": kind, **fields})


def events(kind: Optional[str] = None) -> List[Dict[str, Any]]:
    with _LOCK:
        evs = list(_EVENTS)
    if kind is not None:
        evs = [e for e in evs if e.get("kind") == kind]
    return evs


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    try:                                    # numpy scalars
        return v.item()
    except (AttributeError, ValueError):
        return repr(v)


def to_json(indent: Optional[int] = None) -> str:
    return json.dumps({"spans": _jsonable(spans()),
                       "events": _jsonable(events())}, indent=indent)
