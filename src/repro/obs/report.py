"""Render an observability snapshot to markdown.

Three views over the same process-local state:

  * :func:`render_markdown` — the full dump: metric catalog (counters,
    gauges, histogram percentiles), event counts per kind, and the span
    tree in completion order.
  * :func:`slo_report` — the serving tier's SLO table: p50/p90/p99 of
    every ``serve.*`` histogram (queue wait, padding waste, end-to-end
    latency, decode throughput).
  * :func:`cost_model_report` — predicted-vs-measured dispatch accounting:
    one row per ``cost_observation`` event plus the aggregate
    ``planner.cost_model_error`` percentiles.  A planner mispricing like
    the 313ms-vs-3.4ms top-k inversion shows up here as a two-orders-of-
    magnitude error ratio instead of hiding in a CSV.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = ["render_markdown", "slo_report", "cost_model_report"]


def _fmt(v, nd: int = 3) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}g}"
    return str(v)


def _hist_rows(snap: Dict[str, dict], prefix: str = ""):
    return [(name, m) for name, m in snap.items()
            if m["type"] == "histogram" and name.startswith(prefix)
            and m["count"]]


def render_markdown(snapshot: Optional[Dict[str, dict]] = None) -> str:
    """Everything recorded so far, as one markdown document."""
    snap = _metrics.snapshot() if snapshot is None else snapshot
    out = ["## Observability snapshot\n"]

    scalars = [(n, m) for n, m in snap.items()
               if m["type"] in ("counter", "gauge")]
    if scalars:
        out.append("### Metrics\n\n| metric | type | value |\n|---|---|---|\n")
        for name, m in scalars:
            out.append(f"| {name} | {m['type']} | {_fmt(m['value'])} |\n")
        out.append("\n")

    hists = _hist_rows(snap)
    if hists:
        out.append("### Histograms\n\n"
                   "| metric | count | p50 | p90 | p99 | max |\n"
                   "|---|---|---|---|---|---|\n")
        for name, m in hists:
            out.append(f"| {name} | {m['count']} | {_fmt(m['p50'])} | "
                       f"{_fmt(m['p90'])} | {_fmt(m['p99'])} | "
                       f"{_fmt(m['max'])} |\n")
        out.append("\n")

    events = _trace.events()
    if events:
        kinds: Dict[str, int] = {}
        for e in events:
            kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
        out.append("### Events\n\n| kind | count |\n|---|---|\n")
        for kind, cnt in sorted(kinds.items()):
            out.append(f"| {kind} | {cnt} |\n")
        out.append("\n")

    spans = _trace.spans()
    if spans:
        out.append("### Spans\n\n"
                   "| span | wall ms | device ms | attrs |\n|---|---|---|---|\n")
        for s in spans:
            label = "&nbsp;&nbsp;" * s["depth"] + s["name"]
            attrs = ", ".join(f"{k}={_fmt(v)}" for k, v in s["attrs"].items())
            out.append(f"| {label} | {_fmt(s['wall_ms'])} | "
                       f"{_fmt(s['device_ms'])} | {attrs} |\n")
    return "".join(out)


def slo_report(prefix: str = "serve.") -> str:
    """SLO table of every ``serve.*`` histogram — the north star's "heavy
    traffic" claim rendered as numbers (p50/p90/p99 + throughput)."""
    snap = _metrics.snapshot()
    hists = _hist_rows(snap, prefix)
    if not hists:
        return ("## Serve SLO report\n\n(no serve metrics recorded — "
                "enable observability with repro.obs.enable())\n")
    out = ["## Serve SLO report\n\n",
           "| metric | count | p50 | p90 | p99 | max |\n",
           "|---|---|---|---|---|---|\n"]
    for name, m in hists:
        out.append(f"| {name} | {m['count']} | {_fmt(m['p50'])} | "
                   f"{_fmt(m['p90'])} | {_fmt(m['p99'])} | {_fmt(m['max'])} |\n")
    for name, m in snap.items():
        if name.startswith(prefix) and m["type"] in ("counter", "gauge") \
                and m["value"] is not None:
            out.append(f"| {name} | - | {_fmt(m['value'])} | | | |\n")
    return "".join(out)


def cost_model_report() -> str:
    """Predicted-vs-measured per plan decision, worst mispricing first."""
    obs = _trace.events("cost_observation")
    out = ["## Cost-model accounting\n\n"]
    err = _metrics.histogram("planner.cost_model_error")
    if err.count:
        out.append(f"`cost_model_error` (measured/predicted ratio): "
                   f"p50 {_fmt(err.percentile(50))}, "
                   f"p99 {_fmt(err.percentile(99))}, "
                   f"max {_fmt(err.max)} over {err.count} observations\n\n")
    if not obs:
        out.append("(no cost observations — run a sort with tracing on)\n")
        return "".join(out)
    out.append("| op | n | k | method | predicted ns | measured ns | "
               "error x |\n|---|---|---|---|---|---|---|\n")
    key = lambda e: -(e.get("error") or 0.0)          # noqa: E731
    for e in sorted(obs, key=key):
        out.append(f"| {e.get('op')} | {e.get('n')} | {_fmt(e.get('k'))} | "
                   f"{e.get('method')} | {_fmt(e.get('predicted_ns'))} | "
                   f"{_fmt(e.get('measured_ns'))} | "
                   f"{_fmt(e.get('error'))} |\n")
    return "".join(out)
