"""Fault-tolerance runtime: preemption handling, step watchdog, straggler
detection, and elastic-restart planning.

These are the host-side pieces that make the training loop survivable at
1000+ nodes.  They are deliberately jax-free (plain clocks and signals) so
they behave identically under test and in production:

  * PreemptionHandler — converts SIGTERM/SIGINT into a "save-and-exit"
    request the train loop polls once per step (the async checkpointer makes
    the final save cheap).
  * StepWatchdog — EWMA of step wall-times; flags steps slower than
    ``threshold`` x the moving average.  On a real pod each host reports its
    flag through the coordinator; persistent stragglers get their data
    shards re-balanced / the host cordoned (hook points provided).
  * ElasticPlan — given the surviving device count, picks the largest
    usable mesh (keeps the model axis intact, shrinks data parallelism),
    and recomputes the per-host batch slice.  Checkpoints are mesh-shape-
    agnostic (see checkpoint/), so resume is restore + device_put.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional, Tuple


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._old = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def uninstall(self) -> None:
        for s, h in self._old.items():
            signal.signal(s, h)
        self._old.clear()

    def _handler(self, signum, frame) -> None:
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested


@dataclasses.dataclass
class StepWatchdog:
    """EWMA step timer with straggler flagging."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup_steps: int = 5

    def __post_init__(self):
        self.ewma: Optional[float] = None
        self.count = 0
        self.flagged: List[Tuple[int, float, float]] = []
        self._t0: Optional[float] = None
        self.on_straggler: Optional[Callable[[int, float, float], None]] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        self.count += 1
        if self.ewma is None:
            self.ewma = dt
        is_straggler = (self.count > self.warmup_steps
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self.ewma)
        # slow steps should not poison the baseline
        w = self.alpha if not is_straggler else self.alpha * 0.25
        self.ewma = (1 - w) * self.ewma + w * dt
        return dt


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    usable_devices: int
    dropped_devices: int
    global_batch: int

    @staticmethod
    def plan(n_devices: int, model_parallel: int, global_batch: int,
             want_pods: int = 1) -> "ElasticPlan":
        """Largest (pod, data, model) mesh with the model axis intact.

        The model axis must survive (parameters are TP-sharded at a fixed
        degree); elasticity comes from the data axis.  The batch stays the
        GLOBAL batch — fewer devices just means more grad-accumulation
        (handled by the train loop), so the training trajectory is
        unchanged across restarts.
        """
        if n_devices < model_parallel:
            raise ValueError(
                f"cannot keep model_parallel={model_parallel} with only "
                f"{n_devices} devices")
        data = n_devices // model_parallel
        # keep data a power of two for collective efficiency
        while data & (data - 1):
            data -= 1
        usable = data * model_parallel
        if want_pods > 1 and data % want_pods == 0:
            shape = (want_pods, data // want_pods, model_parallel)
            names = ("pod", "data", "model")
        else:
            shape = (data, model_parallel)
            names = ("data", "model")
        return ElasticPlan(mesh_shape=shape, axis_names=names,
                           usable_devices=usable,
                           dropped_devices=n_devices - usable,
                           global_batch=global_batch)

    def microbatch_for(self, reference_devices: int,
                       reference_microbatch: int) -> int:
        """Scale grad-accumulation so per-device memory stays constant."""
        scale = max(1, reference_devices // self.usable_devices)
        return reference_microbatch * scale
