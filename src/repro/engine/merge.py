"""Merge tree over sorted runs — rung two of the out-of-core sort engine.

Pairwise merge-path merges, applied level by level over a power-of-two run
count: R runs of length L become R/2 runs of length 2L, log2(R) times.
Each level is O(n) ranking work, so the whole tree is O(n log(n/run_len)) on
top of the O(n log run_len) run generation — the O(n log n) total that the
whole-array bitonic network (O(n log^2 n) CAS count) cannot reach.

Three interchangeable merge backends:

  ``xla``     rank merge in pure jnp: each element's output position is its
              own index plus a binary-searched cross-rank in the partner run
              (searchsorted), materialised with a batched scatter.
  ``pallas``  the diagonal-partitioned VMEM kernel (kernels/merge_path.py).
  ``bitonic`` the word-parallel bitonic merge box (reshape-addressed
              min/max network).  O(n log n) compare-swaps versus the other
              backends' O(n) ranking work, but every op is a branchless
              SIMD min/max — off-TPU that beats the gather-bound rank
              merge by a wide margin, so the distributed sample-sort uses
              it as its interpret-mode merge.  Needs power-of-two run
              lengths and is NOT stable (ties follow a consistent
              left-wins predicate, payloads stay attached to their keys).

``xla``/``pallas`` are ascending-stable (left run wins ties); descending
merges flip in, merge ascending, flip out.  Key-value variants carry an
int payload for argsort / top-k.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.engine import runs as _runs

MERGE_BACKENDS = ("xla", "pallas", "bitonic")


def _vsearch(sorted_rows: jnp.ndarray, queries: jnp.ndarray, side: str):
    return jax.vmap(lambda s, q: jnp.searchsorted(s, q, side=side))(
        sorted_rows, queries)


def _rank_merge(a, b, va, vb):
    """Ascending merge of (rows, L) pairs via cross-rank + gathers.

    Gather formulation (no scatter — XLA's CPU scatter is a serial loop):
    ``pa`` is each a-element's output slot; ``i[o] = #a-elements in slots
    [0..o]`` recovers, per output slot, which source to read and at what
    index, so placement is two ``take_along_axis`` plus a select.
    """
    rows, l = a.shape
    pos = jnp.arange(l, dtype=jnp.int32)
    pa = pos[None, :] + _vsearch(b, a, "left")    # a first on ties
    out_pos = jnp.broadcast_to(jnp.arange(2 * l, dtype=jnp.int32)[None, :],
                               (rows, 2 * l))
    i = _vsearch(pa, out_pos, "right")
    j = out_pos - i
    ia = jnp.clip(i - 1, 0, l - 1)
    jb = jnp.clip(j, 0, l - 1)
    from_a = jnp.diff(i, prepend=0, axis=-1) > 0
    out = jnp.where(from_a, jnp.take_along_axis(a, ia, -1),
                    jnp.take_along_axis(b, jb, -1))
    if va is None:
        return out, None
    vout = jnp.where(from_a, jnp.take_along_axis(va, ia, -1),
                     jnp.take_along_axis(vb, jb, -1))
    return out, vout


def _bitonic_box_merge(a, b, va, vb):
    """Merge box over concat(a, reverse(b)) — a bitonic sequence, so only
    the log2(2L) merge substages are needed, each a (pairs, 2, j) reshape
    view + min/max (the same reshape-addressed form as
    ``distributed_sort.bitonic_merge_halves``; gather chains would stall
    XLA's CPU compiler).  With a payload the comparator is an explicit
    a<=b predicate so payloads follow their keys through every swap."""
    rows, l = a.shape
    if l & (l - 1):
        raise ValueError(
            f"bitonic merge backend needs power-of-two run lengths, got {l}")
    n = 2 * l
    z = jnp.concatenate([a, jnp.flip(b, -1)], -1)
    w = None if va is None else jnp.concatenate([va, jnp.flip(vb, -1)], -1)
    j = n // 2
    while j >= 1:
        zv = z.reshape(rows, n // (2 * j), 2, j)
        ka, kb = zv[:, :, 0, :], zv[:, :, 1, :]
        if w is None:
            z = jnp.stack([jnp.minimum(ka, kb), jnp.maximum(ka, kb)],
                          axis=2).reshape(rows, n)
        else:
            wv = w.reshape(rows, n // (2 * j), 2, j)
            pa, pb = wv[:, :, 0, :], wv[:, :, 1, :]
            pred = ka <= kb
            z = jnp.stack([jnp.where(pred, ka, kb), jnp.where(pred, kb, ka)],
                          axis=2).reshape(rows, n)
            w = jnp.stack([jnp.where(pred, pa, pb), jnp.where(pred, pb, pa)],
                          axis=2).reshape(rows, n)
        j //= 2
    return z, w


def merge_pairs(a: jnp.ndarray, b: jnp.ndarray, *, descending: bool = False,
                backend: str = "xla", values: Tuple = (None, None),
                interpret: Optional[bool] = None):
    """Merge row-wise sorted (rows, L) a and b -> (rows, 2L) (+ payloads)."""
    if backend not in MERGE_BACKENDS:
        raise ValueError(
            f"merge backend must be one of {MERGE_BACKENDS}, got {backend!r}")
    va, vb = values
    if descending:
        # flip to ascending AND swap the pair: the ascending merge's
        # left-wins-ties rule turns into right-wins after the final flip,
        # so swapping roles restores "a first on equal keys" — keeping
        # stable pipelines stable in both directions.
        a, b = jnp.flip(b, -1), jnp.flip(a, -1)
        va, vb = (None if vb is None else jnp.flip(vb, -1),
                  None if va is None else jnp.flip(va, -1))
    if backend == "pallas":
        from repro.kernels import merge_path as _mp
        if va is None:
            out, vout = _mp.merge_pairs_blocks(a, b, interpret=interpret), None
        else:
            out, vout = _mp.merge_pairs_kv_blocks(a, b, va, vb,
                                                  interpret=interpret)
    elif backend == "bitonic":
        out, vout = _bitonic_box_merge(a, b, va, vb)
    else:
        out, vout = _rank_merge(a, b, va, vb)
    if descending:
        out = jnp.flip(out, -1)
        vout = None if vout is None else jnp.flip(vout, -1)
    return (out, vout) if values[0] is not None else out


def merge_runs(run_keys: jnp.ndarray, run_vals: Optional[jnp.ndarray] = None,
               *, descending: bool = False, backend: str = "xla",
               interpret: Optional[bool] = None):
    """Collapse (rows, R, L) sorted runs into one (rows, R*L) sorted row.

    R must be a power of two (run generation guarantees it).  This is the
    k-way merge realised as a complete tournament of pairwise merge-path
    merges — log2(R) levels, each touching every element once.
    """
    rows, r, l = run_keys.shape
    if r & (r - 1):
        raise ValueError(f"run count must be a power of two, got {r}")
    keys, vals = run_keys, run_vals
    while r > 1:
        kv = keys.reshape(rows * (r // 2), 2, l)
        a, b = kv[:, 0, :], kv[:, 1, :]
        if vals is None:
            merged = merge_pairs(a, b, descending=descending, backend=backend,
                                 interpret=interpret)
        else:
            vv = vals.reshape(rows * (r // 2), 2, l)
            merged, mvals = merge_pairs(
                a, b, descending=descending, backend=backend,
                values=(vv[:, 0, :], vv[:, 1, :]), interpret=interpret)
            vals = mvals.reshape(rows, r // 2, 2 * l)
        keys = merged.reshape(rows, r // 2, 2 * l)
        r //= 2
        l *= 2
    keys = keys.reshape(rows, l)
    if run_vals is None:
        return keys
    return keys, vals.reshape(rows, l)


def _pad_value(dtype, descending: bool):
    """Pad that keeps a sorted run sorted when appended: the top of the
    dtype's TOTAL order in the merge direction.  For ascending floats that
    is NaN, not +inf — the sort backends and searchsorted both order NaN
    after +inf, so an inf sentinel appended after genuine NaNs would leave
    the padded run unsorted and corrupt every cross-rank.  (Descending
    runs end at -inf; genuine NaNs sort to the *front*, so the -inf
    sentinel stays correct.)"""
    if not descending and jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.nan, dtype)
    return _runs.sort_sentinel(dtype, descending)


def kway_merge(arrays: Sequence[jnp.ndarray], *, descending: bool = False,
               backend: str = "xla",
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Merge k independently sorted 1-D arrays into one sorted array.

    Arrays may have different lengths; each is padded to a common
    power-of-two run length with the direction's total-order pad, and the
    pad is sliced off the far end of the result.
    """
    if not arrays:
        raise ValueError("need at least one array")
    arrays = [jnp.ravel(a) for a in arrays]
    dtype = arrays[0].dtype
    total = sum(a.shape[0] for a in arrays)
    l = _runs.next_pow2(max(a.shape[0] for a in arrays))
    r = _runs.next_pow2(len(arrays))
    sent = _pad_value(dtype, descending)
    padded = [jnp.pad(a, (0, l - a.shape[0]), constant_values=sent)
              for a in arrays]
    padded += [jnp.full((l,), sent, dtype)] * (r - len(arrays))
    stacked = jnp.stack(padded)[None, :, :]
    merged = merge_runs(stacked, descending=descending, backend=backend,
                        interpret=interpret)
    return merged[0, :total]


def kway_merge_kv(keys: Sequence[jnp.ndarray], vals: Sequence[jnp.ndarray],
                  *, descending: bool = False, backend: str = "xla",
                  interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge k independently sorted 1-D (key, payload) arrays.

    The key-only :func:`kway_merge` slices its sentinel padding off the far
    end of the tournament output — value-identical for keys, but with a
    payload attached a pad slot from an *earlier* run ties with a genuine
    sentinel-valued key from a later run, wins on the left-first rule, and
    displaces the genuine payload past the slice boundary.  So the kv
    variant runs the tournament on (key, concatenation-position) pairs and
    drops pad slots by position afterwards: a pad can never shadow a
    genuine element, whatever its key.  Stable for the ``xla``/``pallas``
    backends (ties keep array order, i.e. earlier array first).

    Eager-only: the final compaction is a data-dependent boolean gather —
    fine for the spill tier's host-side merge driver, not jittable.
    """
    if not keys or len(keys) != len(vals):
        raise ValueError("need matching non-empty key/payload array lists")
    keys = [jnp.ravel(a) for a in keys]
    vals = [jnp.ravel(v) for v in vals]
    for a, v in zip(keys, vals):
        if a.shape != v.shape:
            raise ValueError(
                f"key/payload length mismatch: {a.shape} vs {v.shape}")
    dtype = keys[0].dtype
    total = sum(a.shape[0] for a in keys)
    l = _runs.next_pow2(max(1, max(a.shape[0] for a in keys)))
    r = _runs.next_pow2(len(keys))
    sent = _pad_value(dtype, descending)
    pk, pp, off = [], [], 0
    for a in keys:
        m = a.shape[0]
        pk.append(jnp.pad(a, (0, l - m), constant_values=sent))
        pos = jnp.arange(off, off + m, dtype=jnp.int32)
        pp.append(jnp.pad(pos, (0, l - m), constant_values=total))
        off += m
    pk += [jnp.full((l,), sent, dtype)] * (r - len(keys))
    pp += [jnp.full((l,), total, jnp.int32)] * (r - len(keys))
    mk, mp = merge_runs(jnp.stack(pk)[None, :, :], jnp.stack(pp)[None, :, :],
                        descending=descending, backend=backend,
                        interpret=interpret)
    mk, mp = mk[0], mp[0]
    genuine = mp < total
    mk, mp = mk[genuine], mp[genuine]
    return mk, jnp.take(jnp.concatenate(vals), mp)
