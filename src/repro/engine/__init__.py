"""repro.engine — hierarchical out-of-core sort engine.

Completes the memory hierarchy between one VMEM tile (kernels/bitonic_sort)
and the device mesh (core/distributed_sort):

    SRAM array  ->  VMEM tile  ->  engine runs + merge tree  ->  mesh shards

``sort`` / ``argsort`` / ``topk`` here accept any array size: tiled run
generation (runs.py) sorts VMEM-sized pieces with an existing backend, a
merge-path merge tree (merge.py, kernels/merge_path.py) combines them in
O(n log n) total work, and the cost-model planner (planner.py) decides when
the hierarchy pays for itself versus handing the whole array to one backend.
``sort_api`` exposes all of this as ``method="merge"`` and ``method="auto"``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.engine import merge as merge  # noqa: F401  (re-export)
from repro.engine import planner, runs
from repro.engine.merge import kway_merge, merge_pairs, merge_runs  # noqa: F401
from repro.engine.planner import Plan, calibrate, choose, choose_method  # noqa: F401
from repro.engine.segmented import (  # noqa: F401
    group_tokens_by_expert, segment_ids_from_row_splits, segmented_argsort,
    segmented_sort, sort_padded_rows)


# the same axis-flattening helpers the kernel entry points use
from repro.kernels.ops import _from_rows, _to_rows


def _delegate_sort(x, axis, descending, method):
    from repro.core import sort_api
    return sort_api.sort(x, axis=axis, method=method, descending=descending)


def sort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
         method: str = "auto", run_len: Optional[int] = None,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sort along ``axis``; sizes beyond one tile go through runs + merges.

    ``method`` is "auto" (cost-model pick), "merge" (force the engine), or
    any concrete ``sort_api`` backend to delegate to.
    """
    x2, lead, ax = _to_rows(x, axis)
    batch, n = x2.shape
    plan = planner.choose(n, batch, x.dtype, requested=method,
                          run_len=run_len)
    if plan.method != "merge":
        return _delegate_sort(x, ax, descending, plan.method)
    rg = runs.generate_runs(x2, plan.run_len, method=plan.run_method,
                            descending=descending, interpret=interpret)
    merged = merge_runs(rg, descending=descending,
                        backend=plan.merge_backend, interpret=interpret)
    return _from_rows(merged[:, :n], lead, ax)


def argsort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
            method: str = "auto", stable: bool = False,
            run_len: Optional[int] = None,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sorting permutation along ``axis`` via the key-value engine path.

    ``stable=True`` forces a stable pipeline: stable tile sort ("xla" run
    backend) + merge-path merges (stable by construction), regardless of the
    planner's backend preference — segmented sort and MoE grouping rely on
    this.
    """
    x2, lead, ax = _to_rows(x, axis)
    batch, n = x2.shape
    plan = planner.choose(n, batch, x.dtype, requested=method,
                          run_len=run_len)
    if plan.method != "merge" and not stable:
        from repro.core import sort_api
        method_ = plan.method if plan.method != "imc" else "xla"
        return sort_api.argsort(x, axis=ax, method=method_,
                                descending=descending)
    run_method = "xla" if stable else plan.run_method
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :],
                           x2.shape)
    rk, rv = runs.generate_runs_kv(x2, idx, plan.run_len, method=run_method,
                                   descending=descending, interpret=interpret)
    _, order = merge_runs(rk, rv, descending=descending,
                          backend=plan.merge_backend, interpret=interpret)
    return _from_rows(order[:, :n], lead, ax)


def topk(x: jnp.ndarray, k: int, *, method: str = "auto",
         run_len: Optional[int] = None,
         interpret: Optional[bool] = None
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k along the last axis -> (values, indices), descending.

    Engine path: per-run top-k candidates (the paper's partition-then-merge,
    §II-B) followed by a key-value merge tree over the k-prefixes.
    """
    x2, lead, _ = _to_rows(x, -1)
    batch, n = x2.shape
    if not 0 < k <= n:
        raise ValueError(f"k must be in (0, {n}], got {k}")
    plan = planner.choose(n, batch, x.dtype, requested=method,
                          run_len=run_len)
    if plan.method != "merge":
        from repro.core import sort_api
        method_ = plan.method if plan.method != "imc" else "xla"
        v, i = sort_api.topk(x2, k, method=method_)
        return v.reshape(*lead, k), i.reshape(*lead, k)
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], x2.shape)
    rk, rv = runs.generate_runs_kv(x2, idx, plan.run_len,
                                   method=plan.run_method, descending=True,
                                   interpret=interpret)
    # candidate prefixes: only the first k of each run can reach the top k
    kk = runs.next_pow2(min(k, rk.shape[-1]))
    ck, cv = rk[..., :kk], rv[..., :kk]
    mk, mv = merge_runs(ck, cv, descending=True, backend=plan.merge_backend,
                        interpret=interpret)
    return mk[:, :k].reshape(*lead, k), mv[:, :k].reshape(*lead, k)
