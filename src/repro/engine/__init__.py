"""repro.engine — hierarchical out-of-core sort engine.

Completes the memory hierarchy between one VMEM tile (kernels/bitonic_sort)
and the device mesh (core/distributed_sort):

    SRAM array  ->  VMEM tile  ->  engine runs + merge tree  ->  mesh shards

``sort`` / ``argsort`` / ``topk`` / ``sort_kv`` here accept any array size:
tiled run generation (runs.py) sorts VMEM-sized pieces with a registered
backend, a merge-path merge tree (merge.py, kernels/merge_path.py) combines
them in O(n log n) total work, and the cost-model planner (planner.py)
decides when the hierarchy pays for itself versus handing the whole array
to one backend.  The engine is the *execution* layer under the SortSpec
front door (repro.sort): plans come from ``planner.choose_cached`` and
single-backend work is delegated through the registry
(core/sortspec.py), never by backend name.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sortspec
from repro.engine import merge as merge  # noqa: F401  (re-export)
from repro.engine import planner, runs
from repro.engine.merge import kway_merge, merge_pairs, merge_runs  # noqa: F401
from repro.engine.planner import (  # noqa: F401
    DistPlan, Plan, calibrate, choose, choose_cached, choose_distributed,
    choose_distributed_cached, choose_method, clear_plan_cache)
from repro.engine.samplesort import sample_sort  # noqa: F401
from repro.engine.segmented import (  # noqa: F401
    group_tokens_by_expert, segment_ids_from_row_splits, segmented_argsort,
    segmented_sort, sort_padded_rows)


# the same axis-flattening helpers the kernel entry points use
from repro.kernels.ops import _from_rows, _to_rows
from repro.obs import trace as _obs


def _obs_finish(sp, op: str, plan: planner.Plan, n: int, batch: int,
                k: Optional[int] = None) -> None:
    """Pair a fenced span with its plan: record the predicted-vs-measured
    ``cost_observation`` event and the ``cost_model_error`` ratio metric.

    The 313ms-vs-3.4ms top-k inversion class of bug surfaces here as a
    two-orders-of-magnitude error ratio instead of hiding in a CSV.  No-op
    when observability is off (``sp`` is the no-op span) or when the call
    ran under an outer jit (no fence -> no honest device time).  The first
    call at a new shape includes compile time — cold and warm observations
    both land in the histogram, like the bench's cold/warm split.
    """
    if sp.device_ms is None:
        return
    predicted = plan.costs.get(plan.method)
    if not predicted or predicted != predicted or predicted == float("inf"):
        return
    measured_ns = sp.device_ms * 1e6
    error = measured_ns / predicted
    _obs.record_event("cost_observation", op=op, n=n, batch=batch, k=k,
                      method=plan.method, predicted_ns=predicted,
                      measured_ns=measured_ns, error=error)
    from repro.obs import metrics as _metrics
    _metrics.histogram("planner.cost_model_error").observe(error)
    # closed-loop autotuning (opt-in, REPRO_AUTOTUNE=1): when the error
    # histogram says the active constants have drifted off this device,
    # re-probe and swap in a fresh profile — see tuning.refresh_if_stale
    from repro.core import tuning as _tuning
    _tuning.maybe_refresh()


def _spill_fallback(plan: planner.Plan, x2) -> planner.Plan:
    """The spill tier is host-driven (blocking D2H, data-dependent merge
    cursors) and cannot run under an outer ``jit``: for tracer inputs a
    spill plan degrades to the on-device merge pipeline — the best plan
    that *can* execute in the trace, at the caller's own memory risk."""
    if plan.method == "spill" and isinstance(x2, jax.core.Tracer):
        return dataclasses.replace(plan, method="merge")
    return plan


# ---------------------------------------------------------------------------
# merge pipeline over rows form — what the "merge" backend executes
# ---------------------------------------------------------------------------

def merge_sort_rows(x2: jnp.ndarray, *, descending: bool, plan: planner.Plan,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """(rows, n) -> sorted rows via run generation + the merge tree."""
    rg = runs.generate_runs(x2, plan.run_len, method=plan.run_method,
                            descending=descending, interpret=interpret)
    merged = merge_runs(rg, descending=descending,
                        backend=plan.merge_backend, interpret=interpret)
    return merged[:, :x2.shape[-1]]


def merge_sort_rows_kv(k2: jnp.ndarray, v2: jnp.ndarray, *, descending: bool,
                       plan: planner.Plan, stable: bool = False,
                       interpret: Optional[bool] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Key-value merge pipeline.  ``stable=True`` forces a stable tile sort
    ("xla" run backend) so the whole pipeline is stable (merge-path merges
    are stable by construction)."""
    run_method = "xla" if stable else plan.run_method
    rk, rv = runs.generate_runs_kv(k2, v2, plan.run_len, method=run_method,
                                   descending=descending, interpret=interpret)
    mk, mv = merge_runs(rk, rv, descending=descending,
                        backend=plan.merge_backend, interpret=interpret)
    n = k2.shape[-1]
    return mk[:, :n], mv[:, :n]


# ---------------------------------------------------------------------------
# public entry points (any array size, planner-dispatched)
# ---------------------------------------------------------------------------

def sort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
         method: str = "auto", run_len: Optional[int] = None,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sort along ``axis``; sizes beyond one tile go through runs + merges.

    ``method`` is "auto" (cost-model pick), "merge" (force the engine), or
    any registered backend name to delegate to.
    """
    x2, lead, ax = _to_rows(x, axis)
    batch, n = x2.shape
    plan = _spill_fallback(
        planner.choose_cached(n, batch, x.dtype, requested=method,
                              run_len=run_len), x2)
    sp = _obs.trace("engine.sort", n=n, batch=batch, method=plan.method)
    with sp:
        if plan.method == "merge":
            out = merge_sort_rows(x2, descending=descending, plan=plan,
                                  interpret=interpret)
        else:
            out = sortspec.get_backend(plan.method).sort(
                x2, descending=descending, plan=plan, interpret=interpret)
        sp.fence(out)
    _obs_finish(sp, "sort", plan, n, batch)
    return _from_rows(out, lead, ax)


def sort_kv(keys: jnp.ndarray, values: jnp.ndarray, *, axis: int = -1,
            descending: bool = False, method: str = "auto",
            stable: bool = False, run_len: Optional[int] = None,
            interpret: Optional[bool] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort ``keys`` along ``axis`` carrying ``values`` with them.

    ``stable=True`` forces the engine's stable pipeline regardless of the
    planner's backend preference — segmented sort and MoE grouping rely on
    equal keys keeping their input order.
    """
    k2, lead, ax = _to_rows(keys, axis)
    v2, _, _ = _to_rows(values, axis)
    batch, n = k2.shape
    plan = _spill_fallback(
        planner.choose_cached(n, batch, keys.dtype, requested=method,
                              run_len=run_len), k2)
    sp = _obs.trace("engine.sort_kv", n=n, batch=batch, method=plan.method)
    with sp:
        sk = sv = None
        if plan.method != "merge":
            be = sortspec.get_backend(plan.method)
            if not stable or be.capabilities.stable:
                sk, sv = be.sort_kv(k2, v2, descending=descending, plan=plan,
                                    interpret=interpret)
        if sk is None:
            sk, sv = merge_sort_rows_kv(k2, v2, descending=descending,
                                        plan=plan, stable=stable,
                                        interpret=interpret)
        sp.fence((sk, sv))
    _obs_finish(sp, "sort_kv", plan, n, batch)
    return _from_rows(sk, lead, ax), _from_rows(sv, lead, ax)


def argsort(x: jnp.ndarray, *, axis: int = -1, descending: bool = False,
            method: str = "auto", stable: bool = False,
            run_len: Optional[int] = None,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sorting permutation along ``axis`` via the key-value engine path.

    ``stable=True`` forces a stable pipeline: a stable backend if the plan
    resolved to one, else stable tile sort + merge-path merges (stable by
    construction), regardless of the planner's preference.
    """
    x2, lead, ax = _to_rows(x, axis)
    batch, n = x2.shape
    plan = _spill_fallback(
        planner.choose_cached(n, batch, x.dtype, requested=method,
                              run_len=run_len), x2)
    sp = _obs.trace("engine.argsort", n=n, batch=batch, method=plan.method)
    with sp:
        order = None
        if plan.method != "merge":
            be = sortspec.get_backend(plan.method)
            if not stable or be.capabilities.stable:
                order = be.argsort(x2, descending=descending, plan=plan,
                                   interpret=interpret)
        if order is None:
            idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :],
                                   x2.shape)
            _, order = merge_sort_rows_kv(x2, idx, descending=descending,
                                          plan=plan, stable=stable,
                                          interpret=interpret)
        sp.fence(order)
    _obs_finish(sp, "argsort", plan, n, batch)
    return _from_rows(order, lead, ax)


def topk(x: jnp.ndarray, k: int, *, method: str = "auto",
         run_len: Optional[int] = None,
         interpret: Optional[bool] = None
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k along the last axis -> (values, indices), descending.

    The plan is k-aware: ``method="auto"`` weighs O(n·passes) radix
    selection (the "select" backend) against sort-prefix on every sort
    backend, so ``k ≪ n`` workloads never pay for a full sort.  Engine
    path: per-run top-k candidates (the paper's partition-then-merge,
    §II-B) followed by a key-value merge tree over the k-prefixes.
    """
    x2, lead, _ = _to_rows(x, -1)
    batch, n = x2.shape
    if not 1 <= k <= n:
        raise ValueError(
            f"topk k must satisfy 1 <= k <= n (n={n}); got k={k}")
    plan = planner.choose_cached(n, batch, x.dtype, requested=method,
                                 run_len=run_len, k=k)
    sp = _obs.trace("engine.topk", n=n, batch=batch, k=k, method=plan.method)
    with sp:
        if plan.method != "merge":
            v, i = sortspec.get_backend(plan.method).topk(
                x2, k, plan=plan, interpret=interpret)
        else:
            idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :],
                                   x2.shape)
            rk, rv = runs.generate_runs_kv(x2, idx, plan.run_len,
                                           method=plan.run_method,
                                           descending=True,
                                           interpret=interpret)
            # candidate prefixes: only the first k of each run can reach
            # the top k
            kk = runs.next_pow2(min(k, rk.shape[-1]))
            ck, cv = rk[..., :kk], rv[..., :kk]
            mk, mv = merge_runs(ck, cv, descending=True,
                                backend=plan.merge_backend,
                                interpret=interpret)
            v, i = mk[:, :k], mv[:, :k]
        sp.fence((v, i))
    _obs_finish(sp, "topk", plan, n, batch, k)
    return v.reshape(*lead, k), i.reshape(*lead, k)
