"""Tiled run generation — rung one of the out-of-core sort engine.

An arbitrarily large (batched) array is cut into VMEM-sized tiles ("runs"),
each sorted independently by one of the registered single-tile backends; the
merge tree (engine/merge.py) then combines runs into the full result.  This
is the paper's partitioned-macro structure (§II-B) lifted one level: SRAM
subarray -> CAS partition becomes HBM array -> VMEM run.

Runs are padded to ``n_tiles * run_len`` where ``n_tiles`` is a power of two
(so the merge tree is a complete binary tree); padding carries the dtype's
sort sentinel so it falls to the far end and is sliced off after the merge.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import tuning as _tuning

# historical alias — the constant's home is the tuning layer; callers that
# want the *measured* run length for this device resolve it through
# ``tuning.active().run_len`` (``run_len=None`` below does exactly that)
DEFAULT_RUN_LEN = _tuning.DEFAULT_RUN_LEN

RUN_METHODS = ("xla", "bitonic", "pallas", "radix")


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def sort_sentinel(dtype, descending: bool):
    """Value that sorts to the end of the array for the given direction."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if descending else jnp.inf, dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if descending else info.max, dtype)


def run_layout(n: int, run_len: int) -> Tuple[int, int]:
    """(n_tiles, padded_n) for sorting ``n`` elements with ``run_len`` tiles.

    ``run_len`` is rounded up to a power of two: the Pallas tile sort and
    the merge-path kernel both address power-of-two rows.
    """
    run_len = min(next_pow2(run_len), next_pow2(n))
    n_tiles = next_pow2(-(-n // run_len))
    return n_tiles, n_tiles * run_len


def _pad_rows(x: jnp.ndarray, m: int, fill) -> jnp.ndarray:
    n = x.shape[-1]
    if m == n:
        return x
    return jnp.pad(x, ((0, 0), (0, m - n)), constant_values=fill)


def _sort_tiles(tiles: jnp.ndarray, method: str, descending: bool,
                interpret: Optional[bool]) -> jnp.ndarray:
    """Sort each row of (rows*n_tiles, run_len) with the chosen backend."""
    if method == "xla":
        out = jnp.sort(tiles, axis=-1)
        return jnp.flip(out, axis=-1) if descending else out
    if method == "bitonic":
        from repro.core import sort_api
        return sort_api.bitonic_sort(tiles, axis=-1, descending=descending)
    if method == "pallas":
        from repro.kernels import bitonic_sort as _bs
        return _bs.sort_blocks(tiles, descending=descending,
                               interpret=interpret)
    if method == "radix":
        from repro.core import keycodec
        from repro.kernels import radix_sort as _rs
        enc = keycodec.encode(tiles, descending=descending)
        out = _rs.sort_blocks(enc, interpret=interpret)
        return keycodec.decode(out, tiles.dtype, descending=descending)
    raise ValueError(f"run method must be one of {RUN_METHODS}, got {method!r}")


def _sort_tiles_kv(keys: jnp.ndarray, vals: jnp.ndarray, method: str,
                   descending: bool, interpret: Optional[bool]):
    if method == "xla":
        if descending:
            # stable descending (ties keep ascending index order): stable
            # ascending argsort of the reversed row, mapped back and flipped
            order = jnp.flip(jnp.argsort(
                jnp.flip(keys, -1), axis=-1, stable=True), -1)
            order = keys.shape[-1] - 1 - order
        else:
            order = jnp.argsort(keys, axis=-1, stable=True)
        return (jnp.take_along_axis(keys, order, axis=-1),
                jnp.take_along_axis(vals, order, axis=-1))
    if method == "bitonic":
        from repro.core import sort_api
        return sort_api.bitonic_sort(keys, axis=-1, descending=descending,
                                     values=vals)
    if method == "pallas":
        from repro.kernels import bitonic_sort as _bs
        return _bs.sort_kv_blocks(keys, vals, descending=descending,
                                  interpret=interpret)
    if method == "radix":
        # stable (like "xla"): safe for the engine's stable kv pipelines
        from repro.core import keycodec
        from repro.kernels import radix_sort as _rs
        enc = keycodec.encode(keys, descending=descending)
        sk, sv = _rs.sort_kv_blocks(enc, vals, interpret=interpret)
        return keycodec.decode(sk, keys.dtype, descending=descending), sv
    raise ValueError(f"run method must be one of {RUN_METHODS}, got {method!r}")


def generate_runs(x: jnp.ndarray, run_len: Optional[int] = None, *,
                  method: str = "xla", descending: bool = False,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """(rows, n) -> (rows, n_tiles, run_len) independently sorted runs.

    ``run_len=None`` resolves the active tuning profile's measured run
    length for this device."""
    if run_len is None:
        run_len = _tuning.active().run_len
    rows, n = x.shape
    n_tiles, m = run_layout(n, run_len)
    run_len = m // n_tiles
    x = _pad_rows(x, m, sort_sentinel(x.dtype, descending))
    tiles = x.reshape(rows * n_tiles, run_len)
    out = _sort_tiles(tiles, method, descending, interpret)
    return out.reshape(rows, n_tiles, run_len)


def generate_runs_kv(keys: jnp.ndarray, vals: jnp.ndarray,
                     run_len: Optional[int] = None, *,
                     method: str = "xla", descending: bool = False,
                     interpret: Optional[bool] = None):
    """Key-value run generation: payloads follow their keys into the runs."""
    if run_len is None:
        run_len = _tuning.active().run_len
    rows, n = keys.shape
    n_tiles, m = run_layout(n, run_len)
    run_len = m // n_tiles
    keys = _pad_rows(keys, m, sort_sentinel(keys.dtype, descending))
    # pad payloads with out-of-range positions so callers can identify them
    vals = _pad_rows(vals, m, jnp.array(n, vals.dtype))
    sk, sv = _sort_tiles_kv(keys.reshape(rows * n_tiles, run_len),
                            vals.reshape(rows * n_tiles, run_len),
                            method, descending, interpret)
    return (sk.reshape(rows, n_tiles, run_len),
            sv.reshape(rows, n_tiles, run_len))
