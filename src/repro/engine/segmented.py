"""Segmented sort over ragged row groups.

Serving length-buckets and MoE expert groups both need "sort within each
group" where groups are ragged: a flat token stream plus segment ids (or row
splits).  Done as a composite two-pass sort:

  1. order the values with the engine (any backend, need not be stable);
  2. stably re-order that permutation by segment id, so groups come out
     contiguous and each group's interior stays value-sorted.

The stable second pass runs through the engine's merge path (merge-path
merges are stable by construction when runs are generated with a stable tile
sort), so segmented sort scales exactly like the flat engine sort.

Padded-batch variant (``sort_padded_rows``) covers the scheduler's
fixed-shape buckets: rows valid up to ``lengths[i]``, tail restored after
the sort.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import sortspec


def _resolve(method: Optional[str]) -> str:
    """None -> the ambient sort_defaults method (API v2), default "auto"."""
    if method is not None:
        return method
    return sortspec.default("method") or "auto"


def segment_ids_from_row_splits(row_splits: jnp.ndarray,
                                n: int) -> jnp.ndarray:
    """[0, 3, 5, n] -> [0,0,0,1,1,2,...]: dense ids from boundaries."""
    pos = jnp.arange(n, dtype=jnp.int32)
    return (jnp.searchsorted(row_splits, pos, side="right") - 1).astype(
        jnp.int32)


def segmented_argsort(values: jnp.ndarray, segment_ids: jnp.ndarray, *,
                      descending: bool = False,
                      method: Optional[str] = None,
                      run_len: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Permutation grouping ``values`` by segment, value-sorted per group.

    ``values`` and ``segment_ids`` are flat (n,) or batched (..., n) with
    segment ids non-decreasing or not — groups need not be contiguous on
    input; they are contiguous (in ascending segment-id order) in the output
    permutation.
    """
    from repro import engine
    method = _resolve(method)
    order1 = engine.argsort(values, method=method, descending=descending,
                            run_len=run_len, interpret=interpret)
    seg1 = jnp.take_along_axis(segment_ids, order1, axis=-1)
    order2 = engine.argsort(seg1, method=method, stable=True,
                            run_len=run_len, interpret=interpret)
    return jnp.take_along_axis(order1, order2, axis=-1)


def segmented_sort(values: jnp.ndarray, segment_ids: jnp.ndarray, *,
                   descending: bool = False, method: Optional[str] = None,
                   run_len: Optional[int] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(sorted values, grouped segment ids), groups contiguous & ascending."""
    order = segmented_argsort(values, segment_ids, descending=descending,
                              method=method, run_len=run_len,
                              interpret=interpret)
    return (jnp.take_along_axis(values, order, axis=-1),
            jnp.take_along_axis(segment_ids, order, axis=-1))


def sort_padded_rows(values: jnp.ndarray, lengths: jnp.ndarray, *,
                     descending: bool = False, method: Optional[str] = None,
                     fill_value=0, run_len: Optional[int] = None,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Sort each row's valid prefix of a padded (rows, L) batch.

    Positions >= lengths[row] are padding; they are pushed past the valid
    prefix during the sort and rewritten with ``fill_value`` afterwards, so
    the ragged layout is preserved.
    """
    from repro import engine
    from repro.engine import runs as _runs
    method = _resolve(method)
    rows, l = values.shape
    pos = jnp.arange(l, dtype=jnp.int32)[None, :]
    valid = pos < lengths[:, None]
    sent = _runs.sort_sentinel(values.dtype, descending)
    masked = jnp.where(valid, values, sent)
    out = engine.sort(masked, method=method, descending=descending,
                      run_len=run_len, interpret=interpret)
    return jnp.where(valid, out, jnp.array(fill_value, values.dtype))


def group_tokens_by_expert(expert_ids: jnp.ndarray, num_experts: int, *,
                           method: Optional[str] = None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE dispatch order: (permutation, row_splits) grouping tokens by expert.

    The permutation is stable (tokens keep arrival order inside each expert
    group), which is what capacity-truncation policies assume.
    """
    from repro import engine
    perm = engine.argsort(expert_ids, method=_resolve(method), stable=True)
    counts = jnp.bincount(expert_ids.reshape(-1), length=num_experts)
    row_splits = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)])
    return perm, row_splits
