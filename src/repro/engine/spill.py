"""Out-of-core spill-to-host sort tier — sorting past device memory.

The paper's thesis is that off-chip data movement, not compute, dominates
sorting cost; its answer on-chip is the partition/temp-row structure that
keeps operands next to the compute.  This module is the same structure one
level up the hierarchy, for arrays that do not fit on the device at all:

    cut     the host-resident input into device-sized chunks
            (``spill_threshold_bytes`` worth of keys, the same knob the
            planner auto-routes on),
    sort    each chunk on device through the existing registry
            (``repro.engine.sort``, ``method="auto"`` — keycodec, radix,
            merge pipeline, whatever the planner prices cheapest at the
            chunk size),
    spill   sorted runs back to host memory with **double-buffered
            transfers**: chunk ``i+1``'s H2D + device sort are dispatched
            (jax's async dispatch returns futures) *before* blocking on
            chunk ``i``'s D2H, so the link transfer overlaps kernel work,
    merge   the host-resident runs with a k-way merge-path: exact stable
            per-run cursors at every output-block boundary (multi-sequence
            selection by bisection over ``np.searchsorted`` cross-ranks),
            each block's slices merged on device by the engine's merge
            tournament (``merge.kway_merge`` / ``kway_merge_kv``).

Results come back as **host** (numpy) arrays — an out-of-core sort that
ended with one device-resident array would defeat itself.  The engine
front door (``plan.method == "spill"``) converts back to jnp for API
symmetry at sizes where that is representable.

Observability (when ``repro.obs`` tracing is on): a ``spill.sort`` span
over the whole pipeline with per-chunk ``spill.chunk`` child spans and a
``spill.merge_block`` span per output block; ``spill.h2d_bytes`` /
``spill.d2h_bytes`` counters for every byte that crosses the link; and a
``spill.overlap_fraction`` gauge — the fraction of the spill phase's wall
time NOT spent blocked in D2H waits (1.0 = transfers fully hidden behind
chunk sorts, 0.0 = fully serial).

An optional wire-compression hook (``codec=``) mirrors the optimizer's
``grad_compress`` int8 path: sorted float runs are quantized per-run on
spill and dequantized at merge time.  Quantization is monotonic, so runs
stay sorted and the result is exactly the sort of the quantized data —
but it is LOSSY on the key values, so it is opt-in, for
fidelity-tolerant pipelines (fingerprint streams, score shuffles), never
part of auto dispatch.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tuning as _tuning
from repro.engine import merge as _merge
from repro.obs import metrics as _metrics
from repro.obs import trace as _obs

__all__ = [
    "chunk_elems", "spill_sort", "spill_sort_kv", "spill_argsort",
    "sort_rows", "sort_rows_kv", "argsort_rows",
]


def chunk_elems(itemsize: int, chunk_bytes: Optional[int] = None) -> int:
    """Elements of a given width per device chunk.  ``chunk_bytes`` defaults
    to the active profile's ``spill_threshold_bytes`` — the spill tier's
    chunks are exactly the largest arrays the planner will NOT spill."""
    cb = chunk_bytes if chunk_bytes is not None \
        else _tuning.active().spill_threshold_bytes
    if cb < _tuning.MIN_SPILL_THRESHOLD_BYTES:
        raise ValueError(
            f"chunk_bytes must be >= {_tuning.MIN_SPILL_THRESHOLD_BYTES}, "
            f"got {cb}")
    return max(2, int(cb) // max(1, int(itemsize)))


# ---------------------------------------------------------------------------
# optional wire compression (grad_compress's int8 scheme, split in two)
# ---------------------------------------------------------------------------

def _int8_encode(a: np.ndarray) -> Tuple[np.ndarray, float]:
    """Per-run symmetric int8 quantization — the same scheme as
    ``repro.optim.grad_compress``'s int8 codec (per-tensor absmax scale),
    applied per spilled run.  Monotonic, so a sorted run stays sorted."""
    scale = float(np.max(np.abs(a))) / 127.0 if a.size else 0.0
    if scale == 0.0 or not np.isfinite(scale):
        scale = 1.0
    q = np.clip(np.rint(a.astype(np.float32) / scale), -127, 127)
    return q.astype(np.int8), scale


def _int8_decode(q: np.ndarray, scale: float, dtype) -> np.ndarray:
    return (q.astype(np.float32) * scale).astype(dtype)


class _RunStore:
    """Host-resident sorted runs, optionally held compressed.

    ``codec=None`` stores raw numpy runs.  ``codec="int8"`` stores each
    run quantized (4x fewer host bytes for f32 keys) and dequantizes at
    merge time; the savings land on the ``spill.codec_bytes_saved``
    counter.  A ``(encode, decode)`` callable pair plugs in custom codecs
    — ``encode(run) -> (payload, state)``, ``decode(payload, state,
    dtype) -> run``.
    """

    def __init__(self, codec, dtype):
        if codec == "int8" and not np.issubdtype(np.dtype(dtype), np.floating):
            raise ValueError(
                f"int8 spill codec quantizes float runs, got {np.dtype(dtype)}")
        self._codec = codec
        self._dtype = dtype
        self._runs: List = []

    def append(self, run: np.ndarray) -> None:
        if self._codec is None:
            self._runs.append(run)
            return
        if self._codec == "int8":
            q, scale = _int8_encode(run)
        else:
            enc, _ = self._codec
            q, scale = enc(run)
        saved = run.nbytes - q.nbytes
        if saved > 0 and _obs.enabled():
            _metrics.counter("spill.codec_bytes_saved").inc(saved)
        self._runs.append((q, scale))

    def materialize(self) -> List[np.ndarray]:
        if self._codec is None:
            return self._runs
        if self._codec == "int8":
            return [_int8_decode(q, s, self._dtype) for q, s in self._runs]
        _, dec = self._codec
        return [dec(q, s, self._dtype) for q, s in self._runs]

    def __len__(self):
        return len(self._runs)


# ---------------------------------------------------------------------------
# phase 1 — chunk, device-sort, spill (double-buffered)
# ---------------------------------------------------------------------------

def _spill_phase(keys_np: np.ndarray, vals_np: Optional[np.ndarray],
                 chunk: int, *, descending: bool, stable: bool, method: str,
                 overlap: bool, codec, interpret: Optional[bool]
                 ) -> Tuple[_RunStore, Optional[List[np.ndarray]], float]:
    """Cut ``keys_np`` (and optional payload) into ``chunk``-element pieces,
    sort each on device, stream sorted runs back to host.

    ``overlap=True`` is the double-buffered pipeline: chunk ``i+1``'s
    device_put + sort dispatch happen *before* the blocking D2H of chunk
    ``i`` — jax's async dispatch makes the sort a future, so the host-side
    copy of run ``i`` proceeds while the device works on ``i+1``.
    ``overlap=False`` drains every chunk before touching the next (the
    bench's comparison baseline).  Returns the run store, payload runs,
    and the measured overlap fraction of the phase.
    """
    from repro import engine

    n = keys_np.shape[0]
    key_runs = _RunStore(codec, keys_np.dtype)
    val_runs: Optional[List[np.ndarray]] = None if vals_np is None else []
    t_begin = time.perf_counter()
    t_blocked = 0.0

    def _drain(pend) -> None:
        nonlocal t_blocked
        sk, sv = pend
        t0 = time.perf_counter()
        hk = np.asarray(sk)                      # D2H (blocks until ready)
        hv = None if sv is None else np.asarray(sv)
        t_blocked += time.perf_counter() - t0
        if _obs.enabled():
            d2h = hk.nbytes + (0 if hv is None else hv.nbytes)
            _metrics.counter("spill.d2h_bytes").inc(d2h)
        key_runs.append(hk)
        if hv is not None:
            val_runs.append(hv)

    pending = None
    for start in range(0, n, chunk):
        stop = min(start + chunk, n)
        with _obs.trace("spill.chunk", start=start, stop=stop,
                        method=method):
            kc = jax.device_put(keys_np[start:stop])     # H2D
            if _obs.enabled():
                h2d = kc.nbytes
            if vals_np is None:
                sk = engine.sort(kc[None, :], descending=descending,
                                 method=method, interpret=interpret)[0]
                sv = None
            else:
                vc = jax.device_put(vals_np[start:stop])
                if _obs.enabled():
                    h2d += vc.nbytes
                sk, sv = engine.sort_kv(kc[None, :], vc[None, :],
                                        descending=descending, stable=stable,
                                        method=method, interpret=interpret)
                sk, sv = sk[0], sv[0]
            if _obs.enabled():
                _metrics.counter("spill.h2d_bytes").inc(h2d)
        if overlap:
            if pending is not None:
                _drain(pending)                  # overlaps this chunk's sort
            pending = (sk, sv)
        else:
            _drain((sk, sv))                     # fully serial baseline
    if pending is not None:
        _drain(pending)

    wall = max(time.perf_counter() - t_begin, 1e-12)
    frac = max(0.0, 1.0 - t_blocked / wall)
    if _obs.enabled():
        _metrics.gauge("spill.overlap_fraction").set(frac)
    return key_runs, val_runs, frac


# ---------------------------------------------------------------------------
# phase 2 — host k-way merge-path
# ---------------------------------------------------------------------------

def _count_before(asc: np.ndarray, key, tie_first: bool,
                  descending: bool) -> int:
    """How many elements of a sorted run precede ``key`` in merged order.

    ``tie_first=True`` counts equal keys as preceding (the run sits to the
    *left* of the element's own run in the stable tie order).  ``asc`` is
    the run's ascending view (descending runs are searched through their
    reversed view, since ``np.searchsorted`` wants ascending data).
    """
    if descending:
        # preceding = strictly greater (plus ties when tie_first)
        side = "left" if tie_first else "right"
        return int(asc.shape[0] - np.searchsorted(asc, key, side=side))
    side = "right" if tie_first else "left"
    return int(np.searchsorted(asc, key, side=side))


def _stable_rank(runs: Sequence[np.ndarray], asc: Sequence[np.ndarray],
                 r: int, i: int, descending: bool) -> int:
    """Exact merged position of element ``runs[r][i]`` under the stable
    order (ties broken by run index, then in-run index) — the merge-path
    diagonal one level up, computed with cross-run binary searches."""
    key = runs[r][i]
    rank = int(i)
    for q in range(len(runs)):
        if q == r:
            continue
        rank += _count_before(asc[q], key, tie_first=q < r,
                              descending=descending)
    return rank


def _cursors_at(runs: Sequence[np.ndarray], asc: Sequence[np.ndarray],
                d: int, lows: Sequence[int], descending: bool) -> List[int]:
    """Per-run cursors ``hi`` with ``sum(hi) == d``: ``runs[r][:hi[r]]``
    are exactly the first ``d`` elements of the stable merged order.
    ``lows`` (the previous boundary's cursors) bound the bisection."""
    his = []
    for r, run in enumerate(runs):
        lo, hi = int(lows[r]), run.shape[0]
        # smallest i with stable_rank(r, i) >= d; cursor = that i
        while lo < hi:
            mid = (lo + hi) // 2
            if _stable_rank(runs, asc, r, mid, descending) < d:
                lo = mid + 1
            else:
                hi = mid
        his.append(lo)
    return his


def _grouped_kway_kv(kslices: List[jnp.ndarray], vslices: List[jnp.ndarray],
                     fanin: int, *, descending: bool,
                     interpret: Optional[bool]
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge tournament with a capped width: contiguous groups of at most
    ``fanin`` runs merge first, the group outputs merge again, until one
    run remains.  Grouping is CONTIGUOUS in run order, so the tournament's
    left-first tie rule composes across levels and stability survives the
    cap (the autotuned ``merge_fanin`` knob — a wide tournament pads every
    run to the widest, so slices of very uneven length can merge cheaper
    in narrow rounds)."""
    while len(kslices) > 1:
        nk: List[jnp.ndarray] = []
        nv: List[jnp.ndarray] = []
        for i in range(0, len(kslices), fanin):
            gk, gv = kslices[i:i + fanin], vslices[i:i + fanin]
            if len(gk) == 1:
                nk.append(gk[0])
                nv.append(gv[0])
                continue
            mk, mv = _merge.kway_merge_kv(gk, gv, descending=descending,
                                          backend="xla",
                                          interpret=interpret)
            nk.append(mk)
            nv.append(mv)
        kslices, vslices = nk, nv
    return kslices[0], vslices[0]


def _merge_phase(key_runs: Sequence[np.ndarray],
                 val_runs: Optional[Sequence[np.ndarray]], *,
                 descending: bool, block: int,
                 interpret: Optional[bool]
                 ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """K-way merge-path over host-resident runs, one output block at a time.

    Host side owns the *partition* (stable cursors at each block boundary,
    ``O(R^2 log^2 L)`` binary searches — noise next to the data movement);
    the device owns the *merge* of each block's slices through the engine
    tournament, its width capped at the active profile's ``merge_fanin``
    (:func:`_grouped_kway_kv`).  Only the current block's slices are
    device-resident, so peak device footprint stays at chunk scale.
    """
    runs = [np.ravel(r) for r in key_runs]
    total = int(sum(r.shape[0] for r in runs))
    kv = val_runs is not None
    if len(runs) == 1:
        return runs[0], (np.ravel(val_runs[0]) if kv else None)
    asc = [r[::-1] if descending else r for r in runs]
    out_k = np.empty((total,), runs[0].dtype)
    out_v = None
    if kv:
        vruns = [np.ravel(v) for v in val_runs]
        out_v = np.empty((total,), vruns[0].dtype)
    lows = [0] * len(runs)
    written = 0
    fanin = max(2, int(_tuning.active().merge_fanin))
    bounds = list(range(block, total, block)) + [total]
    for d in bounds:
        his = _cursors_at(runs, asc, d, lows, descending)
        sel = [(r, lo, hi) for r, (lo, hi) in enumerate(zip(lows, his))
               if hi > lo]
        with _obs.trace("spill.merge_block", start=written, stop=d,
                        fan_in=len(sel)):
            if len(sel) == 1:
                r, lo, hi = sel[0]
                mk = runs[r][lo:hi]
                mv = vruns[r][lo:hi] if kv else None
            else:
                kslices = [jnp.asarray(runs[r][lo:hi]) for r, lo, hi in sel]
                if _obs.enabled():
                    _metrics.counter("spill.h2d_bytes").inc(
                        sum(s.nbytes for s in kslices))
                if kv:
                    vslices = [jnp.asarray(vruns[r][lo:hi])
                               for r, lo, hi in sel]
                    if _obs.enabled():
                        _metrics.counter("spill.h2d_bytes").inc(
                            sum(s.nbytes for s in vslices))
                    dk, dv = _grouped_kway_kv(
                        kslices, vslices, fanin, descending=descending,
                        interpret=interpret)
                    mk, mv = np.asarray(dk), np.asarray(dv)
                else:
                    # keys-only ALSO goes through the kv tournament (with a
                    # throwaway payload): kway_merge's sentinel padding is
                    # sliced off positionally, which miscounts when genuine
                    # NaN keys sort past the +inf pads — the kv variant
                    # drops pads by position, exact for every key value
                    dk, _ = _grouped_kway_kv(
                        kslices, [jnp.zeros(s.shape, jnp.int8)
                                  for s in kslices],
                        fanin, descending=descending,
                        interpret=interpret)
                    mk, mv = np.asarray(dk), None
                if _obs.enabled():
                    _metrics.counter("spill.d2h_bytes").inc(
                        mk.nbytes + (0 if mv is None else mv.nbytes))
        out_k[written:d] = mk
        if kv:
            out_v[written:d] = mv
        written = d
        lows = his
    return out_k, out_v


# ---------------------------------------------------------------------------
# public 1-D drivers
# ---------------------------------------------------------------------------

def _prepare(x) -> np.ndarray:
    a = np.asarray(x)
    if a.ndim != 1:
        raise ValueError(
            f"spill tier sorts flat 1-D arrays (rows are driven "
            f"independently by the engine); got a {a.ndim}-d input")
    return a


# ---------------------------------------------------------------------------
# bfloat16 keys — host-side mirror of the keycodec order embedding
# ---------------------------------------------------------------------------
# numpy's own comparators do not know bfloat16 (it is an ml_dtypes
# extension type), so the host half of the pipeline (searchsorted
# cursors, run boundaries) cannot run on the raw values.  Instead the
# keys enter the pipeline as the uint16 *keycodec encoding* — a bitcast
# view plus the sign-embedding flips of ``keycodec.encode``, computed
# here with numpy so no device round-trip is needed — and every stage
# (chunk sorts, cursors, merges) runs one ascending unsigned sort.  The
# embedding is a bijection on bit patterns, so decoding the merged output
# is bit-exact, NaN payload bits included; ``descending`` folds into the
# encoding as the usual complement, the pipeline itself always ascends.

def _is_bf16(dtype) -> bool:
    return jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16)


def _bf16_encode(a: np.ndarray, descending: bool) -> np.ndarray:
    u = np.ascontiguousarray(a).view(np.uint16)
    neg = (u >> np.uint16(15)) != 0
    u = u ^ np.where(neg, np.uint16(0xFFFF), np.uint16(0x8000))
    if descending:
        u = u ^ np.uint16(0xFFFF)
    return u


def _bf16_decode(u: np.ndarray, descending: bool) -> np.ndarray:
    if descending:
        u = u ^ np.uint16(0xFFFF)
    top = (u >> np.uint16(15)) != 0
    u = u ^ np.where(top, np.uint16(0x8000), np.uint16(0xFFFF))
    return np.ascontiguousarray(u).view(np.dtype(jnp.bfloat16))


def _nan_safe_method(keys: np.ndarray, method: str) -> str:
    """Dataset-scale streams carry NaNs; the min/max-network device
    backends assume NaN-free floats (registry convention), so when the
    host-resident input visibly contains NaN, ``auto`` chunk sorts pin to
    the total-order ``xla`` backend (NaN sorts last, matching the host
    merge's ``searchsorted`` order).  Explicit methods are honoured."""
    if (method == "auto" and np.issubdtype(keys.dtype, np.floating)
            and np.isnan(keys).any()):
        return "xla"
    return method


def spill_sort(x, *, descending: bool = False,
               chunk_bytes: Optional[int] = None, method: str = "auto",
               overlap: bool = True, codec=None,
               interpret: Optional[bool] = None) -> np.ndarray:
    """Sort a (host- or device-resident) 1-D array of any size; returns a
    sorted **host** numpy array.  See the module docstring for the
    pipeline; ``method`` picks the per-chunk device backend ("auto" =
    planner), ``codec`` opts into lossy int8 wire compression."""
    keys = _prepare(x)
    n = keys.shape[0]
    if n == 0:
        return keys.copy()
    bf16 = _is_bf16(keys.dtype)
    if bf16:
        if codec is not None:
            raise ValueError(
                "codec compresses raw float key runs; bfloat16 keys ride "
                "the pipeline as their uint16 keycodec encoding, which a "
                "magnitude quantizer would scramble")
        keys = _bf16_encode(keys, descending)
        enc_desc, descending = descending, False
    method = _nan_safe_method(keys, method)
    chunk = chunk_elems(keys.dtype.itemsize, chunk_bytes)
    n_chunks = -(-n // chunk)
    with _obs.trace("spill.sort", n=n, chunks=n_chunks, chunk_elems=chunk,
                    overlap=overlap):
        key_runs, _, _ = _spill_phase(
            keys, None, chunk, descending=descending, stable=False,
            method=method, overlap=overlap, codec=codec, interpret=interpret)
        out, _ = _merge_phase(key_runs.materialize(), None,
                              descending=descending, block=chunk,
                              interpret=interpret)
    return _bf16_decode(out, enc_desc) if bf16 else out


def spill_sort_kv(keys, values, *, descending: bool = False,
                  chunk_bytes: Optional[int] = None, method: str = "auto",
                  overlap: bool = True, codec=None,
                  interpret: Optional[bool] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Key-value spill sort (always stable: equal keys keep input order —
    chunk sorts run the engine's stable pipeline and both merge stages
    break ties by run index).  ``codec`` compresses the *payload* runs;
    keys stay exact so the merge order is exact."""
    k = _prepare(keys)
    v = _prepare(values)
    if k.shape != v.shape:
        raise ValueError(
            f"values shape {v.shape} must match keys shape {k.shape}")
    n = k.shape[0]
    if n == 0:
        return k.copy(), v.copy()
    bf16 = _is_bf16(k.dtype)
    if bf16:
        k = _bf16_encode(k, descending)
        enc_desc, descending = descending, False
    method = _nan_safe_method(k, method)
    chunk = chunk_elems(k.dtype.itemsize, chunk_bytes)
    n_chunks = -(-n // chunk)
    with _obs.trace("spill.sort_kv", n=n, chunks=n_chunks, chunk_elems=chunk,
                    overlap=overlap):
        key_runs, val_runs, _ = _spill_phase(
            k, v, chunk, descending=descending, stable=True, method=method,
            overlap=overlap, codec=None, interpret=interpret)
        if codec is not None:
            store = _RunStore(codec, v.dtype)
            for vr in val_runs:
                store.append(vr)
            val_runs = store.materialize()
        out_k, out_v = _merge_phase(key_runs.materialize(), val_runs,
                                    descending=descending, block=chunk,
                                    interpret=interpret)
    if bf16:
        out_k = _bf16_decode(out_k, enc_desc)
    return out_k, out_v


def spill_argsort(x, *, descending: bool = False,
                  chunk_bytes: Optional[int] = None, method: str = "auto",
                  overlap: bool = True,
                  interpret: Optional[bool] = None) -> np.ndarray:
    """Stable sorting permutation via the kv path (int32 positions)."""
    keys = _prepare(x)
    idx = np.arange(keys.shape[0], dtype=np.int32)
    _, order = spill_sort_kv(keys, idx, descending=descending,
                             chunk_bytes=chunk_bytes, method=method,
                             overlap=overlap, interpret=interpret)
    return order


# ---------------------------------------------------------------------------
# rows-form adapters — what the engine/backend registry dispatches to
# ---------------------------------------------------------------------------

def sort_rows(x2, *, descending: bool = False,
              chunk_bytes: Optional[int] = None, method: str = "auto",
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """(rows, n) adapter: each row spilled independently.  Returns jnp for
    engine API symmetry — callers at truly device-impossible sizes use the
    1-D ``spill_sort`` directly and keep the result on host."""
    rows = np.asarray(x2)
    out = np.stack([spill_sort(r, descending=descending,
                               chunk_bytes=chunk_bytes, method=method,
                               interpret=interpret) for r in rows])
    return jnp.asarray(out)


def sort_rows_kv(k2, v2, *, descending: bool = False,
                 chunk_bytes: Optional[int] = None, method: str = "auto",
                 interpret: Optional[bool] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    ks, vs = np.asarray(k2), np.asarray(v2)
    outs = [spill_sort_kv(kr, vr, descending=descending,
                          chunk_bytes=chunk_bytes, method=method,
                          interpret=interpret)
            for kr, vr in zip(ks, vs)]
    return (jnp.asarray(np.stack([o[0] for o in outs])),
            jnp.asarray(np.stack([o[1] for o in outs])))


def argsort_rows(x2, *, descending: bool = False,
                 chunk_bytes: Optional[int] = None, method: str = "auto",
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    rows = np.asarray(x2)
    out = np.stack([spill_argsort(r, descending=descending,
                                  chunk_bytes=chunk_bytes, method=method,
                                  interpret=interpret) for r in rows])
    return jnp.asarray(out)
