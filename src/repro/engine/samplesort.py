"""Single-round distributed sample-sort — splitters instead of D rounds.

``core/distributed_sort.py``'s odd-even transposition moves every shard D
times over ICI: D neighbour-exchange rounds, each paying one shard of
traffic plus a 2m-wide bitonic merge box.  That is exactly the repeated
cross-partition movement the paper eliminates inside one SRAM macro (§II-B
partitions sort concurrently and pay only the Eq. 3-4 temp-row cycles to
exchange operands once per stage).  This module is the cluster-scale
analogue of that single-exchange structure:

  1. **local sort** — each device sorts its shard through the registered
     backend stack (``repro.sort``, planner-dispatched), the §II-B
     "partitions sort concurrently" step;
  2. **splitters** — every shard contributes s regular samples; one tiny
     all-gather + sort yields D-1 global splitters;
  3. **partition** — each sorted shard is cut against the splitters into D
     buckets (bucket d holds the keys destined for device d).  The bucket
     histogram can run on the same per-tile one-hot digit-histogram kernel
     the LSD radix sort uses (``kernels/radix_sort.py``) — the splitter
     interval index plays the digit;
  4. **exchange** — ONE all-to-all moves every bucket to its owner (the
     temp-row operand exchange, paid once instead of D times);
  5. **merge** — each device merges its received runs with the merge-path
     tree (``engine/merge.py``), then a rank-directed rebalance restores
     equal m-element shards so the concatenation over the mesh axis is the
     globally sorted array.

The all-to-all needs one static per-(source, destination) bucket capacity.
``m`` is always safe (a source bucket can never exceed its shard) but
inflates the exchange and merge D-fold, so the sort runs **two phases**:
phase 1 (local sort + splitters + bucket bounds) comes back to the host,
the *measured* maximum bucket count sets the capacity, and phase 2
(exchange + merge + rebalance) runs with buffers sized to what the data
actually needs — with regular sampling that is ~m/D per pair, not m.  The
only cost is one tiny host sync of the (D, D) bound table between two
cached jitted programs.

Everything runs on **encoded keys** (``core/keycodec.py``): signed ints,
floats and ``descending`` all reduce to one ascending unsigned sort, and
key-value payloads ride the same buckets.  Uneven global lengths are padded
to D*m with the maximal encoded key and tracked with explicit validity
counts end to end — pads can tie genuine extreme keys, so no step ever
infers validity from a sentinel comparison.

Keys must be NaN-free floats / any keycodec dtype (same contract as the
radix backend).  The sort is not stable.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import keycodec
from repro.core import tuning as _tuning
from repro.engine.merge import merge_runs
from repro.obs import metrics, trace as _obs

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = ["sample_sort", "sample_topk", "select_splitters", "bucket_bounds",
           "default_samples_per_shard", "alltoall_bytes_per_device",
           "topk_candidate_bytes_per_device"]


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def default_samples_per_shard(local_n: int, n_dev: int) -> int:
    """Regular-sampling oversampling: enough samples that splitters land
    within a small factor of the ideal quantiles, capped by the shard."""
    return max(1, min(local_n, max(8, 2 * n_dev)))


def select_splitters(samples: jnp.ndarray, n_dev: int) -> jnp.ndarray:
    """(D*s,) pooled samples -> (D-1,) global splitters (encoded keys)."""
    pooled = jnp.sort(samples.reshape(-1))
    total = pooled.shape[0]
    pos = (jnp.arange(1, n_dev) * total) // n_dev
    return pooled[pos]


def bucket_bounds(ks: jnp.ndarray, splitters: jnp.ndarray, *,
                  use_histogram: bool = False,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """(D+1,) bucket boundaries of a *sorted* shard against the splitters.

    Bucket d is ``ks[bounds[d]:bounds[d+1]]`` — the keys destined for
    device d (keys equal to a splitter go to the lower bucket).  Two
    equivalent routes:

      * ``use_histogram=False`` — binary search: the shard is sorted, so
        the boundaries are just ``searchsorted(ks, splitters, 'right')``.
      * ``use_histogram=True`` — the radix kernel's per-tile one-hot
        digit histogram (kernels/radix_sort.py) with the splitter interval
        index as the digit; boundaries are the histogram's exclusive
        prefix sum.  Same numbers, but the counting runs on the VMEM
        kernel the radix backend already ships (the TPU path).
    """
    m = ks.shape[0]
    n_dev = splitters.shape[0] + 1
    if n_dev == 1:
        return jnp.asarray([0, m], jnp.int32)
    if use_histogram:
        from repro.kernels import radix_sort as _rs
        ids = jnp.searchsorted(splitters, ks, side="left").astype(jnp.int32)
        interp = (jax.default_backend() != "tpu") if interpret is None \
            else interpret
        # tile the shard exactly like the radix passes do — one full-shard
        # row would materialise an un-tiled (1, m, D) one-hot in VMEM.
        # Pad slots carry an extra bucket id (n_dev) counted into a
        # throwaway histogram column
        tile = min(max(8, _tuning.active().radix_tile), m)
        mt = -(-m // tile) * tile
        if mt != m:
            ids = jnp.pad(ids, (0, mt - m), constant_values=n_dev)
        hist, _ = _rs._digit_stats(ids.reshape(mt // tile, tile),
                                   n_dev + 1, interp)
        counts = jnp.sum(hist, axis=0)[:n_dev]
    else:
        starts = jnp.searchsorted(ks, splitters, side="right")
        counts = jnp.diff(jnp.concatenate(
            [jnp.zeros(1, starts.dtype), starts,
             jnp.full((1,), m, starts.dtype)]))
    return jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(counts).astype(jnp.int32)])


def _all_to_all(v: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """(D, ...) -> (D, ...): row j of the result is what device j held in
    row ``my`` — the single bucket-exchange collective."""
    return jax.lax.all_to_all(v, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)


def _smap(f, mesh, in_specs, out_specs):
    # replication checking has no rule for pallas_call (the histogram
    # kernel and any Pallas local sort), so it is disabled; every output
    # is explicitly sharded over the axis anyway
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # jax >= 0.6 renamed the flag
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


# ---------------------------------------------------------------------------
# phase 1: local sort + splitters + bucket bounds
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _phase1(mesh: Mesh, axis_name: str, n: int, kv: bool, padded: bool,
            local_method: Optional[str], s: int, use_histogram: bool,
            interpret: Optional[bool]):
    """Jitted program: encoded shard -> (sorted shard[, payload], bounds).

    Cached on its statics so repeated serving-shape calls hit the compiled
    executable; the mesh participates in the key (jax meshes hash).
    """
    n_dev = mesh.shape[axis_name]
    m = -(-n // n_dev)

    def local(*args):
        xs = args[0]
        vs = args[1] if kv else None
        my = jax.lax.axis_index(axis_name)
        # valid = not an end-of-array pad; pads all live on the tail shards
        n_valid = jnp.clip(n - my * m, 0, m).astype(jnp.int32)

        # local sort (planner-dispatched registered backend).  Pads carry
        # the maximal encoded key; with a payload they must also stay
        # *behind* genuine max-key ties, so the kv+padded case runs the
        # stable argsort pipeline — validity stays a prefix of the shard
        from repro import sort as _front
        if kv and padded:
            order = _front.argsort(xs, stable=True, method=local_method,
                                   interpret=interpret)
            ks = jnp.take_along_axis(xs, order, -1)
            vs = jnp.take_along_axis(vs, order, -1)
        elif kv:
            ks, vs = _front.sort_kv(xs, vs, method=local_method,
                                    interpret=interpret)
        else:
            ks = _front.sort(xs, method=local_method, interpret=interpret)

        # regular samples -> pooled splitters (one tiny all-gather)
        sample_pos = ((jnp.arange(s) + 1) * m) // (s + 1)
        samples = jax.lax.all_gather(ks[sample_pos], axis_name)
        splitters = select_splitters(samples, n_dev)

        bounds = bucket_bounds(ks, splitters, use_histogram=use_histogram,
                               interpret=interpret)
        # per-bucket count of *genuine* keys: the valid elements are a
        # prefix of the sorted shard, hence a prefix of every bucket
        vcnt = jnp.clip(jnp.minimum(bounds[1:], n_valid) - bounds[:-1],
                        0, m).astype(jnp.int32)
        starts = bounds[:-1]
        if kv:
            return ks, vs, starts, vcnt
        return ks, starts, vcnt

    spec = P(axis_name)
    n_out = 4 if kv else 3
    fn = _smap(local, mesh, (spec, spec) if kv else (spec,),
               (spec,) * n_out)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# phase 2: bucket exchange + merge-path merge + rank rebalance
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _phase2(mesh: Mesh, axis_name: str, n: int, kv: bool, capacity: int,
            key_dtype_name: str, val_dtype_name: Optional[str],
            merge_backend: str, interpret: Optional[bool]):
    """Jitted program: (sorted shard[, payload], starts, vcnt) -> output
    shard(s).  ``capacity`` is the static per-(source, destination) bucket
    size — phase 1's measured maximum, or m for the always-safe bound."""
    n_dev = mesh.shape[axis_name]
    m = -(-n // n_dev)
    n_pad = n_dev * m
    c = capacity
    r_runs = next_pow2(n_dev)
    maxkey = jnp.array(jnp.iinfo(jnp.dtype(key_dtype_name)).max,
                       jnp.dtype(key_dtype_name))

    def local(*args):
        if kv:
            ks, vs, starts, vcnt = args
        else:
            ks, starts, vcnt = args
        my = jax.lax.axis_index(axis_name)

        # fixed-capacity send buffers + ONE all-to-all.  Capacity fill is
        # the max key so runs stay sorted; it is never *interpreted* —
        # validity travels as explicit counts.
        idx = starts[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
        within = jnp.arange(c, dtype=jnp.int32)[None, :] < vcnt[:, None]
        src = jnp.clip(idx, 0, m - 1)
        sendk = jnp.where(within, ks[src], maxkey)
        recvk = _all_to_all(sendk, axis_name)                   # (D, c)
        recv_cnt = _all_to_all(vcnt[:, None], axis_name)[:, 0]  # (D,)
        if kv:
            recvv = _all_to_all(jnp.where(within, vs[src],
                                          jnp.zeros((), vs.dtype)),
                                axis_name)

        # merge the received runs with the merge-path tree.  One int32
        # position payload rides the merge; validity flags (and the user
        # payload) are recovered by gathering through it, so ties between
        # capacity fill and genuine max keys cannot corrupt anything.
        runs = recvk
        if r_runs != n_dev:
            runs = jnp.concatenate(
                [runs, jnp.full((r_runs - n_dev, c), maxkey, runs.dtype)])
        pos = jnp.arange(r_runs * c, dtype=jnp.int32).reshape(1, r_runs, c)
        mk, mpos = merge_runs(runs[None], pos, descending=False,
                              backend=merge_backend, interpret=interpret)
        mk, mpos = mk[0], mpos[0]                              # (R*c,)
        run_valid = (jnp.arange(c, dtype=jnp.int32)[None, :]
                     < recv_cnt[:, None])                       # (D, c)
        if r_runs != n_dev:
            run_valid = jnp.concatenate(
                [run_valid, jnp.zeros((r_runs - n_dev, c), bool)])
        mvalid = run_valid.reshape(-1)[mpos]
        if kv:
            vflat = recvv.reshape(-1)
            if r_runs != n_dev:
                vflat = jnp.concatenate(
                    [vflat, jnp.zeros(((r_runs - n_dev) * c,), vflat.dtype)])
            mv = vflat[mpos]

        # rank-directed rebalance back to equal m-element shards: global
        # rank = my bucket's offset + local rank; rank r lives at slot r%m
        # of device r//m.  Exactly one device owns each slot, so the
        # receive reduction is a plain sum over sources (dtype pinned —
        # accumulating zeros is exact, but sum would promote narrow ints).
        c_my = jnp.sum(recv_cnt).astype(jnp.int32)
        counts_all = jax.lax.all_gather(c_my, axis_name)        # (D,)
        offset = jnp.sum(jnp.where(jnp.arange(n_dev) < my, counts_all, 0))
        lrank = jnp.cumsum(mvalid.astype(jnp.int32)) - 1
        grank = offset + lrank
        flat = jnp.where(mvalid, grank, n_pad)                  # OOB -> drop
        outk = jnp.zeros((n_pad,), ks.dtype).at[flat].set(
            mk, mode="drop").reshape(n_dev, m)
        shard_k = jnp.sum(_all_to_all(outk, axis_name), axis=0,
                          dtype=ks.dtype)
        if kv:
            outv = jnp.zeros((n_pad,), vs.dtype).at[flat].set(
                mv, mode="drop").reshape(n_dev, m)
            shard_v = jnp.sum(_all_to_all(outv, axis_name), axis=0,
                              dtype=vs.dtype)
            return shard_k, shard_v
        return shard_k

    spec = P(axis_name)
    n_in = 4 if kv else 3
    fn = _smap(local, mesh, (spec,) * n_in,
               (spec, spec) if kv else spec)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# front door
# ---------------------------------------------------------------------------

def sample_sort(x: jnp.ndarray, mesh: Mesh, axis_name: str = "data", *,
                values: Optional[jnp.ndarray] = None,
                descending: bool = False,
                local_method: Optional[str] = None,
                samples_per_shard: Optional[int] = None,
                capacity: Optional[int] = None,
                capacity_slack: Optional[float] = None,
                use_histogram: Optional[bool] = None,
                merge_backend: Optional[str] = None,
                interpret: Optional[bool] = None):
    """Globally sort a 1-D array over ``axis_name`` with ONE bucket
    exchange.  Returns the sorted array (or ``(keys, values)`` with a
    payload), same length and sharding layout as the input.

    Unlike the odd-even path the length need not divide the axis size
    (pads are tracked with explicit validity counts), ``descending`` and
    key-value payloads are first-class, and the collective bill is one
    all-to-all of buckets plus one rank-directed rebalance instead of D
    neighbour rounds.

    ``capacity`` overrides the measured per-(source, destination) bucket
    capacity; it is validated against the realized bucket bounds and
    raises rather than silently dropping elements when too small (``m``,
    the shard length, is always sufficient).  Under an outer ``jax.jit``
    the measured mode is unavailable (it syncs counts to the host) and
    the realized bounds cannot be checked, so only ``capacity >= m`` is
    accepted there.

    ``capacity_slack`` (default: the active tuning profile's) multiplies
    the *measured* bucket maximum before pow2 rounding: >1 buys headroom
    so nearby workloads with slightly more skew reuse the same compiled
    phase-2 program instead of recompiling at the next capacity.
    """
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"sample_sort sorts flat 1-D arrays, got {x.shape}")
    if not keycodec.supports(x.dtype):
        raise ValueError(
            f"sample_sort needs a keycodec dtype {keycodec.SUPPORTED}, "
            f"got {jnp.dtype(x.dtype).name!r}")
    n = x.shape[0]
    n_dev = mesh.shape[axis_name]
    m = -(-n // n_dev)                      # shard length (output = input)
    n_pad = n_dev * m
    kv = values is not None
    if kv:
        values = jnp.asarray(values)
        if values.shape != x.shape:
            raise ValueError(f"values shape {values.shape} must match "
                             f"keys shape {x.shape}")
    if use_histogram is None:
        use_histogram = jax.default_backend() == "tpu"
    s = samples_per_shard or default_samples_per_shard(m, n_dev)

    enc = keycodec.encode(x, descending=descending)
    padded = n_pad != n
    if padded:
        maxkey = jnp.array(jnp.iinfo(enc.dtype).max, enc.dtype)
        enc = jnp.pad(enc, (0, n_pad - n), constant_values=maxkey)
        if kv:
            values = jnp.pad(values, (0, n_pad - n))

    p1 = _phase1(mesh, axis_name, n, kv, padded, local_method, s,
                 use_histogram, interpret)
    sp1 = _obs.trace("samplesort.phase1", n=n, n_dev=n_dev, kv=kv,
                     samples_per_shard=s)
    with sp1:
        if kv:
            ks, vs, starts, vcnt = p1(enc, values)
        else:
            ks, starts, vcnt = p1(enc)
        sp1.fence(vcnt)

    # the one host sync: the realized bucket maximum sets the static
    # exchange capacity, so buffers and merge work scale with what the
    # data needs (~m/D with regular sampling) instead of the worst case m
    try:
        max_bucket = int(np.max(np.asarray(vcnt)))
    except jax.errors.TracerArrayConversionError:
        max_bucket = None                   # called under an outer jit
    if capacity is None:
        if max_bucket is None:
            raise ValueError(
                "sample_sort's measured-capacity mode reads the bucket "
                "counts on the host and cannot run under an outer jit; "
                f"pass capacity= (the shard length {m} is always safe)")
        slack = capacity_slack if capacity_slack is not None \
            else _tuning.active().capacity_slack
        cap = _round_capacity(int(math.ceil(max_bucket * slack)), m)
    else:
        cap = _round_capacity(capacity, m)
        if max_bucket is None and cap < m:
            # under a trace there is no way to raise later, and a
            # too-small capacity would silently drop elements — only the
            # provably-safe shard-length capacity is allowed
            raise ValueError(
                f"under an outer jit, capacity must be >= the shard "
                f"length {m} (the realized bucket maximum cannot be "
                f"checked at trace time); got {capacity}")
        if max_bucket is not None and cap < max_bucket:
            raise ValueError(
                f"capacity {capacity} is smaller than the realized maximum "
                f"bucket ({max_bucket}); the shard length {m} is always "
                f"safe")
    if merge_backend is None:
        from repro.kernels.merge_path import DEFAULT_CHUNK
        if jax.default_backend() == "tpu" and (2 * cap) % DEFAULT_CHUNK == 0:
            merge_backend = "pallas"        # the merge-path VMEM kernel
        elif cap & (cap - 1) == 0:
            # off-TPU the gather-bound rank merge loses badly to the
            # word-parallel min/max box (capacity is pow2-rounded, so this
            # is the interpret-mode default)
            merge_backend = "bitonic"
        else:
            merge_backend = "xla"

    itemsize = jnp.dtype(enc.dtype).itemsize + \
        (jnp.dtype(values.dtype).itemsize if kv else 0)
    if _obs.enabled() and max_bucket is not None:
        # bucket-skew accounting: vcnt is the full (D*D,) per-(source,
        # destination) genuine-key count table, already synced to the host
        # for the capacity measurement — skew 1.0 means perfectly regular
        # splitters, capacity (and the exchange bill) scales with it
        counts = np.asarray(vcnt, dtype=np.float64)
        mean_fill = float(counts.mean()) if counts.size else 0.0
        skew = float(max_bucket) / mean_fill if mean_fill else 1.0
        metrics.gauge("samplesort.bucket_skew").set(skew)
        metrics.histogram("samplesort.bucket_fill_max").observe(max_bucket)
        metrics.counter("samplesort.alltoall_bytes").inc(
            n_dev * alltoall_bytes_per_device(n_dev, m, itemsize, cap))
        metrics.counter("samplesort.sorts").inc()

    p2 = _phase2(mesh, axis_name, n, kv,
                 cap, jnp.dtype(enc.dtype).name,
                 jnp.dtype(values.dtype).name if kv else None,
                 merge_backend, interpret)
    sp2 = _obs.trace("samplesort.phase2", n=n, n_dev=n_dev, capacity=cap,
                     merge_backend=merge_backend,
                     bytes=n_dev * alltoall_bytes_per_device(
                         n_dev, m, itemsize, cap) if _obs.enabled() else 0)
    with sp2:
        if kv:
            out_k, out_v = p2(ks, vs, starts, vcnt)
            sp2.fence((out_k, out_v))
        else:
            out = p2(ks, starts, vcnt)
            sp2.fence(out)
    if kv:
        keys = keycodec.decode(out_k[:n], x.dtype, descending=descending)
        return keys, out_v[:n]
    return keycodec.decode(out[:n], x.dtype, descending=descending)


# ---------------------------------------------------------------------------
# distributed top-k: local select -> ONE candidate all-gather -> tiny merge
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _topk_prog(mesh: Mesh, axis_name: str, n: int, k: int,
               key_dtype_name: str, use_kernel: Optional[bool],
               interpret: Optional[bool]):
    """Jitted program: encoded padded shard -> replicated (enc topk, global
    indices).  Cached on its statics like the sample-sort phases."""
    from repro.kernels import radix_select as _sel
    n_dev = mesh.shape[axis_name]
    m = -(-n // n_dev)
    kc = min(k, m)                       # per-shard candidate count
    kdt = jnp.dtype(key_dtype_name)
    maxkey = jnp.array(jnp.iinfo(kdt).max, kdt)

    def local(enc):
        my = jax.lax.axis_index(axis_name)
        base = (my * m).astype(jnp.int32)
        # end-of-array pads all live on the tail shards; force them to the
        # maximal encoded key so the local select ranks them last, and mark
        # them with the out-of-range global index n so a pad tying a
        # genuine extreme key can never displace it in the candidate merge
        n_valid = jnp.clip(n - base, 0, m).astype(jnp.int32)
        valid = jnp.arange(m, dtype=jnp.int32) < n_valid
        e = jnp.where(valid, enc, maxkey)

        # local selection: the kc smallest encoded keys of this shard —
        # §II-B's "partitions sort concurrently", in partial-sort mode
        le, li = _sel.select_topk_encoded(e[None], kc,
                                         use_kernel=use_kernel,
                                         interpret=interpret)
        gi = jnp.where(li[0] < n_valid, base + li[0],
                       jnp.array(n, jnp.int32))

        # THE one collective: D·kc candidates (vs sample-sort's bucket
        # all-to-all of whole shards); every device then runs the same
        # tiny lexicographic merge, so the result is replicated
        ce = jax.lax.all_gather(le[0], axis_name).reshape(-1)
        ci = jax.lax.all_gather(gi, axis_name).reshape(-1)
        se, si = jax.lax.sort((ce, ci), num_keys=2)
        return se[:k], si[:k]

    fn = _smap(local, mesh, (P(axis_name),), (P(None), P(None)))
    return jax.jit(fn)


def sample_topk(x: jnp.ndarray, k: int, mesh: Mesh,
                axis_name: str = "data", *,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None):
    """Mesh-global top-k of a flat array -> ``(values, indices)``, both
    ``(k,)`` and replicated, bit-exact with ``jax.lax.top_k`` on the
    gathered array (values descending, ties keep the lowest global index).

    Movement is the whole point: each device radix-selects its shard's
    ``min(k, m)`` candidates locally (O(m·passes), no sort), ONE
    all-gather moves the ``D·min(k, m)`` candidate (key, index) pairs, and
    a two-key lexicographic sort of that tiny pool — the merge-box reduce
    over D already-sorted candidate runs — finishes on every device.  No
    full-array sort, no bucket all-to-all, no rebalance round: for
    ``k ≪ n`` the collective bill shrinks from O(m) per device to O(D·k).

    Correctness of the candidate cut: a shard with ``g`` genuine elements
    contributes ``min(kc, g)`` of them, and ``sum(min(kc, g_d)) >= k``
    whenever ``n >= k`` — so the global top-k is always inside the pool.
    """
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"sample_topk selects over flat 1-D arrays, "
                         f"got {x.shape}")
    if not keycodec.supports(x.dtype):
        raise ValueError(
            f"sample_topk needs a keycodec dtype {keycodec.SUPPORTED}, "
            f"got {jnp.dtype(x.dtype).name!r}")
    n = x.shape[0]
    if not 1 <= k <= n:
        raise ValueError(
            f"topk k must satisfy 1 <= k <= n (n={n}); got k={k}")
    n_dev = mesh.shape[axis_name]
    m = -(-n // n_dev)
    enc = keycodec.encode(x, descending=True)
    if n_dev * m != n:
        maxkey = jnp.array(jnp.iinfo(enc.dtype).max, enc.dtype)
        enc = jnp.pad(enc, (0, n_dev * m - n), constant_values=maxkey)
    prog = _topk_prog(mesh, axis_name, n, k,
                      jnp.dtype(enc.dtype).name, use_kernel, interpret)
    cand_bytes = 0
    if _obs.enabled():
        cand_bytes = n_dev * topk_candidate_bytes_per_device(
            n_dev, k, m, jnp.dtype(enc.dtype).itemsize)
        metrics.counter("samplesort.topk_candidate_bytes").inc(cand_bytes)
    sp = _obs.trace("samplesort.topk", n=n, k=k, n_dev=n_dev,
                    bytes=cand_bytes)
    with sp:
        ev, ei = prog(enc)
        sp.fence((ev, ei))
    return keycodec.decode(ev, x.dtype, descending=True), ei


def topk_candidate_bytes_per_device(n_dev: int, k: int, local_elems: int,
                                    itemsize: int) -> int:
    """Analytic ICI volume of the candidate all-gather (per device): the
    ``k ≪ n`` counterpart of ``alltoall_bytes_per_device`` — D·min(k, m)
    (key, int32 index) pairs instead of capacity-padded whole buckets."""
    kc = min(k, local_elems)
    return n_dev * kc * (itemsize + 4)


def _round_capacity(cap: int, m: int) -> int:
    """Static capacity: at least one slot, padded up a little so nearby
    workloads share a compiled phase-2 program, never beyond the shard."""
    cap = max(1, cap)
    if cap >= m:
        return m
    return min(m, next_pow2(cap))


def alltoall_bytes_per_device(n_dev: int, local_elems: int,
                              itemsize: int, capacity: Optional[int] = None
                              ) -> int:
    """Analytic ICI volume of the sample-sort exchange (per device): the
    capacity-padded bucket all-to-all plus the rank rebalance round —
    versus ``n_dev`` full-shard moves for odd-even transposition
    (``distributed_sort.collective_bytes_per_device``)."""
    cap = capacity if capacity is not None else \
        min(local_elems, 2 * local_elems // max(1, n_dev) + 1)
    return (n_dev * cap + n_dev * local_elems) * itemsize
