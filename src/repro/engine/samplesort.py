"""Distributed sample-sort over an explicit device topology.

``core/distributed_sort.py``'s odd-even transposition moves every shard D
times over the interconnect.  This module is the cluster-scale analogue of
the paper's single-exchange structure (§II-B: partitions sort concurrently
and pay the Eq. 3-4 temp-row cycles to exchange operands once per stage):
local sort -> splitters -> ONE capacity-padded bucket all-to-all -> merge ->
rank-directed rebalance.

PR 10 reworks the exchange onto ``engine/collectives.py`` and a two-level
**hierarchical** mode for meshes whose axes span two interconnect tiers
(fast intra-host ICI, ~10x slower inter-host DCN — ``core/topology.py``):

  flat (one tier, the degenerate case)
      local sort -> global splitters -> one all-to-all over ALL mesh axes
      -> merge -> global rebalance.  Every element crosses the slow tier
      inside one big exchange.

  hierarchical (two tiers, ``axes = (outer=DCN, inner=ICI)``)
      1. local sort + **intra-host** splitters            (phase 1)
      2. ICI bucket exchange + merge + intra-host rebalance,
         then **outer** splitters over the host-sorted shards (phase 2)
      3. DCN bucket exchange — chunked/pipelined, optional int8 wire
         codec on the payload — + merge + compaction, then per-host
         sub-splitters over the received pool                (phase 3)
      4. ICI finalize exchange + merge + **global** rebalance (phase 4)

    The second ICI round (phase 4) is load-bearing: after the DCN round,
    host g holds exactly the keys of global range g, but spread over its
    devices with *no* inter-device order — each device received only from
    its same-inner-position peers.  One more intra-host splitter round
    restores a total order before the rank arithmetic of the rebalance.

Both modes live behind the same ``sample_sort`` entry; ``axis_name`` may
be one mesh axis, a tuple of axes, or ``None`` for all of them, and
``hierarchical=None`` auto-selects the two-level path on two-axis meshes.

The all-to-alls need static per-(source, destination) bucket capacities;
each phase boundary syncs the measured bucket maximum to the host and the
next jitted program is compiled at that capacity (with the tuning
profile's slack so nearby workloads share executables).

Everything runs on **encoded keys** (``core/keycodec.py``): signed ints,
floats and ``descending`` all reduce to one ascending unsigned sort, and
key-value payloads ride the same buckets.  Uneven global lengths are
padded with the maximal encoded key and tracked with explicit validity
counts end to end — pads can tie genuine extreme keys, so no step ever
infers validity from a sentinel comparison.

Keys must be NaN-free floats / any keycodec dtype (same contract as the
radix backend).  The sort is not stable.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import keycodec
from repro.core import tuning as _tuning
from repro.engine import collectives as coll
from repro.engine.merge import merge_runs
from repro.obs import metrics, trace as _obs

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = ["sample_sort", "sample_topk", "select_splitters", "bucket_bounds",
           "default_samples_per_shard", "alltoall_bytes_per_device",
           "topk_candidate_bytes_per_device"]

AxisArg = Union[str, Tuple[str, ...], None]


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def default_samples_per_shard(local_n: int, n_dev: int) -> int:
    """Regular-sampling oversampling: enough samples that splitters land
    within a small factor of the ideal quantiles, capped by the shard."""
    return max(1, min(local_n, max(8, 2 * n_dev)))


def select_splitters(samples: jnp.ndarray, n_dev: int) -> jnp.ndarray:
    """(D*s,) pooled samples -> (D-1,) global splitters (encoded keys)."""
    pooled = jnp.sort(samples.reshape(-1))
    total = pooled.shape[0]
    pos = (jnp.arange(1, n_dev) * total) // n_dev
    return pooled[pos]


def bucket_bounds(ks: jnp.ndarray, splitters: jnp.ndarray, *,
                  use_histogram: bool = False,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """(D+1,) bucket boundaries of a *sorted* shard against the splitters.

    Bucket d is ``ks[bounds[d]:bounds[d+1]]`` — the keys destined for
    device d (keys equal to a splitter go to the lower bucket).  Two
    equivalent routes:

      * ``use_histogram=False`` — binary search: the shard is sorted, so
        the boundaries are just ``searchsorted(ks, splitters, 'right')``.
      * ``use_histogram=True`` — the radix kernel's per-tile one-hot
        digit histogram (kernels/radix_sort.py) with the splitter interval
        index as the digit; boundaries are the histogram's exclusive
        prefix sum.  Same numbers, but the counting runs on the VMEM
        kernel the radix backend already ships (the TPU path).
    """
    m = ks.shape[0]
    n_dev = splitters.shape[0] + 1
    if n_dev == 1:
        return jnp.asarray([0, m], jnp.int32)
    if use_histogram:
        from repro.kernels import radix_sort as _rs
        ids = jnp.searchsorted(splitters, ks, side="left").astype(jnp.int32)
        interp = (jax.default_backend() != "tpu") if interpret is None \
            else interpret
        # tile the shard exactly like the radix passes do — one full-shard
        # row would materialise an un-tiled (1, m, D) one-hot in VMEM.
        # Pad slots carry an extra bucket id (n_dev) counted into a
        # throwaway histogram column
        tile = min(max(8, _tuning.active().radix_tile), m)
        mt = -(-m // tile) * tile
        if mt != m:
            ids = jnp.pad(ids, (0, mt - m), constant_values=n_dev)
        hist, _ = _rs._digit_stats(ids.reshape(mt // tile, tile),
                                   n_dev + 1, interp)
        counts = jnp.sum(hist, axis=0)[:n_dev]
    else:
        starts = jnp.searchsorted(ks, splitters, side="right")
        counts = jnp.diff(jnp.concatenate(
            [jnp.zeros(1, starts.dtype), starts,
             jnp.full((1,), m, starts.dtype)]))
    return jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(counts).astype(jnp.int32)])


def _smap(f, mesh, in_specs, out_specs):
    # replication checking has no rule for pallas_call (the histogram
    # kernel and any Pallas local sort), so it is disabled; every output
    # is explicitly sharded over the axis anyway
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:  # jax >= 0.6 renamed the flag
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)


# ---------------------------------------------------------------------------
# axis plumbing: one axis, a tuple of axes, or the whole mesh
# ---------------------------------------------------------------------------

def _axes_tuple(mesh: Mesh, axis_name: AxisArg) -> Tuple[str, ...]:
    """Normalise ``axis_name`` to a validated tuple of mesh axis names
    (``None`` -> every mesh axis, in mesh order)."""
    if axis_name is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axis_name, str):
        axes = (axis_name,)
    else:
        axes = tuple(axis_name)
    if not axes:
        raise ValueError("axis_name must name at least one mesh axis")
    for a in axes:
        if not isinstance(a, str):
            raise TypeError(f"axis names must be strings, got {a!r}")
        if a not in mesh.axis_names:
            raise ValueError(f"axis {a!r} not in mesh axes "
                             f"{tuple(mesh.axis_names)}")
    if len(set(axes)) != len(axes):
        raise ValueError(f"duplicate axis names in {axes}")
    return axes


def _n_dev(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    d = 1
    for a in axes:
        d *= int(mesh.shape[a])
    return d


def _coll_axis(axes: Tuple[str, ...]):
    """The collective axis argument: a bare name for one axis, the tuple
    for several (row-major / outer-axis-major device order)."""
    return axes[0] if len(axes) == 1 else axes


def _lin_index(mesh: Mesh, axes: Tuple[str, ...]) -> jnp.ndarray:
    """Traced linear device index, row-major over ``axes`` — matches the
    device order of tuple-axis collectives."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * int(mesh.shape[a]) + jax.lax.axis_index(a)
    return idx.astype(jnp.int32)


def _pick_merge_backend(run_len: int) -> str:
    """Default merge backend for runs of ``run_len`` slots (the same rule
    the flat path always used, parameterised so each hierarchical phase
    picks for its own capacity)."""
    from repro.kernels.merge_path import DEFAULT_CHUNK
    if jax.default_backend() == "tpu" and (2 * run_len) % DEFAULT_CHUNK == 0:
        return "pallas"             # the merge-path VMEM kernel
    if run_len & (run_len - 1) == 0:
        # off-TPU the gather-bound rank merge loses badly to the
        # word-parallel min/max box (capacities are pow2-rounded, so this
        # is the interpret-mode default)
        return "bitonic"
    return "xla"


# ---------------------------------------------------------------------------
# shared traced building blocks (run inside the jitted shard_map programs)
# ---------------------------------------------------------------------------

def _exchange_merge(ks, vs, starts, vcnt, coll_axis, p, local_len, c,
                    maxkey, merge_backend, interpret, *,
                    chunks: int = 1, wire_codec: Optional[str] = None):
    """One bucket exchange round over ``coll_axis`` (fan-out ``p``) plus
    the merge of the received runs.

    ``ks`` is a sorted local pool of ``local_len`` slots cut into ``p``
    buckets by ``starts``/``vcnt`` (genuine-key counts).  Send buffers are
    capacity-``c`` padded with ``maxkey``; with ``chunks > 1`` the
    exchange is issued as that many collectives over contiguous bucket
    slices (``collectives.chunked_all_to_all``) so the receiver merges
    ``p * chunks`` shorter runs and the early merge levels overlap the
    in-flight tail of a slow-tier transfer.  ``wire_codec='int8'`` sends
    the *payload* buckets through the lossy grad_compress codec (keys
    always travel wide).

    Returns ``(mk, mv, mvalid, recv_cnt)``: merged keys (length
    ``next_pow2(p * chunks) * (c // chunks)``), merged payload (or None),
    per-slot validity recovered through the merge's position payload, and
    the (p,) genuine-key counts received from each source.
    """
    idx = starts[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    within = jnp.arange(c, dtype=jnp.int32)[None, :] < vcnt[:, None]
    src = jnp.clip(idx, 0, local_len - 1)
    sendk = jnp.where(within, ks[src], maxkey)
    recvk = coll.chunked_all_to_all(sendk, coll_axis, chunks=chunks)
    recv_cnt = coll.all_to_all(vcnt[:, None], coll_axis)[:, 0]   # (p,)

    cp = c // chunks
    n_runs = p * chunks
    r_runs = next_pow2(n_runs)
    runs = recvk.reshape(n_runs, cp)
    if r_runs != n_runs:
        runs = jnp.concatenate(
            [runs, jnp.full((r_runs - n_runs, cp), maxkey, runs.dtype)])
    # one int32 position payload rides the merge; validity flags (and the
    # user payload) are recovered by gathering through it, so ties between
    # capacity fill and genuine max keys cannot corrupt anything
    pos = jnp.arange(r_runs * cp, dtype=jnp.int32).reshape(1, r_runs, cp)
    mk, mpos = merge_runs(runs[None], pos, descending=False,
                          backend=merge_backend, interpret=interpret)
    mk, mpos = mk[0], mpos[0]                                    # (R*cp,)

    # valid slots are a prefix of each *bucket*; slice i of bucket j holds
    # clip(cnt_j - i*cp, 0, cp) of them
    piece_valid = jnp.clip(
        recv_cnt[:, None] - jnp.arange(chunks, dtype=jnp.int32)[None, :] * cp,
        0, cp)                                                   # (p, chunks)
    run_valid = (jnp.arange(cp, dtype=jnp.int32)[None, :]
                 < piece_valid.reshape(-1)[:, None])             # (n_runs, cp)
    if r_runs != n_runs:
        run_valid = jnp.concatenate(
            [run_valid, jnp.zeros((r_runs - n_runs, cp), bool)])
    mvalid = run_valid.reshape(-1)[mpos]

    mv = None
    if vs is not None:
        sendv = jnp.where(within, vs[src], jnp.zeros((), vs.dtype))
        if wire_codec == "int8":
            q, scale = coll.wire_encode_int8(sendv)
            rq = coll.chunked_all_to_all(q, coll_axis, chunks=chunks)
            rs = coll.all_to_all(scale, coll_axis)
            recvv = coll.wire_decode_int8(rq.reshape(p, c), rs, vs.dtype)
        else:
            recvv = coll.chunked_all_to_all(sendv, coll_axis,
                                            chunks=chunks).reshape(p, c)
        vflat = recvv.reshape(-1)
        if r_runs != n_runs:
            vflat = jnp.concatenate(
                [vflat, jnp.zeros(((r_runs - n_runs) * cp,), vflat.dtype)])
        mv = vflat[mpos]
    return mk, mv, mvalid, recv_cnt


def _rebalance(mk, mv, mvalid, recv_cnt, coll_axis, group, m, my):
    """Rank-directed rebalance of a merged pool back to equal ``m``-slot
    shards over ``group`` devices: rank r lives at slot ``r % m`` of
    device ``r // m`` (``my`` is this device's rank-order index within
    the group, matching ``coll_axis``'s device order).  Exactly one
    device owns each slot, so the receive reduction is a plain sum over
    sources (dtype pinned — accumulating zeros is exact, but sum would
    promote narrow ints).  Tail slots past the group's valid count come
    back ZERO, not maxkey — callers that feed the shard into another
    search round must refill them."""
    n_slots = group * m
    c_my = jnp.sum(recv_cnt).astype(jnp.int32)
    counts_all = jax.lax.all_gather(c_my, coll_axis).reshape(-1)  # (group,)
    offset = jnp.sum(jnp.where(jnp.arange(group) < my, counts_all, 0))
    lrank = jnp.cumsum(mvalid.astype(jnp.int32)) - 1
    grank = offset + lrank
    flat = jnp.where(mvalid, grank, n_slots)                  # OOB -> drop
    outk = jnp.zeros((n_slots,), mk.dtype).at[flat].set(
        mk, mode="drop").reshape(group, m)
    shard_k = jnp.sum(coll.all_to_all(outk, coll_axis), axis=0,
                      dtype=mk.dtype)
    shard_v = None
    if mv is not None:
        outv = jnp.zeros((n_slots,), mv.dtype).at[flat].set(
            mv, mode="drop").reshape(group, m)
        shard_v = jnp.sum(coll.all_to_all(outv, coll_axis), axis=0,
                          dtype=mv.dtype)
    return shard_k, shard_v


# ---------------------------------------------------------------------------
# phase 1: local sort + splitters + bucket bounds
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _phase1(mesh: Mesh, axes: Tuple[str, ...], part_axes: Tuple[str, ...],
            n: int, kv: bool, padded: bool, local_method: Optional[str],
            s: int, use_histogram: bool, interpret: Optional[bool]):
    """Jitted program: encoded shard -> (sorted shard[, payload], starts,
    vcnt).  ``axes`` is the full sharding (validity follows the linear
    device index over it); ``part_axes`` is the group the splitters
    partition over — all of ``axes`` for the flat path, the inner axis
    only for the hierarchical first round.

    Cached on its statics so repeated serving-shape calls hit the compiled
    executable; the mesh participates in the key (jax meshes hash).
    """
    n_dev = _n_dev(mesh, axes)
    p = _n_dev(mesh, part_axes)
    m = -(-n // n_dev)

    def local(*args):
        xs = args[0]
        vs = args[1] if kv else None
        my = _lin_index(mesh, axes)
        # valid = not an end-of-array pad; pads all live on the tail shards
        n_valid = jnp.clip(n - my * m, 0, m).astype(jnp.int32)

        # local sort (planner-dispatched registered backend).  Pads carry
        # the maximal encoded key; with a payload they must also stay
        # *behind* genuine max-key ties, so the kv+padded case runs the
        # stable argsort pipeline — validity stays a prefix of the shard
        from repro import sort as _front
        if kv and padded:
            order = _front.argsort(xs, stable=True, method=local_method,
                                   interpret=interpret)
            ks = jnp.take_along_axis(xs, order, -1)
            vs = jnp.take_along_axis(vs, order, -1)
        elif kv:
            ks, vs = _front.sort_kv(xs, vs, method=local_method,
                                    interpret=interpret)
        else:
            ks = _front.sort(xs, method=local_method, interpret=interpret)

        # regular samples -> pooled splitters (one tiny all-gather over
        # the partition group)
        sample_pos = ((jnp.arange(s) + 1) * m) // (s + 1)
        samples = jax.lax.all_gather(ks[sample_pos], _coll_axis(part_axes))
        splitters = select_splitters(samples, p)

        bounds = bucket_bounds(ks, splitters, use_histogram=use_histogram,
                               interpret=interpret)
        # per-bucket count of *genuine* keys: the valid elements are a
        # prefix of the sorted shard, hence a prefix of every bucket
        vcnt = jnp.clip(jnp.minimum(bounds[1:], n_valid) - bounds[:-1],
                        0, m).astype(jnp.int32)
        starts = bounds[:-1]
        if kv:
            return ks, vs, starts, vcnt
        return ks, starts, vcnt

    spec = P(axes)
    n_out = 4 if kv else 3
    fn = _smap(local, mesh, (spec, spec) if kv else (spec,),
               (spec,) * n_out)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# flat phase 2: bucket exchange + merge-path merge + rank rebalance
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _phase2(mesh: Mesh, axes: Tuple[str, ...], n: int, kv: bool,
            capacity: int, key_dtype_name: str,
            val_dtype_name: Optional[str], merge_backend: str,
            chunks: int, wire_codec: Optional[str],
            interpret: Optional[bool]):
    """Jitted program: (sorted shard[, payload], starts, vcnt) -> output
    shard(s).  ``capacity`` is the static per-(source, destination) bucket
    size — phase 1's measured maximum, or m for the always-safe bound."""
    n_dev = _n_dev(mesh, axes)
    m = -(-n // n_dev)
    c = capacity
    ax = _coll_axis(axes)
    maxkey = jnp.array(jnp.iinfo(jnp.dtype(key_dtype_name)).max,
                       jnp.dtype(key_dtype_name))

    def local(*args):
        if kv:
            ks, vs, starts, vcnt = args
        else:
            (ks, starts, vcnt), vs = args, None
        my = _lin_index(mesh, axes)
        mk, mv, mvalid, recv_cnt = _exchange_merge(
            ks, vs, starts, vcnt, ax, n_dev, m, c, maxkey,
            merge_backend, interpret, chunks=chunks, wire_codec=wire_codec)
        shard_k, shard_v = _rebalance(mk, mv, mvalid, recv_cnt, ax,
                                      n_dev, m, my)
        if kv:
            return shard_k, shard_v
        return shard_k

    spec = P(axes)
    n_in = 4 if kv else 3
    fn = _smap(local, mesh, (spec,) * n_in,
               (spec, spec) if kv else spec)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# hierarchical phases 2-4 (two-level: ICI round, DCN round, ICI finalize)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _hier_phase2(mesh: Mesh, outer: str, inner: str, n: int, kv: bool,
                 c1: int, s2: int, key_dtype_name: str,
                 val_dtype_name: Optional[str], merge_backend: str,
                 use_histogram: bool, interpret: Optional[bool]):
    """Intra-host round: ICI bucket exchange + merge + intra-host
    rebalance, then the OUTER splitter prep.  In: phase-1 outputs (shard,
    intra starts/vcnt).  Out: host-sorted equal shards + (d_out,) outer
    bucket starts/vcnt."""
    d_out = int(mesh.shape[outer])
    d_in = int(mesh.shape[inner])
    n_dev = d_out * d_in
    m = -(-n // n_dev)
    host_span = d_in * m
    kdt = jnp.dtype(key_dtype_name)
    maxkey = jnp.array(jnp.iinfo(kdt).max, kdt)

    def local(*args):
        if kv:
            ks, vs, starts, vcnt = args
        else:
            (ks, starts, vcnt), vs = args, None
        ho = jax.lax.axis_index(outer)
        hi = jax.lax.axis_index(inner)

        mk, mv, mvalid, recv_cnt = _exchange_merge(
            ks, vs, starts, vcnt, inner, d_in, m, c1, maxkey,
            merge_backend, interpret)
        shard_k, shard_v = _rebalance(mk, mv, mvalid, recv_cnt, inner,
                                      d_in, m, hi)

        # after the intra rebalance, host g holds global slice
        # [g*host_span, (g+1)*host_span) sorted across its devices; the
        # rebalance zero-fills tail slots, which would corrupt the outer
        # splitter search — refill with the max key (validity is analytic)
        host_valid = jnp.clip(n - ho * host_span, 0, host_span)
        my_valid = jnp.clip(host_valid - hi * m, 0, m).astype(jnp.int32)
        slot = jnp.arange(m, dtype=jnp.int32)
        shard_k = jnp.where(slot < my_valid, shard_k, maxkey)

        # outer splitters: pooled over the WHOLE mesh (each host's shards
        # are now sorted, so regular positions are proper quantiles)
        sample_pos = ((jnp.arange(s2) + 1) * m) // (s2 + 1)
        samples = jax.lax.all_gather(shard_k[sample_pos], (outer, inner))
        splitters = select_splitters(samples, d_out)
        bounds = bucket_bounds(shard_k, splitters,
                               use_histogram=use_histogram,
                               interpret=interpret)
        vcnt2 = jnp.clip(jnp.minimum(bounds[1:], my_valid) - bounds[:-1],
                         0, m).astype(jnp.int32)
        if kv:
            return shard_k, shard_v, bounds[:-1], vcnt2
        return shard_k, bounds[:-1], vcnt2

    spec = P((outer, inner))
    n_in = 4 if kv else 3
    fn = _smap(local, mesh, (spec,) * n_in, (spec,) * n_in)
    return jax.jit(fn)


@functools.lru_cache(maxsize=128)
def _hier_phase3(mesh: Mesh, outer: str, inner: str, n: int, kv: bool,
                 c2: int, chunks: int, s3: int, key_dtype_name: str,
                 val_dtype_name: Optional[str], merge_backend: str,
                 wire_codec: Optional[str], use_histogram: bool,
                 interpret: Optional[bool]):
    """Cross-host round: chunked/pipelined DCN bucket exchange + merge +
    compaction, then the per-host sub-splitter prep for the finalize.
    Out: compacted sorted pool (length L = next_pow2(d_out*chunks) *
    (c2//chunks)) + (d_in,) sub-bucket starts/vcnt."""
    d_out = int(mesh.shape[outer])
    d_in = int(mesh.shape[inner])
    n_dev = d_out * d_in
    m = -(-n // n_dev)
    cp = c2 // chunks
    L = next_pow2(d_out * chunks) * cp
    kdt = jnp.dtype(key_dtype_name)
    maxkey = jnp.array(jnp.iinfo(kdt).max, kdt)

    def local(*args):
        if kv:
            ks, vs, starts, vcnt = args
        else:
            (ks, starts, vcnt), vs = args, None

        mk, mv, mvalid, recv_cnt = _exchange_merge(
            ks, vs, starts, vcnt, outer, d_out, m, c2, maxkey,
            merge_backend, interpret, chunks=chunks, wire_codec=wire_codec)

        # the merged pool interleaves capacity pads with genuine max-key
        # ties, so validity is NOT a prefix — compact it back to one with
        # a rank scatter (maxkey fill keeps the tail sorted for the
        # sub-splitter search)
        c_my = jnp.sum(recv_cnt).astype(jnp.int32)
        lrank = jnp.cumsum(mvalid.astype(jnp.int32)) - 1
        tgt = jnp.where(mvalid, lrank, L)                  # OOB -> drop
        ck = jnp.full((L,), maxkey, mk.dtype).at[tgt].set(mk, mode="drop")
        cv = None
        if kv:
            cv = jnp.zeros((L,), mv.dtype).at[tgt].set(mv, mode="drop")

        # per-host sub-splitters: each host now holds exactly one global
        # key range, but spread over its devices with no inter-device
        # order — sample the *valid prefix* (dynamic length c_my), pool
        # over the inner axis only, and cut d_in sub-buckets
        pos = jnp.clip(((jnp.arange(s3) + 1) * c_my) // (s3 + 1), 0, L - 1)
        samples = jax.lax.all_gather(ck[pos], inner)
        splitters = select_splitters(samples, d_in)
        bounds = bucket_bounds(ck, splitters, use_histogram=use_histogram,
                               interpret=interpret)
        vcnt3 = jnp.clip(jnp.minimum(bounds[1:], c_my) - bounds[:-1],
                         0, L).astype(jnp.int32)
        if kv:
            return ck, cv, bounds[:-1], vcnt3
        return ck, bounds[:-1], vcnt3

    spec = P((outer, inner))
    n_in = 4 if kv else 3
    fn = _smap(local, mesh, (spec,) * n_in, (spec,) * n_in)
    return jax.jit(fn)


@functools.lru_cache(maxsize=128)
def _hier_phase4(mesh: Mesh, outer: str, inner: str, n: int, kv: bool,
                 L: int, c3: int, key_dtype_name: str,
                 val_dtype_name: Optional[str], merge_backend: str,
                 interpret: Optional[bool]):
    """Finalize round: ICI sub-bucket exchange + merge, then the GLOBAL
    rank rebalance over both axes — the concatenation over the linear
    device order is the globally sorted array."""
    d_out = int(mesh.shape[outer])
    d_in = int(mesh.shape[inner])
    n_dev = d_out * d_in
    m = -(-n // n_dev)
    kdt = jnp.dtype(key_dtype_name)
    maxkey = jnp.array(jnp.iinfo(kdt).max, kdt)

    def local(*args):
        if kv:
            ks, vs, starts, vcnt = args
        else:
            (ks, starts, vcnt), vs = args, None
        my = _lin_index(mesh, (outer, inner))

        mk, mv, mvalid, recv_cnt = _exchange_merge(
            ks, vs, starts, vcnt, inner, d_in, L, c3, maxkey,
            merge_backend, interpret)
        shard_k, shard_v = _rebalance(mk, mv, mvalid, recv_cnt,
                                      (outer, inner), n_dev, m, my)
        if kv:
            return shard_k, shard_v
        return shard_k

    spec = P((outer, inner))
    n_in = 4 if kv else 3
    fn = _smap(local, mesh, (spec,) * n_in,
               (spec, spec) if kv else spec)
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# front door
# ---------------------------------------------------------------------------

def _sync_max(vcnt) -> Optional[int]:
    """Host-sync the measured bucket maximum (None under an outer jit)."""
    try:
        return int(np.max(np.asarray(vcnt)))
    except jax.errors.TracerArrayConversionError:
        return None


def sample_sort(x: jnp.ndarray, mesh: Mesh, axis_name: AxisArg = "data", *,
                values: Optional[jnp.ndarray] = None,
                descending: bool = False,
                local_method: Optional[str] = None,
                samples_per_shard: Optional[int] = None,
                capacity: Optional[int] = None,
                capacity_slack: Optional[float] = None,
                use_histogram: Optional[bool] = None,
                merge_backend: Optional[str] = None,
                hierarchical: Optional[bool] = None,
                pipeline_chunks: Optional[int] = None,
                wire_codec: Optional[str] = None,
                interpret: Optional[bool] = None):
    """Globally sort a 1-D array over ``axis_name`` — one mesh axis, a
    tuple of axes, or ``None`` for the whole mesh.  Returns the sorted
    array (or ``(keys, values)`` with a payload), same length and
    sharding layout as the input.

    On a two-axis mesh ``(outer, inner)`` the sort defaults to the
    **hierarchical** two-level schedule (see the module docstring): an
    intra-host samplesort round over the fast inner tier, ONE chunked
    cross-host exchange over the slow outer tier, and an intra-host
    finalize — the flat single-exchange path remains available as
    ``hierarchical=False`` (and is the only path on one-axis meshes).
    Both produce bit-identical output.

    ``capacity`` overrides the measured per-(source, destination) bucket
    capacity on the flat path; it is validated against the realized
    bucket bounds and raises rather than silently dropping elements when
    too small (``m``, the shard length, is always sufficient).  Under an
    outer ``jax.jit`` the measured mode is unavailable (it syncs counts
    to the host) and the realized bounds cannot be checked, so only
    ``capacity >= m`` is accepted there; the hierarchical path measures
    three capacities and cannot run under an outer jit at all.

    ``capacity_slack`` (default: the active tuning profile's) multiplies
    the *measured* bucket maxima before pow2 rounding: >1 buys headroom
    so nearby workloads with slightly more skew reuse the same compiled
    programs instead of recompiling at the next capacity.

    ``pipeline_chunks`` splits the slow-tier exchange into that many
    chunked collectives (``collectives.pipeline_chunks`` picks the
    realizable count); ``wire_codec='int8'`` sends the float *payload*
    buckets of the cross-host exchange through the lossy grad_compress
    codec — keys always travel wide, so the sort ORDER stays exact while
    payload values are quantised.
    """
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"sample_sort sorts flat 1-D arrays, got {x.shape}")
    if not keycodec.supports(x.dtype):
        raise ValueError(
            f"sample_sort needs a keycodec dtype {keycodec.SUPPORTED}, "
            f"got {jnp.dtype(x.dtype).name!r}")
    axes = _axes_tuple(mesh, axis_name)
    n = x.shape[0]
    n_dev = _n_dev(mesh, axes)
    m = -(-n // n_dev)                      # shard length (output = input)
    n_pad = n_dev * m
    kv = values is not None
    if kv:
        values = jnp.asarray(values)
        if values.shape != x.shape:
            raise ValueError(f"values shape {values.shape} must match "
                             f"keys shape {x.shape}")
    two_tier = len(axes) == 2 and \
        all(int(mesh.shape[a]) > 1 for a in axes)
    if hierarchical and len(axes) != 2:
        raise ValueError(
            f"hierarchical sample_sort needs exactly two mesh axes "
            f"(outer, inner); got {axes}")
    # a degenerate tier (size-1 axis) makes the two-level schedule pure
    # overhead — it silently collapses to the flat path, same output
    hier = two_tier if hierarchical is None else (hierarchical and two_tier)
    if wire_codec is not None:
        if wire_codec not in coll.WIRE_CODECS:
            raise ValueError(f"unknown wire_codec {wire_codec!r}; "
                             f"available: {coll.WIRE_CODECS}")
        if not kv:
            raise ValueError("wire_codec compresses the PAYLOAD buckets; "
                             "pass values= (keys always travel wide)")
        if not jnp.issubdtype(values.dtype, jnp.floating):
            raise ValueError(
                f"wire_codec='int8' quantises float payloads, got "
                f"{jnp.dtype(values.dtype).name!r}")
    if use_histogram is None:
        use_histogram = jax.default_backend() == "tpu"
    s = samples_per_shard or default_samples_per_shard(m, n_dev)
    slack = capacity_slack if capacity_slack is not None \
        else _tuning.active().capacity_slack

    enc = keycodec.encode(x, descending=descending)
    padded = n_pad != n
    if padded:
        maxkey = jnp.array(jnp.iinfo(enc.dtype).max, enc.dtype)
        enc = jnp.pad(enc, (0, n_pad - n), constant_values=maxkey)
        if kv:
            values = jnp.pad(values, (0, n_pad - n))
    kname = jnp.dtype(enc.dtype).name
    vname = jnp.dtype(values.dtype).name if kv else None
    itemsize = jnp.dtype(enc.dtype).itemsize + \
        (jnp.dtype(values.dtype).itemsize if kv else 0)

    if hier:
        out = _hier_sample_sort(
            enc, values, mesh, axes, n, kv, padded, local_method, s,
            capacity, slack, use_histogram, merge_backend,
            pipeline_chunks, wire_codec, itemsize, kname, vname, interpret)
    else:
        out = _flat_sample_sort(
            enc, values, mesh, axes, n, kv, padded, local_method, s,
            capacity, slack, use_histogram, merge_backend,
            pipeline_chunks, wire_codec, itemsize, kname, vname, interpret)
    if kv:
        out_k, out_v = out
        keys = keycodec.decode(out_k[:n], x.dtype, descending=descending)
        return keys, out_v[:n]
    return keycodec.decode(out[:n], x.dtype, descending=descending)


def _flat_sample_sort(enc, values, mesh, axes, n, kv, padded, local_method,
                      s, capacity, slack, use_histogram, merge_backend,
                      pipeline_chunks, wire_codec, itemsize, kname, vname,
                      interpret):
    """The one-tier path: splitters over the whole mesh, ONE exchange."""
    n_dev = _n_dev(mesh, axes)
    m = -(-n // n_dev)
    p1 = _phase1(mesh, axes, axes, n, kv, padded, local_method, s,
                 use_histogram, interpret)
    sp1 = _obs.trace("samplesort.phase1", n=n, n_dev=n_dev, kv=kv,
                     samples_per_shard=s)
    with sp1:
        if kv:
            ks, vs, starts, vcnt = p1(enc, values)
        else:
            ks, starts, vcnt = p1(enc)
        sp1.fence(vcnt)

    # the one host sync: the realized bucket maximum sets the static
    # exchange capacity, so buffers and merge work scale with what the
    # data needs (~m/D with regular sampling) instead of the worst case m
    max_bucket = _sync_max(vcnt)
    if capacity is None:
        if max_bucket is None:
            raise ValueError(
                "sample_sort's measured-capacity mode reads the bucket "
                "counts on the host and cannot run under an outer jit; "
                f"pass capacity= (the shard length {m} is always safe)")
        cap = _round_capacity(int(math.ceil(max_bucket * slack)), m)
    else:
        cap = _round_capacity(capacity, m)
        if max_bucket is None and cap < m:
            # under a trace there is no way to raise later, and a
            # too-small capacity would silently drop elements — only the
            # provably-safe shard-length capacity is allowed
            raise ValueError(
                f"under an outer jit, capacity must be >= the shard "
                f"length {m} (the realized bucket maximum cannot be "
                f"checked at trace time); got {capacity}")
        if max_bucket is not None and cap < max_bucket:
            raise ValueError(
                f"capacity {capacity} is smaller than the realized maximum "
                f"bucket ({max_bucket}); the shard length {m} is always "
                f"safe")
    chunks = coll.pipeline_chunks(cap, pipeline_chunks) \
        if pipeline_chunks is not None else 1
    if merge_backend is None:
        merge_backend = _pick_merge_backend(cap // chunks)

    total_bytes = n_dev * alltoall_bytes_per_device(n_dev, m, itemsize, cap)
    if _obs.enabled() and max_bucket is not None:
        # bucket-skew accounting: vcnt is the full (D*D,) per-(source,
        # destination) genuine-key count table, already synced to the host
        # for the capacity measurement — skew 1.0 means perfectly regular
        # splitters, capacity (and the exchange bill) scales with it
        counts = np.asarray(vcnt, dtype=np.float64)
        mean_fill = float(counts.mean()) if counts.size else 0.0
        skew = float(max_bucket) / mean_fill if mean_fill else 1.0
        metrics.gauge("samplesort.bucket_skew").set(skew)
        metrics.histogram("samplesort.bucket_fill_max").observe(max_bucket)
        metrics.counter("samplesort.alltoall_bytes").inc(total_bytes)
        metrics.counter("samplesort.sorts").inc()
        if len(axes) == 2:
            coll.record_split_exchange(total_bytes,
                                       int(mesh.shape[axes[1]]),
                                       int(mesh.shape[axes[0]]))
        else:
            coll.record_exchange("ici", total_bytes)

    p2 = _phase2(mesh, axes, n, kv, cap, kname, vname, merge_backend,
                 chunks, wire_codec, interpret)
    sp2 = _obs.trace("samplesort.phase2", n=n, n_dev=n_dev, capacity=cap,
                     merge_backend=merge_backend,
                     bytes=total_bytes if _obs.enabled() else 0)
    with sp2:
        if kv:
            out_k, out_v = p2(ks, vs, starts, vcnt)
            sp2.fence((out_k, out_v))
            return out_k, out_v
        out = p2(ks, starts, vcnt)
        sp2.fence(out)
        return out


def _hier_sample_sort(enc, values, mesh, axes, n, kv, padded, local_method,
                      s, capacity, slack, use_histogram, merge_backend,
                      pipeline_chunks, wire_codec, itemsize, kname, vname,
                      interpret):
    """The two-level driver: four jitted phases, three capacity syncs."""
    outer_ax, inner_ax = axes
    d_out = int(mesh.shape[outer_ax])
    d_in = int(mesh.shape[inner_ax])
    n_dev = d_out * d_in
    m = -(-n // n_dev)
    if capacity is not None:
        raise ValueError(
            "capacity= overrides the FLAT exchange capacity; the "
            "hierarchical path measures three per-phase capacities "
            "(pass hierarchical=False to pin the flat one)")

    # phase 1: local sort + INTRA-host splitters (partition group = inner)
    p1 = _phase1(mesh, axes, (inner_ax,), n, kv, padded, local_method, s,
                 use_histogram, interpret)
    sp1 = _obs.trace("samplesort.hier.phase1", n=n, n_dev=n_dev, kv=kv,
                     d_out=d_out, d_in=d_in, samples_per_shard=s)
    with sp1:
        if kv:
            ks, vs, starts, vcnt = p1(enc, values)
        else:
            ks, starts, vcnt = p1(enc)
            vs = None
        sp1.fence(vcnt)
    max1 = _sync_max(vcnt)
    if max1 is None:
        raise ValueError(
            "hierarchical sample_sort measures per-phase exchange "
            "capacities on the host and cannot run under an outer jit; "
            "call it eagerly, or pass hierarchical=False with capacity=")
    c1 = _round_capacity(int(math.ceil(max1 * slack)), m)
    mb1 = merge_backend or _pick_merge_backend(c1)

    # phase 2: ICI exchange + intra-host rebalance + outer splitter prep
    p2 = _hier_phase2(mesh, outer_ax, inner_ax, n, kv, c1, s, kname, vname,
                      mb1, use_histogram, interpret)
    sp2 = _obs.trace("samplesort.hier.phase2", n=n, capacity=c1,
                     merge_backend=mb1)
    with sp2:
        if kv:
            ks, vs, starts, vcnt = p2(ks, vs, starts, vcnt)
        else:
            ks, starts, vcnt = p2(ks, starts, vcnt)
        sp2.fence(vcnt)
    max2 = _sync_max(vcnt)
    c2 = _round_capacity(int(math.ceil(max2 * slack)), m)
    chunks = coll.pipeline_chunks(c2, pipeline_chunks)
    mb2 = merge_backend or _pick_merge_backend(c2 // chunks)

    # phase 3: chunked DCN exchange + compaction + sub-splitter prep
    p3 = _hier_phase3(mesh, outer_ax, inner_ax, n, kv, c2, chunks, s,
                      kname, vname, mb2, wire_codec, use_histogram,
                      interpret)
    sp3 = _obs.trace("samplesort.hier.phase3", n=n, capacity=c2,
                     chunks=chunks, wire_codec=wire_codec or "none",
                     merge_backend=mb2)
    with sp3:
        if kv:
            ks, vs, starts, vcnt = p3(ks, vs, starts, vcnt)
        else:
            ks, starts, vcnt = p3(ks, starts, vcnt)
        sp3.fence(vcnt)
    L = next_pow2(d_out * chunks) * (c2 // chunks)
    max3 = _sync_max(vcnt)
    c3 = _round_capacity(int(math.ceil(max3 * slack)), L)
    mb3 = merge_backend or _pick_merge_backend(c3)

    if _obs.enabled():
        # per-tier movement bill (analytic, like the flat path's):
        # ICI carries the intra round (exchange + intra rebalance), the
        # finalize exchange, and its share of the global rebalance; DCN
        # carries the cross-host buckets (narrowed by the wire codec) and
        # the rest of the rebalance
        ici = n_dev * alltoall_bytes_per_device(d_in, m, itemsize, c1)
        ici += n_dev * d_in * c3 * itemsize
        dcn = n_dev * d_out * c2 * itemsize
        if wire_codec == "int8":
            val_is = jnp.dtype(vname).itemsize
            saved = n_dev * coll.wire_bytes_saved(d_out, c2, val_is)
            dcn -= saved
            metrics.counter("collectives.wire_bytes_saved").inc(saved)
        coll.record_exchange("ici", ici)
        coll.record_exchange("dcn", dcn)
        coll.record_split_exchange(n_dev * n_dev * m * itemsize,
                                   d_in, d_out)
        metrics.counter("samplesort.alltoall_bytes").inc(
            ici + dcn + n_dev * n_dev * m * itemsize)
        metrics.counter("samplesort.sorts").inc()

    # phase 4: ICI finalize exchange + GLOBAL rank rebalance
    p4 = _hier_phase4(mesh, outer_ax, inner_ax, n, kv, L, c3, kname, vname,
                      mb3, interpret)
    sp4 = _obs.trace("samplesort.hier.phase4", n=n, capacity=c3,
                     merge_backend=mb3)
    with sp4:
        if kv:
            out_k, out_v = p4(ks, vs, starts, vcnt)
            sp4.fence((out_k, out_v))
            return out_k, out_v
        out = p4(ks, starts, vcnt)
        sp4.fence(out)
        return out


# ---------------------------------------------------------------------------
# distributed top-k: local select -> ONE candidate all-gather -> tiny merge
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=128)
def _topk_prog(mesh: Mesh, axes: Tuple[str, ...], n: int, k: int,
               key_dtype_name: str, use_kernel: Optional[bool],
               interpret: Optional[bool]):
    """Jitted program: encoded padded shard -> replicated (enc topk, global
    indices).  Cached on its statics like the sample-sort phases."""
    from repro.kernels import radix_select as _sel
    n_dev = _n_dev(mesh, axes)
    m = -(-n // n_dev)
    kc = min(k, m)                       # per-shard candidate count
    kdt = jnp.dtype(key_dtype_name)
    maxkey = jnp.array(jnp.iinfo(kdt).max, kdt)

    def local(enc):
        my = _lin_index(mesh, axes)
        base = (my * m).astype(jnp.int32)
        # end-of-array pads all live on the tail shards; force them to the
        # maximal encoded key so the local select ranks them last, and mark
        # them with the out-of-range global index n so a pad tying a
        # genuine extreme key can never displace it in the candidate merge
        n_valid = jnp.clip(n - base, 0, m).astype(jnp.int32)
        valid = jnp.arange(m, dtype=jnp.int32) < n_valid
        e = jnp.where(valid, enc, maxkey)

        # local selection: the kc smallest encoded keys of this shard —
        # §II-B's "partitions sort concurrently", in partial-sort mode
        le, li = _sel.select_topk_encoded(e[None], kc,
                                         use_kernel=use_kernel,
                                         interpret=interpret)
        gi = jnp.where(li[0] < n_valid, base + li[0],
                       jnp.array(n, jnp.int32))

        # THE one collective: D·kc candidates (vs sample-sort's bucket
        # all-to-all of whole shards); every device then runs the same
        # tiny lexicographic merge, so the result is replicated
        ax = _coll_axis(axes)
        ce = jax.lax.all_gather(le[0], ax).reshape(-1)
        ci = jax.lax.all_gather(gi, ax).reshape(-1)
        se, si = jax.lax.sort((ce, ci), num_keys=2)
        return se[:k], si[:k]

    fn = _smap(local, mesh, (P(axes),), (P(None), P(None)))
    return jax.jit(fn)


def sample_topk(x: jnp.ndarray, k: int, mesh: Mesh,
                axis_name: AxisArg = "data", *,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None):
    """Mesh-global top-k of a flat array -> ``(values, indices)``, both
    ``(k,)`` and replicated, bit-exact with ``jax.lax.top_k`` on the
    gathered array (values descending, ties keep the lowest global index).

    Movement is the whole point: each device radix-selects its shard's
    ``min(k, m)`` candidates locally (O(m·passes), no sort), ONE
    all-gather moves the ``D·min(k, m)`` candidate (key, index) pairs, and
    a two-key lexicographic sort of that tiny pool — the merge-box reduce
    over D already-sorted candidate runs — finishes on every device.  No
    full-array sort, no bucket all-to-all, no rebalance round: for
    ``k ≪ n`` the collective bill shrinks from O(m) per device to O(D·k).
    The candidate pool is small enough that even on a two-tier mesh the
    flat all-gather IS the right schedule — there is no hierarchical
    variant to pick.

    Correctness of the candidate cut: a shard with ``g`` genuine elements
    contributes ``min(kc, g)`` of them, and ``sum(min(kc, g_d)) >= k``
    whenever ``n >= k`` — so the global top-k is always inside the pool.
    """
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"sample_topk selects over flat 1-D arrays, "
                         f"got {x.shape}")
    if not keycodec.supports(x.dtype):
        raise ValueError(
            f"sample_topk needs a keycodec dtype {keycodec.SUPPORTED}, "
            f"got {jnp.dtype(x.dtype).name!r}")
    n = x.shape[0]
    if not 1 <= k <= n:
        raise ValueError(
            f"topk k must satisfy 1 <= k <= n (n={n}); got k={k}")
    axes = _axes_tuple(mesh, axis_name)
    n_dev = _n_dev(mesh, axes)
    m = -(-n // n_dev)
    enc = keycodec.encode(x, descending=True)
    if n_dev * m != n:
        maxkey = jnp.array(jnp.iinfo(enc.dtype).max, enc.dtype)
        enc = jnp.pad(enc, (0, n_dev * m - n), constant_values=maxkey)
    prog = _topk_prog(mesh, axes, n, k,
                      jnp.dtype(enc.dtype).name, use_kernel, interpret)
    cand_bytes = 0
    if _obs.enabled():
        cand_bytes = n_dev * topk_candidate_bytes_per_device(
            n_dev, k, m, jnp.dtype(enc.dtype).itemsize)
        metrics.counter("samplesort.topk_candidate_bytes").inc(cand_bytes)
        if len(axes) == 2:
            coll.record_split_exchange(cand_bytes,
                                       int(mesh.shape[axes[1]]),
                                       int(mesh.shape[axes[0]]))
        else:
            coll.record_exchange("ici", cand_bytes)
    sp = _obs.trace("samplesort.topk", n=n, k=k, n_dev=n_dev,
                    bytes=cand_bytes)
    with sp:
        ev, ei = prog(enc)
        sp.fence((ev, ei))
    return keycodec.decode(ev, x.dtype, descending=True), ei


def topk_candidate_bytes_per_device(n_dev: int, k: int, local_elems: int,
                                    itemsize: int) -> int:
    """Analytic ICI volume of the candidate all-gather (per device): the
    ``k ≪ n`` counterpart of ``alltoall_bytes_per_device`` — D·min(k, m)
    (key, int32 index) pairs instead of capacity-padded whole buckets."""
    kc = min(k, local_elems)
    return n_dev * kc * (itemsize + 4)


def _round_capacity(cap: int, m: int) -> int:
    """Static capacity: at least one slot, padded up a little so nearby
    workloads share a compiled program, never beyond the local pool."""
    cap = max(1, cap)
    if cap >= m:
        return m
    return min(m, next_pow2(cap))


def alltoall_bytes_per_device(n_dev: int, local_elems: int,
                              itemsize: int, capacity: Optional[int] = None
                              ) -> int:
    """Analytic interconnect volume of one sample-sort round (per
    device): the capacity-padded bucket all-to-all plus the rank
    rebalance round — versus ``n_dev`` full-shard moves for odd-even
    transposition (``distributed_sort.collective_bytes_per_device``)."""
    cap = capacity if capacity is not None else \
        min(local_elems, 2 * local_elems // max(1, n_dev) + 1)
    return (n_dev * cap + n_dev * local_elems) * itemsize
