"""Cost-model dispatch — picks the sorting backend from (n, batch, dtype).

Extends the paper-constant cost model (core/cost_model.py) with per-tile
constants that can be *measured* on the running backend, then prices every
eligible backend and returns the cheapest as an executable plan.
``method="auto"`` on the public API is a thin wrapper over this module.

Eligibility is a pure capability query against the backend registry
(core/sortspec.py): each backend declares the dtypes it sorts correctly,
an optional auto-dispatch size cap, and whether auto may pick it at all —
there are no per-backend validity rules here, so a third-party backend
registered with ``@register_backend`` is priced and dispatched without any
planner edits.  Pricing likewise goes through ``SortBackend.cost_ns``
(defaulting to the analytic model; unknown backends price at +inf until
they override it).

Resolved plans are cached per (n, batch, dtype, requested, run_len) and
invalidated on calibration or registry changes, so repeated serving-shape
calls skip re-planning entirely.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cost_model, sortspec
from repro.core import tuning as _tuning
from repro.core.backends import MAX_BITONIC_N, MAX_PALLAS_N  # noqa: F401
from repro.engine import runs as _runs


@dataclasses.dataclass(frozen=True)
class Plan:
    """Executable dispatch decision for one (n, batch, dtype) workload."""
    method: str                  # any auto-dispatchable registered backend
    run_len: int                 # engine tile size (merge method only)
    run_method: str              # backend sorting each run
    merge_backend: str           # "xla" | "pallas" merge primitive
    costs: Dict[str, float]      # estimated ns per candidate


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def constants() -> cost_model.DeviceSortConstants:
    """The cost constants every plan is priced with — the active tuning
    profile's (per-platform defaults until ``calibrate()`` measures real
    ones or a persisted profile matches the device fingerprint)."""
    return _tuning.active().constants


def _eligible(method: str, n: int, dtype, run_len: int) -> bool:
    """Generic capability query: may auto hand (n, dtype) to ``method``?"""
    return sortspec.get_backend(method).eligible(n, dtype, run_len)


def _auto_candidates() -> Dict[str, sortspec.SortBackend]:
    return {name: be for name, be in sortspec.registered_backends().items()
            if be.capabilities.auto_dispatch}


def choose(n: int, batch: int = 1, dtype=jnp.float32, *,
           requested: str = "auto",
           run_len: Optional[int] = None,
           k: Optional[int] = None) -> Plan:
    """Resolve ``requested`` ("auto" or a concrete method) into a Plan.

    With ``k`` set the workload is a top-k and every candidate is priced
    through ``SortBackend.topk_cost_ns``: selection backends answer with
    the O(n·passes) ``cost_model.selection_cost_ns``, sort backends with
    the sort-prefix contract (their full sort cost), and the xla backend
    with the *native* ``lax.top_k`` price off-TPU — so auto lands on
    radix-select once ``k ≪ n`` on TPU, on the tuned native selection on
    hosts (where it beats everything — the ``topk_xla`` rows in
    results_engine_cpu.csv), and on a plain sort when k approaches n.

    Every resolved plan is recorded as a structured ``plan_decision``
    event when observability is on (repro.obs) — candidate cost table,
    chosen backend, predicted ns — so dispatch is auditable after the
    fact; ``choose_cached`` hits skip both re-pricing and the event.
    """
    prof = _tuning.active()
    rl = run_len or prof.run_len
    consts = prof.constants
    interp = not on_tpu()
    candidates = _auto_candidates()
    costs = {
        name: (be.topk_cost_ns(n, k, batch, dtype, run_len=rl,
                               consts=consts, interpreted=interp)
               if k is not None
               else be.cost_ns(n, batch, dtype, run_len=rl, consts=consts,
                               interpreted=interp))
        for name, be in candidates.items()
    }
    # out-of-core routing is by *feasibility*, not price: key bytes beyond
    # the active profile's spill threshold do not fit the device backends'
    # working set (input + runs + merge ping-pong), so the spill tier is
    # the only honest plan above it and never a candidate below it.
    # Top-k stays on the device paths (a dataset-scale top-k wants
    # per-chunk selection + candidate merge — ROADMAP follow-through).
    itemsize = jnp.dtype(dtype).itemsize
    oversized = (k is None
                 and n * batch * itemsize > prof.spill_threshold_bytes
                 and sortspec.get_backend("spill").eligible(n, dtype, rl))
    if k is None and (oversized or requested == "spill"):
        costs["spill"] = cost_model.spill_sort_cost_ns(
            n, batch, itemsize, consts=consts)
    if requested == "auto":
        if oversized:
            method = "spill"
        else:
            def _valid(name: str) -> bool:
                caps = candidates[name].capabilities
                if not candidates[name].eligible(n, dtype, rl):
                    return False
                # selection switch-over: below the tuned floor the
                # O(n·passes) counting constant never beats a tiny sort,
                # and the modeled crossover is noisy at small n — auto
                # skips selection engines there (explicit
                # requested="select" is still honoured)
                if k is not None and caps.selection and n < prof.select_min_n:
                    return False
                # sort plans need a sorter; top-k plans need a topk path
                return caps.supports_topk if k is not None \
                    else caps.supports_sort
            valid = [m for m in costs if _valid(m)]
            method = min(valid, key=costs.__getitem__)
    else:
        method = requested
    run_method = "pallas" if (on_tpu() and _eligible("pallas", rl, dtype, rl)) \
        else "xla"
    merge_backend = "pallas" if on_tpu() else "xla"
    plan = Plan(method=method, run_len=rl, run_method=run_method,
                merge_backend=merge_backend, costs=costs)
    _record_decision(plan, n=n, batch=batch, dtype=dtype, requested=requested,
                     k=k)
    return plan


def _record_decision(plan: Plan, *, n: int, batch: int, dtype,
                     requested: str, k: Optional[int]) -> None:
    """One structured event per resolved plan (cache misses only — hits
    never reach ``choose``).  No-op unless observability is enabled."""
    from repro.obs import trace as _obs
    if not _obs.enabled():
        return
    _obs.record_event(
        "plan_decision", n=n, batch=batch, dtype=jnp.dtype(dtype).name,
        requested=requested, k=k, method=plan.method,
        predicted_ns=plan.costs.get(plan.method),
        costs={m: c for m, c in plan.costs.items()},
        run_len=plan.run_len, backend=jax.default_backend())
    from repro.obs import metrics as _m
    _m.counter("planner.decisions").inc()


def choose_method(n: int, batch: int = 1, dtype=jnp.float32) -> str:
    """Just the backend name — what the public "auto" resolves to."""
    return choose(n, batch, dtype).method


# ---------------------------------------------------------------------------
# relational dispatch — which sorting backend carries each relational op
# ---------------------------------------------------------------------------

def choose_relational(op: str, n: int, batch: int = 1, dtype=jnp.float32, *,
                      requested: str = "auto") -> Plan:
    """Resolve the sort backbone for a relational op (repro.relational).

    Prices every auto-dispatchable sort backend with
    ``cost_model.relational_cost_ns``.  Order-sensitive ops (join's
    duplicate-pair order, group-by's arrival-order aggregation,
    group_ranks) run the engine's *stable* pipeline: a non-stable backend
    would be silently substituted by the forced-stable merge fallback at
    execution time (``engine.argsort``/``sort_kv`` with ``stable=True``),
    so the planner prices those candidates at that fallback — the honest
    cost of actually picking them — instead of their raw sort cost.
    """
    from repro.core import keycodec
    from repro.relational.relspec import SORT_OPS, STABLE_OPS
    if op not in SORT_OPS:
        raise ValueError(
            f"choose_relational plans the sort-backed ops "
            f"{tuple(sorted(SORT_OPS))}, got {op!r}")
    prof = _tuning.active()
    rl = prof.run_len
    consts = prof.constants
    interp = not on_tpu()
    kb = keycodec.key_bits(dtype) if keycodec.supports(dtype) else 32
    candidates = {name: be for name, be in _auto_candidates().items()
                  if be.capabilities.supports_sort}
    costs: Dict[str, float] = {}
    for name, be in candidates.items():
        effective = name
        if op in STABLE_OPS and not be.capabilities.stable \
                and name != "merge":
            effective = "merge"
        try:
            costs[name] = cost_model.relational_cost_ns(
                op, effective, n, batch, run_len=rl, key_bits=kb,
                consts=consts, pallas_interpreted=interp)
        except ValueError:
            costs[name] = float("inf")   # unknown backend: never auto-picked
    if requested == "auto":
        valid = [m for m in costs
                 if candidates[m].eligible(n, dtype, rl)
                 and costs[m] != float("inf")]
        method = min(valid, key=costs.__getitem__)
    else:
        method = requested
    run_method = "pallas" if (on_tpu() and _eligible("pallas", rl, dtype, rl)) \
        else "xla"
    plan = Plan(method=method, run_len=rl, run_method=run_method,
                merge_backend="pallas" if on_tpu() else "xla", costs=costs)
    from repro.obs import trace as _obs
    if _obs.enabled():
        _obs.record_event(
            "relational_plan_decision", op=op, n=n, batch=batch,
            dtype=jnp.dtype(dtype).name, requested=requested,
            method=plan.method, predicted_ns=plan.costs.get(plan.method),
            costs=dict(plan.costs), backend=jax.default_backend())
        from repro.obs import metrics as _m
        _m.counter("planner.relational_decisions").inc()
    return plan


def choose_relational_cached(op: str, n: int, batch: int = 1,
                             dtype=jnp.float32, *,
                             requested: str = "auto") -> Plan:
    """``choose_relational`` memoized in the shared plan cache — same
    invalidation rules (calibration generation, registry generation)."""
    key = ("rel", op, n, batch, jnp.dtype(dtype).name, requested,
           _tuning.generation(), sortspec.registry_generation(),
           jax.default_backend())
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = choose_relational(op, n, batch, dtype, requested=requested)
        _PLAN_CACHE[key] = plan
    else:
        from repro.obs import trace as _obs
        if _obs.enabled():
            from repro.obs import metrics as _m
            _m.counter("planner.plan_cache_hits").inc()
    return plan


# ---------------------------------------------------------------------------
# distributed dispatch — sample-sort vs odd-even vs hierarchical
# ---------------------------------------------------------------------------

DIST_STRATEGIES = ("sample", "oddeven")


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Dispatch decision for a mesh-global sort of n over n_dev devices."""
    strategy: str                # "sample" | "oddeven" | "hier"
    n_dev: int
    costs: Dict[str, float]      # estimated ns per strategy


def choose_distributed(n: int, n_dev: int, dtype=jnp.float32, *,
                       topology=None) -> DistPlan:
    """Price the distributed strategies and return the cheapest one.

    Odd-even transposition pays D collective launches but only a bitonic
    merge box per round; sample-sort pays two capacity-padded all-to-alls
    plus one merge-path tree.  Small (n, D) therefore stays on odd-even
    and large workloads cross over to the single-round exchange — the
    mesh-level mirror of the engine's run-length crossover.

    With a two-tier ``topology`` (``core.topology.Topology``, e.g. from
    ``Topology.for_mesh``) a third candidate joins: the **hierarchical**
    two-level sample-sort, priced per tier
    (``cost_model.hierarchical_sort_cost_ns``) while the flat strategies
    pay the *blended* two-tier link rate
    (``cost_model.flat_collective_rates`` — a flat exchange sends an
    ``(outer-1)/outer`` fraction of its traffic over the slow tier).
    Flat wins on uniform meshes (the hierarchy's extra intra rounds are
    pure overhead there); hierarchical wins once the slow tier is
    skewed enough that confining most traffic to the fast tier pays.
    """
    itemsize = jnp.dtype(dtype).itemsize
    consts = constants()
    hier = topology is not None and topology.is_hierarchical \
        and len(topology.axes) >= 2
    if not hier:
        costs = {
            s: cost_model.distributed_sort_cost_ns(s, n, n_dev, itemsize,
                                                   consts=consts)
            for s in DIST_STRATEGIES
        }
        return DistPlan(strategy=min(costs, key=costs.__getitem__),
                        n_dev=n_dev, costs=costs)
    if topology.n_devices != n_dev:
        raise ValueError(
            f"topology spans {topology.n_devices} devices, the sort "
            f"plans for {n_dev}")
    outer = topology.axes[0]
    innermost = topology.axes[-1]
    inner_size = n_dev // outer.size
    ia, ib = innermost.latency_ns, innermost.per_byte_ns
    da, db = outer.latency_ns, outer.per_byte_ns
    fa, fb = cost_model.flat_collective_rates(
        inner_size, outer.size, ici_alpha=ia, ici_per_byte=ib,
        dcn_alpha=da, dcn_per_byte=db)
    costs = {
        s: cost_model.distributed_sort_cost_ns(s, n, n_dev, itemsize,
                                               consts=consts,
                                               alpha=fa, per_byte=fb)
        for s in DIST_STRATEGIES
    }
    costs["hier"] = cost_model.hierarchical_sort_cost_ns(
        n, inner_size, outer.size, itemsize, consts=consts,
        ici_alpha=ia, ici_per_byte=ib, dcn_alpha=da, dcn_per_byte=db)
    return DistPlan(strategy=min(costs, key=costs.__getitem__),
                    n_dev=n_dev, costs=costs)


def choose_distributed_cached(n: int, n_dev: int, dtype=jnp.float32, *,
                              topology=None) -> DistPlan:
    """``choose_distributed`` memoized alongside the single-device plans —
    same invalidation rules (calibration state, registry generation) plus
    the topology generation and *full* per-axis identity, so
    ``topology.calibrate()`` or swapping the active topology transparently
    re-plans.  The key carries the link rates, not just the mesh shape:
    two same-shaped topologies with different tier rates are different
    pricing problems and must never share a plan."""
    from repro.core import topology as _topo
    tsig = None if topology is None else tuple(
        (a.name, a.size, a.tier, a.bandwidth_bytes_per_s, a.latency_ns)
        for a in topology.axes)
    key = ("dist", n, n_dev, jnp.dtype(dtype).name, tsig,
           _topo.generation(), _tuning.generation(),
           sortspec.registry_generation(), jax.default_backend())
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = choose_distributed(n, n_dev, dtype, topology=topology)
        _PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict[tuple, Plan] = {}


def choose_cached(n: int, batch: int = 1, dtype=jnp.float32, *,
                  requested: str = "auto",
                  run_len: Optional[int] = None,
                  k: Optional[int] = None) -> Plan:
    """``choose`` memoized on the workload statics (``k`` included — a
    top-k plan and a sort plan for the same row shape differ).

    Serving paths hit the same (shape, dtype, spec) combination every step;
    this skips re-pricing entirely.  The cache key folds in the calibration
    state and the registry generation, so ``calibrate()`` or registering a
    new backend transparently re-plans.
    """
    key = (n, batch, jnp.dtype(dtype).name, requested, run_len, k,
           _tuning.generation(), sortspec.registry_generation(),
           jax.default_backend())
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = choose(n, batch, dtype, requested=requested, run_len=run_len,
                      k=k)
        _PLAN_CACHE[key] = plan
    else:
        from repro.obs import trace as _obs
        if _obs.enabled():
            from repro.obs import metrics as _m
            _m.counter("planner.plan_cache_hits").inc()
    return plan


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


# ---------------------------------------------------------------------------
# autotuner: probe every registered backend, sweep the parameter space,
# fit the constants, persist the winning profile
# ---------------------------------------------------------------------------

def _time_ns(fn, reps: int = 3) -> float:
    fn()  # compile / warm up
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e9


def _probe_registered(x, sel_k: int, reps: int,
                      include_pallas: bool) -> Dict[str, float]:
    """One warm sort (and top-k, where supported) probe per *registered*
    auto-dispatchable backend at the calibration shape -> {probe: ns}.

    This is the raw measurement table a persisted profile carries
    (``TuningProfile.probe_ns``): third-party backends registered via
    ``@register_backend`` are probed too, so the profile stays an audit
    of the whole registry, not just the built-in constant fit.
    """
    n = x.shape[-1]
    vmem_only = () if include_pallas else ("pallas", "radix")
    table: Dict[str, float] = {}
    for name, be in sortspec.registered_backends().items():
        caps = be.capabilities
        if not caps.auto_dispatch or name in vmem_only:
            continue
        try:
            if caps.supports_sort:
                f = jax.jit(lambda v, b=be: b.sort(v))
                table[f"{name}.sort.n{n}"] = _time_ns(
                    lambda: jax.block_until_ready(f(x)), reps)
            if caps.supports_topk and sel_k <= n:
                f = jax.jit(lambda v, b=be: b.topk(v, sel_k)[0])
                table[f"{name}.topk.n{n}.k{sel_k}"] = _time_ns(
                    lambda: jax.block_until_ready(f(x)), reps)
        except Exception:       # a broken third-party backend must not
            continue            # sink the whole calibration
    return table


def _sweep_digit_bits(x, reps: int) -> Tuple[int, Dict[str, float]]:
    """Time the LSD radix kernel at each candidate digit width and return
    the fastest.  Wider digits mean fewer passes but a (1 << digit_bits)
    times larger one-hot histogram tensor per tile — the classic radix
    trade the paper makes at the CAS level with its bit-serial W."""
    from repro.core import keycodec
    from repro.kernels import radix_sort as _rs
    enc = keycodec.encode(x, descending=False)
    table: Dict[str, float] = {}
    for db in (4, 8):
        f = jax.jit(lambda v, d=db: _rs.sort_blocks(v, digit_bits=d))
        table[f"digit_bits={db}"] = _time_ns(
            lambda: jax.block_until_ready(f(enc)), reps)
    best = min((4, 8), key=lambda d: table[f"digit_bits={d}"])
    return best, table


def _sweep_radix_tile(x, digit_bits: int, reps: int
                      ) -> Tuple[int, Dict[str, float]]:
    """Time the LSD radix kernel at each candidate histogram tile and
    return the fastest.  Bigger tiles amortise grid launch overhead but
    grow the per-tile one-hot histogram tensor (tile x (1 << digit_bits))
    a VMEM partition has to hold — the same partition-size trade §II-B
    makes when it splits the macro into N/2 CAS blocks."""
    from repro.core import keycodec
    from repro.kernels import radix_sort as _rs
    enc = keycodec.encode(x, descending=False)
    grid = tuple(t for t in (128, 256, 512) if t <= enc.shape[-1])
    if not grid:
        return _tuning.DEFAULT_RADIX_TILE, {}
    table: Dict[str, float] = {}
    for t in grid:
        f = jax.jit(lambda v, t=t: _rs.sort_blocks(
            v, tile=t, digit_bits=digit_bits))
        table[f"radix_tile={t}"] = _time_ns(
            lambda: jax.block_until_ready(f(enc)), reps)
    best = min(grid, key=lambda t: table[f"radix_tile={t}"])
    return best, table


def _sweep_merge_fanin(tile_n: int, reps: int
                       ) -> Tuple[int, Dict[str, float]]:
    """Time the spill tier's grouped merge tournament at each candidate
    width over 16 chunk-sized runs and return the fastest.

    A wide tournament merges everything in one round but pads every run
    to a power-of-two level count; narrow rounds launch more merges and
    move the data log_f(R) times.  The crossover is a device property
    (launch overhead vs bandwidth), so it is measured here and consumed
    by ``spill._merge_phase`` via the profile's ``merge_fanin``."""
    import numpy as np
    from repro.engine import merge as _merge
    rng = np.random.default_rng(3)
    n_runs = 16
    runs = [jnp.asarray(np.sort(rng.standard_normal(tile_n)
                                .astype(np.float32)))
            for _ in range(n_runs)]
    vals = [jnp.arange(tile_n, dtype=jnp.int32) for _ in range(n_runs)]
    from repro.engine.spill import _grouped_kway_kv
    table: Dict[str, float] = {}
    grid = (2, 4, 8, 16)
    for fanin in grid:
        def run(f=fanin):
            mk, mv = _grouped_kway_kv(list(runs), list(vals), f,
                                      descending=False, interpret=None)
            jax.block_until_ready((mk, mv))
        table[f"merge_fanin={fanin}"] = _time_ns(run, reps)
    best = min(grid, key=lambda f: table[f"merge_fanin={f}"])
    return best, table


def _sweep_run_len(tile_n: int, batch: int, reps: int
                   ) -> Tuple[Optional[int], Dict[str, float]]:
    """Time the full engine pipeline (run generation + merge tree) over a
    run-length grid at an 8-tile probe size and return the fastest.

    Longer runs trade cheap vectorised tile-sort work for fewer
    gather-bound merge levels; the crossover is a property of the
    substrate (the reason the old hardcoded TPU/CPU split existed) and
    this measures it instead of guessing it."""
    import numpy as np
    from repro.engine import merge as _merge
    n_probe = 8 * tile_n
    rows = max(1, batch // 8)
    v = jnp.asarray(
        np.random.default_rng(1).standard_normal((rows, n_probe)),
        jnp.float32)
    grid = sorted({rl for rl in (tile_n // 2, tile_n, 2 * tile_n,
                                 4 * tile_n)
                   if 256 <= rl <= n_probe // 2})
    if not grid:
        return None, {}
    table: Dict[str, float] = {}
    for rl in grid:
        f = jax.jit(lambda w, r=rl: _merge.merge_runs(
            _runs.generate_runs(w, r, method="xla"), backend="xla"))
        table[f"run_len={rl}"] = _time_ns(
            lambda: jax.block_until_ready(f(v)), reps)
    best = min(grid, key=lambda r: table[f"run_len={r}"])
    return best, table


def _sweep_capacity_slack(reps: int) -> Tuple[Optional[float],
                                              Dict[str, float]]:
    """Time the distributed sample-sort at each candidate bucket-capacity
    slack (multi-device only — with one device there is no exchange to
    size).  Slack > 1 pads the measured bucket maximum so near-identical
    workloads reuse one compiled phase-2 program; the sweep measures
    whether the larger exchange buys back its cost in recompiles."""
    import numpy as np
    devs = jax.devices()
    if len(devs) < 2:
        return None, {}
    from jax.sharding import Mesh
    from repro.engine.samplesort import sample_sort
    n_dev = len(devs)
    mesh = Mesh(np.array(devs), ("data",))
    x = jnp.asarray(np.random.default_rng(2).standard_normal(1024 * n_dev),
                    jnp.float32)
    table: Dict[str, float] = {}
    for slack in (1.0, 1.25, 1.5):
        try:
            ns = _time_ns(lambda s=slack: jax.block_until_ready(
                sample_sort(x, mesh, "data", capacity_slack=s)), reps)
        except Exception:
            continue
        table[f"capacity_slack={slack}"] = ns
    if not table:
        return None, {}
    best = min(table, key=table.__getitem__)
    return float(best.split("=")[1]), table


def _fit_select_min_n(consts: cost_model.DeviceSortConstants,
                      digit_bits: int, tile: int) -> int:
    """Analytic switch-over: the smallest power-of-two n at which the
    *measured* selection constant beats the cheapest non-selection top-k
    path (k=64, f32).  Below it, auto never dispatches a selection
    engine — the counting passes cannot amortise."""
    k = 64
    for exp in range(6, 21):
        n = 1 << exp
        if n <= k:
            continue
        sel = cost_model.selection_cost_ns(
            n, k, 32, consts=consts, digit_bits=digit_bits, tile=tile)
        alt = cost_model.device_sort_cost_ns("xla", n, consts=consts)
        if not on_tpu():
            alt = min(alt, cost_model.xla_topk_cost_ns(n, k, consts=consts))
        if sel < alt:
            return n
    return _tuning.DEFAULT_SELECT_MIN_N


def calibrate(tile_n: int = 2048, batch: int = 64, reps: int = 3, *,
              include_pallas: Optional[bool] = None,
              sweep_params: bool = True,
              persist: bool = False,
              path=None) -> _tuning.TuningProfile:
    """Autotune this device: probe every registered backend, sweep the
    kernel parameter space, fit the per-element constants, install (and
    optionally persist) the winning :class:`~repro.core.tuning.TuningProfile`.

    Stages:

      1. **probe** — one warm timing per registered auto-dispatchable
         backend (sort + top-k) at the calibration shape; the raw table
         rides the profile as ``probe_ns``.
      2. **fit** — rescale the analytic leading constants (xla, bitonic,
         merge, radix, select, native top-k) to the measurements, exactly
         the closed-form inversion the paper does from Table I/II to ns.
      3. **sweep** (``sweep_params=True``) — measure the discrete knobs:
         radix ``digit_bits`` in {4, 8} and the histogram ``radix_tile``
         in {128, 256, 512} (kernel paths only), the engine ``run_len``
         grid, the spill tier's ``merge_fanin`` tournament width in
         {2, 4, 8, 16}, and the sample-sort ``capacity_slack`` (multi-
         device only); fit the selection switch-over from the measured
         constants.  Every sweep's raw timing table rides the profile's
         ``sweeps`` audit dict.
      4. **install** — ``tuning.set_active`` swaps the profile in (every
         cached plan dies via the generation counter); ``persist=True``
         writes the schema-versioned JSON (``path`` or the profile cache)
         so the *next* process starts from measurements, not guesses.

    The Pallas probes (whole-array bitonic AND the radix kernel) only run
    on a real TPU by default: interpret-mode timings say nothing about
    kernel speed (the analytic constant plus the interpret penalty already
    prices those paths) and a single interpreted tile sort can take minutes
    on CPU.
    """
    import numpy as np
    from repro.engine import merge as _merge
    if include_pallas is None:
        include_pallas = on_tpu()
    be = sortspec.get_backend
    x = jnp.asarray(np.random.default_rng(0).standard_normal((batch, tile_n)),
                    jnp.float32)
    elems = batch * tile_n
    lg = cost_model._log2(tile_n)

    xla_f = jax.jit(lambda v: be("xla").sort(v))
    bit_f = jax.jit(lambda v: be("bitonic").sort(v))
    half = tile_n // 2
    mrg_f = jax.jit(lambda v: _merge.merge_pairs(
        jnp.sort(v[:, :half]), jnp.sort(v[:, half:]), backend="xla"))

    xla_ns = _time_ns(lambda: xla_f(x).block_until_ready(), reps)
    bit_ns = _time_ns(lambda: bit_f(x).block_until_ready(), reps)
    mrg_ns = _time_ns(lambda: mrg_f(x).block_until_ready(), reps)

    # parameter sweeps run BEFORE the constant fit so the radix/select
    # constants are normalised by the pass count the tuned digit width
    # actually implies
    defaults = _tuning.default_profile()
    digit_bits, tile = defaults.digit_bits, defaults.radix_tile
    run_len, slack = defaults.run_len, defaults.capacity_slack
    merge_fanin = defaults.merge_fanin
    sweeps: Dict[str, Dict[str, float]] = {}
    if sweep_params:
        if include_pallas:
            digit_bits, tbl = _sweep_digit_bits(x, reps)
            sweeps["digit_bits"] = tbl
            tile, tbl = _sweep_radix_tile(x, digit_bits, reps)
            if tbl:
                sweeps["radix_tile"] = tbl
        rl, tbl = _sweep_run_len(tile_n, batch, reps)
        if rl is not None:
            run_len, sweeps["run_len"] = rl, tbl
        merge_fanin, tbl = _sweep_merge_fanin(tile_n, reps)
        sweeps["merge_fanin"] = tbl
        sl, tbl = _sweep_capacity_slack(reps)
        if sl is not None:
            slack, sweeps["capacity_slack"] = sl, tbl

    # selection probe: runs everywhere (off-TPU the select uses its jnp
    # histogram path, so the timing is honest without a real TPU)
    from repro.core import keycodec as _kc
    sel_k = min(64, tile_n)
    sel_f = jax.jit(lambda v: be("select").topk(v, sel_k)[0])
    sel_ns = _time_ns(lambda: sel_f(x).block_until_ready(), reps)
    sel_passes = -(-_kc.key_bits(x.dtype) // digit_bits)
    # strip the modeled O(k log k) ordering term with the constant this
    # same calibration will price it at (the measured xla one, not the
    # default — selection_cost_ns re-adds the term using the measured
    # constants); floor at 10% of the measurement so a noisy probe can
    # never produce a free selection
    sel_kterm = (xla_ns / (elems * lg)) * batch \
        * sel_k * cost_model._log2(sel_k)
    sel_c = max(sel_ns - sel_kterm, 0.1 * sel_ns) / (elems * sel_passes)

    # native top-k probe (same shapes): off-TPU this is XLA:CPU's tuned
    # selection and the measured constant keeps the k-aware plan honest;
    # on TPU the xla backend prices top-k at sort-prefix, so the probe is
    # only bookkeeping there (same 10% floor logic as the select probe)
    xtk_f = jax.jit(lambda v: be("xla").topk(v, sel_k)[0])
    xtk_ns = _time_ns(lambda: xtk_f(x).block_until_ready(), reps)
    xtk_c = max(xtk_ns - sel_kterm, 0.1 * xtk_ns) / elems

    dc = defaults.constants
    pal_c, rad_c = dc.pallas, dc.radix
    if include_pallas:
        from repro.core import keycodec
        from repro.kernels import radix_sort as _rs
        pal_f = jax.jit(lambda v: be("pallas").sort(v))
        pal_ns = _time_ns(lambda: pal_f(x).block_until_ready(), reps)
        pal_c = pal_ns / (elems * lg * lg)
        rad_f = jax.jit(lambda v: _rs.sort_blocks(
            keycodec.encode(v, descending=False), digit_bits=digit_bits))
        rad_ns = _time_ns(lambda: rad_f(x).block_until_ready(), reps)
        passes = -(-keycodec.key_bits(x.dtype) // digit_bits)
        rad_c = rad_ns / (elems * passes)
        if not on_tpu():  # fold into (constant x penalty) form
            pal_c /= dc.pallas_interpret_penalty
            rad_c /= dc.pallas_interpret_penalty
    consts = cost_model.DeviceSortConstants(
        xla=xla_ns / (elems * lg),
        bitonic=bit_ns / (elems * lg * lg),
        pallas=pal_c,
        radix=rad_c,
        select=sel_c,
        xla_topk=xtk_c,
        merge_run=xla_ns / (elems * lg),
        merge_level=mrg_ns / elems,
    )
    select_min_n = _fit_select_min_n(consts, digit_bits, tile) \
        if sweep_params else defaults.select_min_n

    probe_ns = _probe_registered(x, sel_k, reps, include_pallas)
    probe_ns.update({"xla.merge_pairs": mrg_ns})

    profile = _tuning.TuningProfile(
        fingerprint=_tuning.device_fingerprint(),
        constants=consts,
        digit_bits=digit_bits,
        radix_tile=tile,
        run_len=run_len,
        capacity_slack=slack,
        select_min_n=select_min_n,
        merge_fanin=merge_fanin,
        source="calibrated",
        probe_ns=probe_ns,
        sweeps=sweeps or None,
    )
    if persist:
        _tuning.save(profile, path)
    _tuning.set_active(profile)
    clear_plan_cache()
    return profile


def reset_calibration() -> None:
    """Back to the built-in per-platform defaults (and re-plan): the
    inverse of ``calibrate``, ignoring any persisted profile."""
    _tuning.set_active(_tuning.default_profile())
    clear_plan_cache()
