"""Cost-model dispatch — picks the sorting backend from (n, batch, dtype).

Extends the paper-constant cost model (core/cost_model.py) with per-tile
constants that can be *measured* on the running backend, then prices every
eligible software backend and returns the cheapest as an executable plan.
``sort_api.sort(..., method="auto")`` is a thin wrapper over this module.

Hard validity rules come first — auto must never pick a backend that errors:

  * ``imc`` is never auto-selected (bit-serial validation backend).
  * ``bitonic`` / ``pallas`` whole-array paths are capped at sizes where the
    power-of-two padded row still fits a sane VMEM tile.
  * ``merge`` requires more than one run (vs the *resolved* run length);
    below that it degenerates anyway.
  * ``radix`` requires a keycodec-encodable dtype ({u,i}{8,16,32}, f16,
    bf16, f32); its pass count is priced from the encoded key width.
  * unknown / exotic dtypes fall back to ``xla`` unconditionally.

Only then does the cost model arbitrate among the survivors.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.engine import runs as _runs

# whole-array network caps: beyond these the padded row stops being a
# reasonable VMEM-resident tile and the hierarchy should take over
MAX_BITONIC_N = 1 << 14
MAX_PALLAS_N = 1 << 16

# default engine tile size per substrate: on TPU a run is one VMEM tile; on
# CPU larger runs trade (cheap, vectorised) tile-sort work for (expensive,
# gather-bound) merge levels — 8K is the measured sweet spot for jnp tiles
CPU_RUN_LEN = 8192

# dtypes every backend's min/max compare handles (NaN-free floats assumed)
_COMPARABLE = {"float32", "bfloat16", "float16", "int32", "uint32",
               "int16", "uint16", "int8", "uint8"}

_measured: Optional[cost_model.DeviceSortConstants] = None


@dataclasses.dataclass(frozen=True)
class Plan:
    """Executable dispatch decision for one (n, batch, dtype) workload."""
    method: str                  # "xla" | "bitonic" | "pallas" | "merge" | "radix"
    run_len: int                 # engine tile size (merge method only)
    run_method: str              # backend sorting each run
    merge_backend: str           # "xla" | "pallas" merge primitive
    costs: Dict[str, float]      # estimated ns per candidate


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def constants() -> cost_model.DeviceSortConstants:
    return _measured or cost_model.DeviceSortConstants()


def _eligible(method: str, n: int, dtype, run_len: int) -> bool:
    if jnp.dtype(dtype).name not in _COMPARABLE:
        return method == "xla"
    if method == "bitonic":
        return _runs.next_pow2(n) <= MAX_BITONIC_N
    if method == "pallas":
        return _runs.next_pow2(n) <= MAX_PALLAS_N
    if method == "merge":
        # a single run degenerates to "sort one tile and merge nothing":
        # compare against the run length the plan will actually use, not
        # the module default (8K on CPU vs the 2K default)
        return n > run_len
    if method == "radix":
        from repro.core import keycodec
        return keycodec.supports(dtype)
    return method == "xla"


def choose(n: int, batch: int = 1, dtype=jnp.float32, *,
           requested: str = "auto",
           run_len: Optional[int] = None) -> Plan:
    """Resolve ``requested`` ("auto" or a concrete method) into a Plan."""
    rl = run_len or (_runs.DEFAULT_RUN_LEN if on_tpu() else CPU_RUN_LEN)
    consts = constants()
    interp = not on_tpu()
    from repro.core import keycodec
    kb = keycodec.key_bits(dtype) if keycodec.supports(dtype) else 32
    costs = {
        m: cost_model.device_sort_cost_ns(
            m, n, batch, run_len=rl, consts=consts, pallas_interpreted=interp,
            key_bits=kb)
        for m in ("xla", "bitonic", "pallas", "merge", "radix")
    }
    if requested == "auto":
        candidates = [m for m in costs if _eligible(m, n, dtype, rl)]
        method = min(candidates, key=costs.__getitem__)
    else:
        method = requested
    run_method = "pallas" if (on_tpu() and _eligible("pallas", rl, dtype, rl)) \
        else "xla"
    merge_backend = "pallas" if on_tpu() else "xla"
    return Plan(method=method, run_len=rl, run_method=run_method,
                merge_backend=merge_backend, costs=costs)


def choose_method(n: int, batch: int = 1, dtype=jnp.float32) -> str:
    """Just the backend name — what sort_api's "auto" resolves to."""
    return choose(n, batch, dtype).method


# ---------------------------------------------------------------------------
# measured per-tile constants
# ---------------------------------------------------------------------------

def _time_ns(fn, reps: int = 3) -> float:
    fn()  # compile / warm up
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e9


def calibrate(tile_n: int = 2048, batch: int = 64, reps: int = 3, *,
              include_pallas: Optional[bool] = None
              ) -> cost_model.DeviceSortConstants:
    """Measure per-tile constants on the live backend and cache them.

    Times one VMEM-tile-sized probe per backend plus one merge level, and
    rescales the analytic constants so subsequent ``choose`` calls price
    backends with numbers observed on this machine.  Optional: the defaults
    are good enough for dispatch ordering; calibration sharpens crossover
    points.

    The Pallas probes (the whole-array bitonic AND the radix kernel) only
    run on a real TPU by default: interpret-mode timings say nothing about
    kernel speed (the analytic constant plus the interpret penalty already
    prices those paths) and a single interpreted tile sort can take minutes
    on CPU.
    """
    global _measured
    import numpy as np
    from repro.core import sort_api
    from repro.engine import merge as _merge
    if include_pallas is None:
        include_pallas = on_tpu()
    x = jnp.asarray(np.random.default_rng(0).standard_normal((batch, tile_n)),
                    jnp.float32)
    elems = batch * tile_n
    lg = cost_model._log2(tile_n)

    xla_f = jax.jit(lambda v: sort_api.sort(v, method="xla"))
    bit_f = jax.jit(lambda v: sort_api.sort(v, method="bitonic"))
    half = tile_n // 2
    mrg_f = jax.jit(lambda v: _merge.merge_pairs(
        jnp.sort(v[:, :half]), jnp.sort(v[:, half:]), backend="xla"))

    xla_ns = _time_ns(lambda: xla_f(x).block_until_ready(), reps)
    bit_ns = _time_ns(lambda: bit_f(x).block_until_ready(), reps)
    mrg_ns = _time_ns(lambda: mrg_f(x).block_until_ready(), reps)

    defaults = cost_model.DeviceSortConstants()
    pal_c, rad_c = defaults.pallas, defaults.radix
    if include_pallas:
        from repro.core import keycodec
        from repro.kernels import radix_sort as _rs
        pal_f = jax.jit(lambda v: sort_api.sort(v, method="pallas"))
        pal_ns = _time_ns(lambda: pal_f(x).block_until_ready(), reps)
        pal_c = pal_ns / (elems * lg * lg)
        rad_f = jax.jit(lambda v: sort_api.sort(v, method="radix"))
        rad_ns = _time_ns(lambda: rad_f(x).block_until_ready(), reps)
        passes = -(-keycodec.key_bits(x.dtype) // _rs.DIGIT_BITS)
        rad_c = rad_ns / (elems * passes)
        if not on_tpu():  # fold into (constant x penalty) form
            pal_c /= defaults.pallas_interpret_penalty
            rad_c /= defaults.pallas_interpret_penalty
    _measured = cost_model.DeviceSortConstants(
        xla=xla_ns / (elems * lg),
        bitonic=bit_ns / (elems * lg * lg),
        pallas=pal_c,
        radix=rad_c,
        merge_run=xla_ns / (elems * lg),
        merge_level=mrg_ns / elems,
    )
    return _measured


def reset_calibration() -> None:
    global _measured
    _measured = None
