"""Collectives facade — the one home for the distributed sort's exchanges.

Every mesh collective the sorting stack issues goes through here, so the
movement accounting the paper centres on (Eq. 3-4: operand exchange priced
per link crossed) has a single chokepoint:

  * :func:`all_to_all` — the tiled bucket exchange, over one mesh axis or
    a tuple of axes (the flat degenerate case of the hierarchy).
  * :func:`chunked_all_to_all` — the same exchange split into ``chunks``
    independent collectives over contiguous slices of each bucket.  Each
    slice of a sorted run is itself sorted, so the consumer merges
    ``D * chunks`` shorter runs instead of ``D`` long ones — the merge
    tree's first levels depend only on the first chunk, which lets the
    scheduler overlap the remaining (slow-tier DCN) transfers with local
    merge work instead of serialising transfer-then-merge.
  * the **int8 wire codec** — opt-in lossy compression of float *payload*
    buckets on the slow tier, reusing ``optim/grad_compress``'s scheme
    (per-bucket absmax scale, round-to-nearest int8).  Keys are never
    compressed: the sort order must stay bit-exact; only the payload the
    caller explicitly marked compressible rides the narrow format.
  * :func:`record_exchange` — per-tier byte counters
    (``collectives.ici_bytes`` / ``collectives.dcn_bytes``) so the obs
    subsystem sees how much traffic each tier of the topology carried.

The first three run inside jitted ``shard_map`` programs; the counters are
host-side (obs is zero-overhead when disabled, and counters cannot tick
inside a trace anyway) — callers record the analytic volume next to the
program launch, exactly like ``samplesort.alltoall_bytes``.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.obs import metrics, trace as _obs

__all__ = [
    "AxisName", "all_to_all", "chunked_all_to_all", "pipeline_chunks",
    "wire_encode_int8", "wire_decode_int8", "wire_bytes_saved",
    "record_exchange", "DEFAULT_PIPELINE_CHUNKS", "WIRE_CODECS",
]

AxisName = Union[str, Tuple[str, ...]]

# how many slices the slow-tier bucket exchange is split into by default:
# enough that the first merge levels start ~1/4 of the way into the
# transfer, few enough that per-collective launch overhead stays noise
DEFAULT_PIPELINE_CHUNKS = 4

WIRE_CODECS = ("int8",)


def _axis_arg(axis_name: AxisName):
    """lax collectives accept a name or a tuple; normalise singleton
    tuples back to the bare name for maximum version compatibility."""
    if isinstance(axis_name, tuple) and len(axis_name) == 1:
        return axis_name[0]
    return axis_name


def all_to_all(v: jnp.ndarray, axis_name: AxisName) -> jnp.ndarray:
    """(D, ...) -> (D, ...): row j of the result is what device j held in
    row ``my`` — the single bucket-exchange collective.  ``axis_name`` may
    be a tuple of mesh axes; the device order is then row-major over the
    tuple (outer axis major), matching the linear device index the
    sample-sort phases shard by."""
    return jax.lax.all_to_all(v, _axis_arg(axis_name), split_axis=0,
                              concat_axis=0, tiled=True)


def pipeline_chunks(capacity: int, requested: Optional[int] = None) -> int:
    """The realizable chunk count for a bucket of ``capacity`` slots: the
    largest power of two <= ``requested`` that divides the capacity (a
    chunk must be a whole slice of every bucket).  Odd capacities pipeline
    at 1 — correctness never depends on the split."""
    req = DEFAULT_PIPELINE_CHUNKS if requested is None else requested
    req = max(1, req)
    chunks = 1
    while chunks * 2 <= min(req, capacity) and capacity % (chunks * 2) == 0:
        chunks *= 2
    return chunks


def chunked_all_to_all(v: jnp.ndarray, axis_name: AxisName, *,
                       chunks: int = 1) -> jnp.ndarray:
    """(D, c) -> (D, chunks, c // chunks): the bucket exchange issued as
    ``chunks`` independent collectives over contiguous bucket slices.

    ``out[j, i]`` is slice ``i`` of the bucket device ``j`` sent here; a
    contiguous slice of a sorted bucket is itself sorted, so the receiver
    treats the result as ``D * chunks`` sorted runs.  Splitting the
    exchange is what buys transfer/merge overlap on the slow tier — the
    merge tree's early levels consume chunk 0 while later chunks are
    still in flight (on a single-stream backend the chunks simply run
    back-to-back; the result is identical either way).
    """
    d, c = v.shape
    if chunks <= 1:
        return all_to_all(v, axis_name)[:, None, :]
    if c % chunks:
        raise ValueError(
            f"bucket capacity {c} is not divisible by chunks={chunks} "
            f"(use pipeline_chunks to pick a realizable count)")
    pieces = v.reshape(d, chunks, c // chunks)
    outs = [all_to_all(pieces[:, i, :], axis_name) for i in range(chunks)]
    return jnp.stack(outs, axis=1)


# ---------------------------------------------------------------------------
# int8 wire codec (grad_compress's scheme, applied to exchange buckets)
# ---------------------------------------------------------------------------

def wire_encode_int8(v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(D, c) float buckets -> (int8 buckets, (D, 1) f32 scales).

    Per-bucket absmax scaling with round-to-nearest — the exact scheme
    ``optim/grad_compress`` ships for momentum tensors.  Lossy: only the
    payload side of a key-value exchange may ride this, and only when the
    caller opted in (``wire_codec="int8"``); keys always travel wide.
    """
    a = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = a / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(v.astype(jnp.float32) / safe), -127, 127)
    return q.astype(jnp.int8), safe.astype(jnp.float32)


def wire_decode_int8(q: jnp.ndarray, scale: jnp.ndarray,
                     dtype) -> jnp.ndarray:
    """Inverse of :func:`wire_encode_int8` (up to quantisation error)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def wire_bytes_saved(n_dev: int, capacity: int, itemsize: int) -> int:
    """Bytes the int8 codec keeps off the wire for one payload exchange:
    each slot shrinks to 1 byte + a 4-byte per-bucket scale."""
    wide = n_dev * capacity * itemsize
    narrow = n_dev * capacity * 1 + n_dev * 4
    return max(0, wide - narrow)


# ---------------------------------------------------------------------------
# per-tier movement accounting (host-side; obs no-ops when disabled)
# ---------------------------------------------------------------------------

def record_exchange(tier: str, nbytes: int) -> None:
    """Count ``nbytes`` of collective traffic against a topology tier.
    Callers pass the analytic per-round volume (they know capacity and
    fan-out); the counter names are stable obs API:
    ``collectives.ici_bytes`` / ``collectives.dcn_bytes``."""
    if not _obs.enabled() or nbytes <= 0:
        return
    metrics.counter(f"collectives.{tier}_bytes").inc(int(nbytes))


def record_split_exchange(nbytes: int, inner: int, outer: int) -> None:
    """Account one FLAT exchange over an ``outer x inner`` two-tier mesh:
    with destinations uniform over the mesh, ``(outer-1)/outer`` of the
    traffic crosses DCN and the rest stays on ICI (the same split
    ``cost_model.flat_collective_rates`` prices)."""
    if outer <= 1:
        record_exchange("ici", nbytes)
        return
    f_dcn = (outer - 1) / outer
    record_exchange("dcn", int(nbytes * f_dcn))
    record_exchange("ici", int(nbytes * (1.0 - f_dcn)))


def axis_sizes(mesh, axes: Sequence[str]) -> Tuple[int, ...]:
    """Mesh axis sizes in the given order (validating membership)."""
    for a in axes:
        if a not in mesh.axis_names:
            raise ValueError(f"axis {a!r} not in mesh axes "
                             f"{tuple(mesh.axis_names)}")
    return tuple(int(mesh.shape[a]) for a in axes)
