#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the default-marker test suite.
# Extra args are passed straight to pytest, e.g.  scripts/tier1.sh -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# --durations=10 surfaces the suite's hot spots (it runs ~9 min on CPU CI)
exec python -m pytest -x -q --durations=10 "$@"
