#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the default-marker test suite.
# Extra args are passed straight to pytest, e.g.  scripts/tier1.sh -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# lint first (cheap, config in ruff.toml); CI runs the same check as its
# own job, so keep local and CI gates identical
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "[tier1] ruff not installed; skipping lint (CI still runs it)" >&2
fi
# TIER1_MULTIDEV=<D> runs the distributed-sort suites on D simulated
# host-platform devices instead of the full single-device suite — the CI
# multi-device job sets TIER1_MULTIDEV=8 so every push exercises the
# sample-sort / odd-even paths at real D>1, not just the degenerate D=1,
# and (at D>=8) the two-level hierarchical schedule on a real 2x4
# (hosts x devices) grid, fuzz lens included.
if [[ -n "${TIER1_MULTIDEV:-}" ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=${TIER1_MULTIDEV} ${XLA_FLAGS:-}"
  exec python -m pytest -x -q --durations=10 \
    tests/test_distributed_sort.py tests/test_samplesort.py \
    tests/test_hierarchical_sort.py tests/test_topology.py \
    tests/test_distributed_topk.py tests/test_relational_distributed.py \
    "tests/test_fuzz_conformance.py::test_fuzz_hier_sample_sort_matches_flat_and_jnp" \
    "$@"
fi
# TIER1_SPILL=1 runs the out-of-core spill tier by itself: the spill unit
# suite, the data-pipeline dedup consumers, the spill fuzz lenses, and the
# suite-wide slow-marked cases (the spill job is CI's home for `-m slow`
# coverage, so the deselected-by-default tests still run on every push).
if [[ -n "${TIER1_SPILL:-}" ]]; then
  python -m pytest -x -q --durations=10 \
    tests/test_spill.py tests/test_data.py \
    "tests/test_fuzz_conformance.py::test_fuzz_spill_sort_matches_jnp" \
    "tests/test_fuzz_conformance.py::test_fuzz_spill_argsort_is_stable" \
    "$@"
  exec python -m pytest -x -q --durations=10 -m slow "$@"
fi
# TIER1_BENCH=1 appends the perf-trajectory leg after the suite: emit a
# fresh bench document on the quick probe grid, then enforce the
# auto-within-factor-of-best invariant (scripts/bench_gate.py) and, when
# the committed baseline exists, the no-drift-vs-baseline bound.  Pass
# TIER1_BENCH_ARGS for extra gate flags (e.g. "--warn-only" on noisy CI).
if [[ -n "${TIER1_BENCH:-}" ]]; then
  python -m pytest -x -q --durations=10 "$@"
  echo "[tier1] bench leg: emitting benchmarks/BENCH_sort.ci.json"
  python -m benchmarks.emit_bench --quick --out benchmarks/BENCH_sort.ci.json
  baseline_args=()
  if [[ -f benchmarks/BENCH_sort.json ]]; then
    baseline_args=(--baseline benchmarks/BENCH_sort.json)
  fi
  # shellcheck disable=SC2086
  python scripts/bench_gate.py benchmarks/BENCH_sort.ci.json \
    "${baseline_args[@]}" ${TIER1_BENCH_ARGS:-}
  exit 0
fi
# TIER1_TUNE=1 appends the autotuner leg: run a tiny-grid calibrate() that
# probes this machine, persists the winning tuning profile, and validates
# the emitted JSON (schema + device fingerprint) via --check.  The profile
# lands in a throwaway dir so the run never pollutes the user's cache.
if [[ -n "${TIER1_TUNE:-}" ]]; then
  python -m pytest -x -q --durations=10 "$@"
  tunedir="$(mktemp -d)"
  trap 'rm -rf "$tunedir"' EXIT
  echo "[tier1] tune leg: calibrating into $tunedir/profile.json"
  python scripts/autotune.py --tile-n 512 --batch 8 --reps 1 \
    --out "$tunedir/profile.json" --check
  exit 0
fi
# --durations=10 surfaces the suite's hot spots (it runs ~9 min on CPU CI)
exec python -m pytest -x -q --durations=10 "$@"
