#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the default-marker test suite.
# Extra args are passed straight to pytest, e.g.  scripts/tier1.sh -m slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# lint first (cheap, config in ruff.toml); CI runs the same check as its
# own job, so keep local and CI gates identical
if command -v ruff >/dev/null 2>&1; then
  ruff check .
else
  echo "[tier1] ruff not installed; skipping lint (CI still runs it)" >&2
fi
# --durations=10 surfaces the suite's hot spots (it runs ~9 min on CPU CI)
exec python -m pytest -x -q --durations=10 "$@"
