"""Calibrate this device's tuning profile and persist it.

Thin CLI over ``repro.engine.planner.calibrate(persist=True)``: probes every
registered backend, sweeps the discrete kernel knobs (radix digit width, run
length, sample-sort capacity slack), fits the per-device cost constants, and
writes the winning ``repro.core.tuning`` profile as JSON.

  PYTHONPATH=src python scripts/autotune.py                    # default grid
  ... --tile-n 512 --batch 8 --reps 1                          # tiny CI grid
  ... --out /tmp/profile.json --check                          # validate it
  ... --no-sweeps                                              # constants only

Without ``--out`` the profile lands in the default search path
(``$REPRO_TUNING_DIR``, else ``~/.cache/repro/profiles``) where every
subsequent repro process auto-loads it.  ``--check`` reloads the emitted file
through ``tuning.load`` and verifies schema + device fingerprint, exiting
non-zero on any mismatch — the tier-1 TIER1_TUNE leg runs exactly this.
"""
from __future__ import annotations

import argparse
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tile-n", type=int, default=2048,
                    help="probe tile length (power of two)")
    ap.add_argument("--batch", type=int, default=64,
                    help="probe batch rows")
    ap.add_argument("--reps", type=int, default=3,
                    help="timing repetitions per probe")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="profile path (default: tuning search path)")
    ap.add_argument("--no-sweeps", action="store_true",
                    help="fit cost constants only, keep default knobs")
    ap.add_argument("--include-pallas", action="store_true",
                    help="probe Pallas kernels even off-TPU (interpret "
                         "mode, slow)")
    ap.add_argument("--check", action="store_true",
                    help="reload the emitted profile and verify schema + "
                         "device fingerprint")
    args = ap.parse_args(argv)

    from repro.core import tuning
    from repro.engine import planner

    prof = planner.calibrate(
        tile_n=args.tile_n, batch=args.batch, reps=args.reps,
        include_pallas=True if args.include_pallas else None,
        sweep_params=not args.no_sweeps, persist=True, path=args.out)

    path = (pathlib.Path(args.out) if args.out
            else tuning.profile_path(prof.fingerprint))
    print(f"[autotune] fingerprint   {prof.fingerprint}")
    print(f"[autotune] digit_bits    {prof.digit_bits}")
    print(f"[autotune] run_len       {prof.run_len}")
    print(f"[autotune] capacity_slack {prof.capacity_slack}")
    print(f"[autotune] select_min_n  {prof.select_min_n}")
    print(f"[autotune] wrote {path}")

    if args.check:
        try:
            loaded = tuning.load(path)
        except tuning.ProfileError as e:
            print(f"[autotune] CHECK FAILED: reload rejected: {e}",
                  file=sys.stderr)
            return 1
        if loaded.fingerprint != tuning.device_fingerprint():
            print(f"[autotune] CHECK FAILED: fingerprint "
                  f"{loaded.fingerprint!r} != device "
                  f"{tuning.device_fingerprint()!r}", file=sys.stderr)
            return 1
        if loaded.constants != prof.constants:
            print("[autotune] CHECK FAILED: constants did not round-trip",
                  file=sys.stderr)
            return 1
        print("[autotune] check OK: profile reloads with matching "
              "fingerprint and constants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
