"""Gate on BENCH_sort.json: ``auto`` must track the best measured backend.

The invariant this enforces is the whole point of the cost-model planner:
at every bench point, the latency of ``method="auto"`` stays within
``--factor`` of the best measured candidate backend.  A regression here is
a planner mispricing (the class of bug that had ``topk auto`` 90x off the
native XLA path) — the gate turns the next one into a red build instead of
a CSV archaeology project.

  PYTHONPATH=src python scripts/bench_gate.py benchmarks/BENCH_sort.json
  ... --factor 2.0       # override (env BENCH_GATE_FACTOR also works)
  ... --warn-only        # report but always exit 0 (noisy CPU CI)
  ... --baseline benchmarks/BENCH_sort.json   # trajectory diff vs commit

``--warn-only`` has one override: when the document's ``profile`` block
(schema v2) says a *persisted* tuning profile exists for this device
fingerprint, the gate hard-fails anyway — measured constants remove the
"the defaults were guesses" excuse, which is exactly the TPU-CI hard-fail
the ROADMAP called for, keyed on evidence instead of platform.

``--baseline PATH`` additionally compares each point's auto/best ratio
against the same-named point in a committed baseline document: a point
regresses when its ratio exceeds ``factor`` times the baseline's (floored
at 1.0), so the trajectory can only drift slowly even when every absolute
ratio stays under the gate.

Exit status: 0 when every point passes (or --warn-only without a pinned
profile), 1 on any violation, 2 on a malformed/missing artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

DEFAULT_FACTOR = 2.0
SCHEMAS = ("repro.bench.sort/v1", "repro.bench.sort/v2")


def _ratios(doc: dict) -> dict:
    """{point name: auto.ns / best.ns} for every measurable point."""
    out = {}
    for p in doc.get("points", []):
        auto, best = p.get("auto", {}), p.get("best", {})
        if auto.get("ns") and best.get("ns"):
            out[p.get("name")] = (auto["ns"] / best["ns"], auto, best)
    return out


def check(doc: dict, factor: float, baseline: dict = None):
    """-> (violations, checked) where each violation is a dict."""
    if doc.get("schema") not in SCHEMAS:
        raise ValueError(f"unknown schema {doc.get('schema')!r} "
                         f"(expected one of {SCHEMAS})")
    base_ratios = _ratios(baseline) if baseline is not None else {}
    violations, checked = [], 0
    for name, (ratio, auto, best) in _ratios(doc).items():
        checked += 1
        allowed, why = factor, "factor"
        if name in base_ratios:
            # trajectory bound: at most factor x the committed ratio (floored
            # at 1.0) — a point the baseline already shows as noisy is only a
            # violation when it drifts further, not for being noisy
            allowed, why = factor * max(1.0, base_ratios[name][0]), "baseline"
        if ratio > allowed:
            violations.append({
                "name": name, "ratio": ratio, "factor": allowed, "why": why,
                "auto_backend": auto.get("backend"), "auto_ns": auto["ns"],
                "best_backend": best.get("backend"), "best_ns": best["ns"]})
    return violations, checked


def profile_pinned(doc: dict) -> bool:
    """True when the run was (or should have been) planned under measured,
    persisted constants — the warn-only escape hatch closes."""
    return bool(doc.get("profile", {}).get("persisted"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", nargs="?",
                    default="benchmarks/BENCH_sort.json")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR",
                                                 DEFAULT_FACTOR)),
                    help="max allowed auto.ns / best.ns ratio")
    ap.add_argument("--warn-only", action="store_true",
                    help="report violations but exit 0 (overridden to "
                         "hard-fail when a persisted tuning profile "
                         "matches this device)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed BENCH_sort.json to diff ratios against")
    args = ap.parse_args(argv)

    path = pathlib.Path(args.artifact)
    try:
        doc = json.loads(path.read_text())
        baseline = None
        if args.baseline:
            baseline = json.loads(pathlib.Path(args.baseline).read_text())
            if baseline.get("schema") not in SCHEMAS:
                raise ValueError(
                    f"baseline has unknown schema "
                    f"{baseline.get('schema')!r}")
        violations, checked = check(doc, args.factor, baseline)
    except (OSError, ValueError) as e:
        print(f"[bench_gate] cannot check {path}: {e}", file=sys.stderr)
        return 2

    warn_only = args.warn_only
    if warn_only and profile_pinned(doc):
        print("[bench_gate] persisted tuning profile matches this device: "
              "--warn-only overridden, violations fail the build")
        warn_only = False

    for v in violations:
        print(f"[bench_gate] FAIL {v['name']}: auto({v['auto_backend']}) "
              f"{v['auto_ns']/1e3:.1f}us is {v['ratio']:.2f}x best"
              f"({v['best_backend']}) {v['best_ns']/1e3:.1f}us "
              f"(allowed {v['factor']:.2f}x, {v['why']} bound)")
    print(f"[bench_gate] {checked - len(violations)}/{checked} points "
          f"within bounds (factor {args.factor:.2f}x"
          + (", baseline diff" if args.baseline else "") + ")"
          + (" [warn-only]" if warn_only and violations else ""))
    if violations and not warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
