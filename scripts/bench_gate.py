"""Gate on BENCH_sort.json: ``auto`` must track the best measured backend.

The invariant this enforces is the whole point of the cost-model planner:
at every bench point, the latency of ``method="auto"`` stays within
``--factor`` of the best measured candidate backend.  A regression here is
a planner mispricing (the class of bug that had ``topk auto`` 90x off the
native XLA path) — the gate turns the next one into a red build instead of
a CSV archaeology project.

  PYTHONPATH=src python scripts/bench_gate.py benchmarks/BENCH_sort.json
  ... --factor 2.0       # override (env BENCH_GATE_FACTOR also works)
  ... --warn-only        # report but always exit 0 (noisy CPU CI)

Exit status: 0 when every point passes (or --warn-only), 1 on any
violation, 2 on a malformed/missing artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

DEFAULT_FACTOR = 2.0
SCHEMA = "repro.bench.sort/v1"


def check(doc: dict, factor: float):
    """-> (violations, checked) where each violation is a dict."""
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"unknown schema {doc.get('schema')!r} "
                         f"(expected {SCHEMA!r})")
    violations, checked = [], 0
    for p in doc.get("points", []):
        auto, best = p.get("auto", {}), p.get("best", {})
        if not auto.get("ns") or not best.get("ns"):
            continue
        checked += 1
        ratio = auto["ns"] / best["ns"]
        if ratio > factor:
            violations.append({
                "name": p.get("name"), "ratio": ratio, "factor": factor,
                "auto_backend": auto.get("backend"), "auto_ns": auto["ns"],
                "best_backend": best.get("backend"), "best_ns": best["ns"]})
    return violations, checked


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", nargs="?",
                    default="benchmarks/BENCH_sort.json")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR",
                                                 DEFAULT_FACTOR)),
                    help="max allowed auto.ns / best.ns ratio")
    ap.add_argument("--warn-only", action="store_true",
                    help="report violations but exit 0")
    args = ap.parse_args(argv)

    path = pathlib.Path(args.artifact)
    try:
        doc = json.loads(path.read_text())
        violations, checked = check(doc, args.factor)
    except (OSError, ValueError) as e:
        print(f"[bench_gate] cannot check {path}: {e}", file=sys.stderr)
        return 2

    for v in violations:
        print(f"[bench_gate] FAIL {v['name']}: auto({v['auto_backend']}) "
              f"{v['auto_ns']/1e3:.1f}us is {v['ratio']:.2f}x best"
              f"({v['best_backend']}) {v['best_ns']/1e3:.1f}us "
              f"(allowed {v['factor']:.2f}x)")
    print(f"[bench_gate] {checked - len(violations)}/{checked} points "
          f"within {args.factor:.2f}x of best"
          + (" [warn-only]" if args.warn_only and violations else ""))
    if violations and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
