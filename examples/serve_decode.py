"""Serving example: batched requests through the length-sorted scheduler,
top-k sampled decode via the paper's bitonic kernels.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve

done, stats = serve("gemma-2b", smoke=True, n_requests=20, batch_size=8,
                    decode_steps=24, topk=20)
for r in done[:3]:
    print(f"request {r.rid}: prompt len {len(r.prompt)}, "
          f"generated {r.out[:10].tolist()}...")
