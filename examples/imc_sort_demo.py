"""Deep-dive into the in-memory CAS block: watch the 28-cycle gate program
execute on the simulated 6T SRAM array, cycle by cycle.

Run:  PYTHONPATH=src python examples/imc_sort_demo.py [A] [B]
"""
import sys

import numpy as np
import jax.numpy as jnp

from repro.core import cas, gates, imc_array

a = int(sys.argv[1]) if len(sys.argv) > 1 else 0b1000   # paper Fig. 7: A=1000
b = int(sys.argv[2]) if len(sys.argv) > 2 else 0b0001   # paper Fig. 7: B=0001

prog = gates.build_cas_program(4)
print(f"CAS of A={a:04b} B={b:04b} on a {prog.n_rows}-row x 4-col IMC array")
print(f"phases: compare={prog.compare_cycles} mux={prog.mux_cycles} "
      f"writeback={prog.writeback_cycles}  (paper: 18/8/2)\n")

state = imc_array.make_array(1, prog.n_rows, 4)
state = imc_array.write_word(state, imc_array.ROW_A,
                             imc_array.int_to_bits(jnp.asarray([a], jnp.uint32), 4))
state = imc_array.write_word(state, imc_array.ROW_B,
                             imc_array.int_to_bits(jnp.asarray([b], jnp.uint32), 4))
counter = imc_array.CycleCounter()
for cyc, op in enumerate(prog.ops, start=1):
    state = imc_array.step(state, op, counter)
    row = np.array(state[0, op.dst].astype(np.int32))
    print(f"cycle {cyc:2d}  {op.kind.value:4s} -> row {op.dst:2d} "
          f"[{''.join(map(str, row))}]  {op.label}")

lo = int(imc_array.bits_to_int(imc_array.read_word(state, imc_array.ROW_A))[0])
hi = int(imc_array.bits_to_int(imc_array.read_word(state, imc_array.ROW_B))[0])
print(f"\nresult: min={lo:04b} (row 3, cycle 28)  max={hi:04b} (row 4, cycle 27)")
print(f"op mix: {counter.as_dict()}   paper Table I: NOR 14 NOT 8 AND 3 COPY 3")
assert (lo, hi) == (min(a, b), max(a, b))
