"""Out-of-core sort engine demo: runs, merge tree, planner, segmented sort.

Walks the memory hierarchy the engine completes — one VMEM tile to
million-element arrays — and shows the pieces the serving stack calls:

  PYTHONPATH=src python examples/engine_sort_demo.py
"""
import numpy as np
import jax.numpy as jnp

from repro import engine
from repro.engine import planner, runs, segmented

rng = np.random.default_rng(0)

print("== 1. planner: cost-model dispatch over (n, batch) ==")
for n in (256, 4096, 65536, 1 << 20):
    plan = planner.choose(n, batch=1)
    est = {k: f"{v / 1e3:.0f}us" for k, v in sorted(plan.costs.items())}
    print(f"  n={n:>8}: auto -> {plan.method:8s} estimates={est}")

print("\n== 2. million-element sort through the engine ==")
n = (1 << 20) + 12345          # deliberately non-power-of-two
x = jnp.asarray(rng.standard_normal(n), jnp.float32)
out = np.array(engine.sort(x, method="merge"))
assert (out == np.sort(np.array(x))).all()
plan = planner.choose(n, 1, requested="merge")
n_tiles, padded = runs.run_layout(n, plan.run_len)
print(f"  n={n}: {n_tiles} runs of {padded // n_tiles}, "
      f"{int(np.log2(n_tiles))} merge levels — bit-exact vs jnp.sort")

print("\n== 3. top-k at vocab scale (partition-then-merge, paper §II-B) ==")
logits = jnp.asarray(rng.standard_normal((4, 152064)), jnp.float32)
v, i = engine.topk(logits, 50, method="merge")
ref = -np.sort(-np.array(logits), -1)[:, :50]
assert (np.array(v) == ref).all()
print(f"  topk(50) over vocab 152064: ok, head {np.array(v)[0, :3].round(3)}")

print("\n== 4. segmented sort (serving length buckets / MoE groups) ==")
values = jnp.asarray(rng.standard_normal(64), jnp.float32)
seg = jnp.asarray(np.sort(rng.integers(0, 5, 64)).astype(np.int32))
sv, sseg = segmented.segmented_sort(values, seg)
sv, sseg = np.array(sv), np.array(sseg)
for s in range(5):
    grp = sv[sseg == s]
    assert (np.diff(grp) >= 0).all()
print("  5 ragged groups sorted independently in one pass: ok")

perm, splits = segmented.group_tokens_by_expert(
    jnp.asarray(rng.integers(0, 8, 256).astype(np.int32)), 8)
print(f"  MoE grouping: row_splits={np.array(splits).tolist()}")
