"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the host mesh, with checkpointing and straggler watchdog active.

This is deliberately the SAME driver the pod launch uses
(repro.launch.train) — only the config size differs.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
import repro.configs.gemma_2b as g
from repro.launch import train as train_lib

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

# ~100M params: a narrow gemma-family model (exact count printed below)
cfg = dataclasses.replace(
    g.CONFIG, name="gemma-100m", n_layers=6, d_model=512, n_heads=8,
    n_kv_heads=1, head_dim=64, d_ff=2048, vocab_size=32768, max_seq=4096)
print(f"model: {cfg.name}, ~{cfg.n_params()/1e6:.0f}M params")

# register it so the train driver can find it
import repro.configs.base as base
import sys, types
mod = types.ModuleType("repro.configs.gemma_100m")
mod.CONFIG = cfg
mod.smoke = lambda: cfg
sys.modules["repro.configs.gemma_100m"] = mod

losses = train_lib.train("gemma_100m", smoke=False, steps=args.steps,
                         batch=args.batch, seq=args.seq, lr=1e-3,
                         ckpt_dir="/tmp/repro_train_lm", ckpt_every=100)
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
assert losses[-1] < losses[0], "loss did not decrease"
