"""Quickstart: the ADS-IMC sorting stack in five minutes.

1. sort with every backend (xla / bitonic / pallas / merge / auto / imc)
2. validate the paper's headline numbers from the cost model
3. run the cycle-accurate in-memory sort and inspect its accounting

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import sort_api, cost_model
from repro.core.sorter import sort_in_memory

print("== 1. one API, seven backends ==")
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 100)),
                dtype=jnp.float32)
for method in ("xla", "bitonic", "pallas", "merge", "radix", "auto"):
    out = sort_api.sort(x, method=method)
    assert (np.diff(np.array(out), axis=-1) >= 0).all()
    print(f"  sort(method={method!r}): ok, first row head "
          f"{np.array(out)[0, :3].round(3)}")

vals, idx = sort_api.topk(x, 5, method="pallas")
print(f"  topk(5, pallas): values[0]={np.array(vals)[0].round(3)}")

big = jnp.asarray(np.random.default_rng(2).standard_normal(1 << 20),
                  dtype=jnp.float32)
out = sort_api.sort(big, method="merge")
assert (np.diff(np.array(out)) >= 0).all()
print(f"  sort(n={big.shape[0]}, method='merge'): ok "
      f"(out-of-core engine: tiled runs + merge-path tree)")

print("\n== 2. the paper's numbers, reproduced ==")
claims = cost_model.validate_claims()
for name, model, paper, tol in claims.rows[:8]:
    print(f"  {name:42s} model={model:>8} paper={paper}")
print(f"  ... all {len(claims.rows)} claims pass: {claims.all_pass()}")

print("\n== 3. faithful in-memory sort (bit-serial, cycle-accurate) ==")
v = np.random.default_rng(1).integers(0, 16, size=(2, 8))
res = sort_in_memory(v, width=4)
print(f"  input : {v.tolist()}")
print(f"  sorted: {np.array(res.values).tolist()}")
print(f"  cycles: {res.cycles} (= {res.compute_cycles} compute "
      f"+ {res.movement_cycles} movement)   [paper: 192]")
print(f"  array : {res.array_rows} rows x {res.array_cols} cols, "
      f"{res.n_partitions} partitions, {res.n_temp_rows} temp rows")
print(f"  latency: {cost_model.sort_latency_ns(8):.1f} ns  "
      f"[paper Table II: 105.6 ns]")
