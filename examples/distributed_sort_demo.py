"""The paper's memory partitioning at cluster scale: every device sorts its
shard in-VMEM, then the shards combine over the mesh — either D odd-even
bitonic merge rounds (each a temp-row operand exchange, Eq. 3-4) or the
single-round sample-sort (splitters + ONE bucket all-to-all, §II-B's
exchange-once structure).  `strategy="auto"` lets the planner's collective
cost model pick per (n, D).

Run: PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/distributed_sort_demo.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed_sort as ds
from repro.engine import planner, samplesort

mesh = jax.make_mesh((len(jax.devices()),), ("data",))
n_dev = mesh.shape["data"]
local = 4096
n = n_dev * local
x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
xs = jax.device_put(x, NamedSharding(mesh, P("data")))

plan = planner.choose_distributed(n, n_dev, xs.dtype)
out = ds.distributed_sort(xs, mesh)                  # strategy="auto"
assert np.allclose(np.array(out), np.sort(x))
print(f"globally sorted {n} elements over {n_dev} devices "
      f"(auto -> {plan.strategy}; modeled ns: "
      + ", ".join(f"{k}={v:.3g}" for k, v in sorted(plan.costs.items()))
      + ")")

oe = ds.collective_bytes_per_device(n_dev, local, 4)
ss = samplesort.alltoall_bytes_per_device(n_dev, local, 4)
print(f"ICI volume/device: odd-even {oe/1e3:.1f} kB ({n_dev} rounds x "
      f"{local*4/1e3:.1f} kB) vs sample {ss/1e3:.1f} kB "
      f"(1 bucket all-to-all + 1 rebalance)")

# the sample path also covers what odd-even cannot express: uneven length,
# descending, and a payload riding the buckets
k = np.random.default_rng(1).integers(0, 100, n - 3).astype(np.int32)
sk, sv = ds.distributed_sort(jax.numpy.asarray(k), mesh, strategy="sample",
                             descending=True,
                             values=jax.numpy.arange(n - 3))
assert (np.array(sk) == np.flip(np.sort(k))).all()
assert (k[np.array(sv)] == np.array(sk)).all()
print(f"sample-sort kv/descending/uneven: {n - 3} elements OK")
print("device order is globally ascending:",
      bool(np.all(np.diff(np.array(out)) >= 0)))
