"""The paper's memory partitioning at cluster scale: every device sorts its
shard in-VMEM, then odd-even bitonic merge rounds exchange shards over the
mesh (ppermute = the temp-row operand exchange of Eq. 3-4).

Run: PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/distributed_sort_demo.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed_sort as ds

mesh = jax.make_mesh((len(jax.devices()),), ("data",))
n_dev = mesh.shape["data"]
local = 4096
x = np.random.default_rng(0).standard_normal(n_dev * local).astype(np.float32)
xs = jax.device_put(x, NamedSharding(mesh, P("data")))
out = ds.distributed_sort(xs, mesh)
assert np.allclose(np.array(out), np.sort(x))
vol = ds.collective_bytes_per_device(n_dev, local, 4)
print(f"globally sorted {n_dev * local} elements over {n_dev} devices")
print(f"merge-phase ICI volume: {vol/1e3:.1f} kB/device "
      f"({n_dev} rounds x {local*4/1e3:.1f} kB)")
print("device order is globally ascending:",
      bool(np.all(np.diff(np.array(out)) >= 0)))
