"""core.topology: the explicit (mesh shape, per-tier link rates) value —
schema round-trip, mesh derivation, persistence search path, the active
ambient + generation counter the planner caches on, and the collectives
facade's transport helpers.  Pure host logic plus single-device jax, so
the whole file runs on the tier-1 job at any device count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.engine import collectives


def _topo2x4(dcn_slowdown: float = 10.0) -> topology.Topology:
    ici_bw, ici_lat = 5e10, 2_000.0
    return topology.Topology(
        fingerprint="test-fixture",
        axes=(
            topology.TopologyAxis(
                name="host", size=2, tier=topology.TIER_DCN,
                bandwidth_bytes_per_s=ici_bw / dcn_slowdown,
                latency_ns=ici_lat * dcn_slowdown),
            topology.TopologyAxis(
                name="dev", size=4, tier=topology.TIER_ICI,
                bandwidth_bytes_per_s=ici_bw, latency_ns=ici_lat),
        ),
        source="default")


# ---------------------------------------------------------------------------
# the value itself
# ---------------------------------------------------------------------------

def test_topology_shape_accessors():
    t = _topo2x4()
    assert t.axis_names == ("host", "dev")
    assert t.n_devices == 8
    assert t.signature() == (("host", 2), ("dev", 4))
    assert t.is_hierarchical
    assert t.axis("dev").tier == topology.TIER_ICI
    with pytest.raises(KeyError):
        t.axis("nope")


def test_topology_per_byte_ns_inverts_bandwidth():
    ax = _topo2x4(1.0).axes[1]
    assert ax.per_byte_ns == pytest.approx(1e9 / ax.bandwidth_bytes_per_s)


def test_degenerate_axes_are_not_hierarchical():
    t = topology.Topology(
        fingerprint="f",
        axes=(
            topology.TopologyAxis(name="host", size=1,
                                  tier=topology.TIER_DCN,
                                  bandwidth_bytes_per_s=1e9,
                                  latency_ns=1.0),
            topology.TopologyAxis(name="dev", size=8,
                                  tier=topology.TIER_ICI,
                                  bandwidth_bytes_per_s=1e9,
                                  latency_ns=1.0),
        ),
        source="default")
    assert not t.is_hierarchical


def test_from_mesh_tiers_and_signature():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    t = topology.from_mesh(mesh)
    assert t.signature() == (("data", n),)
    assert t.axes[0].tier == topology.TIER_ICI  # single axis: pure ICI
    with pytest.raises(topology.TopologyError):
        topology.from_mesh(mesh, ("bogus",))


def test_schema_roundtrip_and_rejects():
    t = _topo2x4()
    doc = t.to_dict()
    back = topology.Topology.from_dict(doc)
    assert back == t
    with pytest.raises(topology.TopologyError):
        topology.Topology.from_dict({"nonsense": True})
    with pytest.raises(topology.TopologyError):
        topology.Topology.from_dict([1, 2, 3])


# ---------------------------------------------------------------------------
# persistence: save/load and the identity-gated search
# ---------------------------------------------------------------------------

def test_save_load_roundtrip(tmp_path):
    t = _topo2x4()
    p = topology.save(t, tmp_path / "t.json")
    assert topology.load(p) == t
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(topology.TopologyError):
        topology.load(bad)


def test_persisted_path_rejects_wrong_identity(tmp_path, monkeypatch):
    monkeypatch.setenv(topology.TOPOLOGY_DIR_ENV, str(tmp_path))
    t = _topo2x4()
    topology.save(t, topology.topology_path(t, tmp_path))
    # fingerprint mismatch -> not found (the stored one is "test-fixture")
    assert topology.persisted_path(t.signature()) is None
    assert topology.persisted_path(
        t.signature(), fingerprint="test-fixture") is not None
    got = topology.load_for_mesh(t.signature(), fingerprint="test-fixture")
    assert got is not None and got.source == "persisted"
    assert got.signature() == t.signature()


# ---------------------------------------------------------------------------
# active ambient + generation (what invalidates cached dist plans)
# ---------------------------------------------------------------------------

def test_set_active_bumps_generation():
    before = topology.generation()
    try:
        topology.set_active(_topo2x4())
        assert topology.generation() == before + 1
        assert topology.active() == _topo2x4()
        # for_mesh prefers the matching active topology
        if len(jax.devices()) >= 8:
            mesh = jax.make_mesh((2, 4), ("host", "dev"))
            assert topology.for_mesh(mesh) == _topo2x4()
    finally:
        topology.set_active(None)
    assert topology.active() is None


# ---------------------------------------------------------------------------
# collectives facade: transport helpers (host math, no mesh needed)
# ---------------------------------------------------------------------------

def test_pipeline_chunks_divides_capacity():
    assert collectives.pipeline_chunks(1024, 4) == 4
    assert collectives.pipeline_chunks(1024, 3) == 2  # pow2 <= requested
    assert collectives.pipeline_chunks(6, 4) == 2     # must divide capacity
    assert collectives.pipeline_chunks(7, 8) == 1     # odd capacity: no split
    assert collectives.pipeline_chunks(1024, 0) == 1  # clamped, never raises


def test_wire_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.uniform(-500, 500, (4, 64)).astype(np.float32))
    q, scale = collectives.wire_encode_int8(v)
    assert q.dtype == jnp.int8
    back = collectives.wire_decode_int8(q, scale, jnp.float32)
    # per-bucket absmax quantization: error within one step per bucket
    err = np.abs(np.asarray(back) - np.asarray(v))
    bound = np.max(np.abs(np.asarray(v)), axis=-1, keepdims=True) / 127.0
    assert (err <= bound + 1e-6).all()


def test_wire_bytes_saved_counts_payload_shrink():
    # f32 payload (4B) -> int8 wire (1B): 3 bytes saved per slot, minus
    # the 4-byte per-bucket scale that rides along
    assert collectives.wire_bytes_saved(8, 128, 4) == 8 * 128 * 3 - 8 * 4
    # a 1-byte payload cannot shrink: the codec would only add scales
    assert collectives.wire_bytes_saved(8, 128, 1) == 0
