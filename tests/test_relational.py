"""repro.relational — sort-powered relational kernels vs numpy references.

Every op's documented reference semantics checked element-exactly
(np.unique / scatter-reduce group-by / nested-loop join / np.histogram),
plus the RelSpec front-door validation, the planner's relational pricing,
and the three consumer rewires' helpers (MoE group_ranks, pipeline dedup,
serve batch accounting).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.relational as rel
from repro.engine import planner
from repro.relational.relspec import RelSpec


def _col(seed=0, n=64, lo=-20, hi=20, dtype=np.int32):
    return np.random.default_rng(seed).integers(lo, hi, n).astype(dtype)


# ---------------------------------------------------------------------------
# unique
# ---------------------------------------------------------------------------

def test_unique_matches_numpy():
    x = _col(1)
    ref_v, ref_inv, ref_c = np.unique(x, return_inverse=True,
                                      return_counts=True)
    u = rel.unique(x, return_inverse=True, return_counts=True)
    m = int(u.n_unique)
    assert m == len(ref_v)
    np.testing.assert_array_equal(np.asarray(u.values[:m]), ref_v)
    np.testing.assert_array_equal(np.asarray(u.inverse), ref_inv)
    np.testing.assert_array_equal(np.asarray(u.counts[:m]), ref_c)
    # tail without fill_value repeats the max -> globally non-decreasing
    tail = np.asarray(u.values[m:])
    assert (tail == ref_v[-1]).all()


def test_unique_fill_value_pads_tail():
    x = np.asarray([3, 1, 3, 1], np.int32)
    u = rel.unique(x, fill_value=-7)
    assert np.asarray(u.values).tolist() == [1, 3, -7, -7]


def test_unique_signed_zero_merges():
    z = np.asarray([0.0, -0.0, 1.0, -0.0], np.float32)
    u = rel.unique(z)
    m = int(u.n_unique)
    assert m == 2
    assert np.asarray(u.values[:m]).tolist() == [0.0, 1.0]


def test_unique_empty():
    u = rel.unique(np.zeros(0, np.int32), return_inverse=True,
                   return_counts=True)
    assert int(u.n_unique) == 0
    assert u.values.shape == (0,)
    assert u.inverse.shape == (0,) and u.counts.shape == (0,)


def test_unique_all_equal():
    x = np.full(33, 7, np.int32)
    u = rel.unique(x, return_counts=True)
    assert int(u.n_unique) == 1
    assert int(u.counts[0]) == 33


def test_unique_under_jit():
    x = jnp.asarray(_col(2))

    @jax.jit
    def f(v):
        u = rel.unique(v, return_inverse=True)
        return u.values, u.n_unique, u.inverse

    vals, m, inv = f(x)
    ref_v, ref_inv = np.unique(np.asarray(x), return_inverse=True)
    np.testing.assert_array_equal(np.asarray(vals[:int(m)]), ref_v)
    np.testing.assert_array_equal(np.asarray(inv), ref_inv)


# ---------------------------------------------------------------------------
# group_by
# ---------------------------------------------------------------------------

def test_group_by_all_aggregates_match_numpy():
    k = _col(3, n=100, lo=-8, hi=8)
    v = _col(4, n=100, lo=0, hi=50)
    ref_k, inv = np.unique(k, return_inverse=True)
    g = len(ref_k)
    gb = rel.group_by(k, v, agg=("sum", "min", "max", "count", "mean"))
    assert int(gb.n_groups) == g
    np.testing.assert_array_equal(np.asarray(gb.keys[:g]), ref_k)
    rsum = np.zeros(g, np.int64)
    np.add.at(rsum, inv, v)
    rmin = np.full(g, np.iinfo(np.int32).max)
    np.minimum.at(rmin, inv, v)
    rmax = np.full(g, np.iinfo(np.int32).min)
    np.maximum.at(rmax, inv, v)
    rcnt = np.bincount(inv, minlength=g)
    np.testing.assert_array_equal(np.asarray(gb.aggregates[0][:g]),
                                  rsum.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(gb.aggregates[1][:g]), rmin)
    np.testing.assert_array_equal(np.asarray(gb.aggregates[2][:g]), rmax)
    np.testing.assert_array_equal(np.asarray(gb.aggregates[3][:g]), rcnt)
    np.testing.assert_array_equal(
        np.asarray(gb.aggregates[4][:g]),
        rsum.astype(np.float32) / rcnt.astype(np.float32))


def test_group_by_single_agg_and_empty():
    k = np.asarray([2, 2, 2], np.int32)
    v = np.asarray([1, 10, 100], np.int32)
    gb = rel.group_by(k, v, agg="sum")
    assert int(gb.n_groups) == 1 and int(gb.aggregates[0][0]) == 111
    ge = rel.group_by(np.zeros(0, np.int32), np.zeros(0, np.int32))
    assert int(ge.n_groups) == 0 and ge.aggregates[0].shape == (0,)


# ---------------------------------------------------------------------------
# join
# ---------------------------------------------------------------------------

def _ref_join(lk, rk):
    """The documented pair order: ascending key, then left input order,
    then right input order."""
    pairs = []
    for key in np.unique(lk[np.isin(lk, rk)]):
        for li in np.flatnonzero(lk == key):
            for ri in np.flatnonzero(rk == key):
                pairs.append((int(li), int(ri)))
    return pairs


def test_join_matches_reference_order():
    lk = _col(5, n=23, lo=0, hi=8)
    rk = _col(6, n=17, lo=0, hi=8)
    j = rel.join(lk, rk)
    p = int(j.n_pairs)
    got = list(zip(np.asarray(j.left_idx[:p]).tolist(),
                   np.asarray(j.right_idx[:p]).tolist()))
    assert got == _ref_join(lk, rk)


def test_join_size_fill_and_overflow():
    lk = np.asarray([1, 1], np.int32)
    rk = np.asarray([1, 1, 1], np.int32)
    j = rel.join(lk, rk, size=8, fill_value=-1)
    assert int(j.n_pairs) == 6
    assert np.asarray(j.left_idx[6:]).tolist() == [-1, -1]
    with pytest.raises(ValueError, match="pass size >= 6"):
        rel.join(lk, rk, size=4)


def test_join_empty_sides_and_no_matches():
    j = rel.join(np.zeros(0, np.int32), np.asarray([1], np.int32), size=2)
    assert int(j.n_pairs) == 0
    j2 = rel.join(np.asarray([1, 2], np.int32),
                  np.asarray([3, 4], np.int32))
    assert int(j2.n_pairs) == 0
    assert (np.asarray(j2.left_idx) == -1).all()


# ---------------------------------------------------------------------------
# rle / delta
# ---------------------------------------------------------------------------

def test_rle_round_trip_and_counts():
    x = _col(7, n=50, lo=0, hi=6)
    r = rel.run_length_encode(x)
    nr = int(r.n_runs)
    ref_v, ref_c = np.unique(x, return_counts=True)
    np.testing.assert_array_equal(np.asarray(r.values[:nr]), ref_v)
    np.testing.assert_array_equal(np.asarray(r.run_lengths[:nr]), ref_c)
    assert (np.asarray(r.run_lengths[nr:]) == 0).all()
    dec = rel.rle_decode(r.values, r.run_lengths, len(x))
    np.testing.assert_array_equal(np.asarray(dec), np.sort(x))


def test_rle_assume_sorted_skips_the_sort():
    s = np.asarray([1, 1, 2, 5, 5, 5], np.int32)
    r = rel.run_length_encode(s, assume_sorted=True)
    assert np.asarray(r.values[:int(r.n_runs)]).tolist() == [1, 2, 5]
    assert np.asarray(r.run_lengths[:3]).tolist() == [2, 1, 3]


def test_delta_round_trip_including_wraparound():
    x = np.asarray([np.iinfo(np.int32).min, -1, 0,
                    np.iinfo(np.int32).max], np.int32)
    d = rel.delta_encode(x)
    np.testing.assert_array_equal(np.asarray(rel.delta_decode(d.deltas)),
                                  np.sort(x))


# ---------------------------------------------------------------------------
# sketches
# ---------------------------------------------------------------------------

def test_histogram_matches_numpy_on_same_edges():
    x = np.random.default_rng(8).normal(size=200).astype(np.float32)
    h = rel.histogram(x, 16)
    edges = np.asarray(h.edges)
    ref, _ = np.histogram(x, bins=edges)
    np.testing.assert_array_equal(np.asarray(h.counts), ref)
    assert int(np.asarray(h.counts).sum()) == len(x)


def test_histogram_pinned_range_excludes_outliers():
    x = np.asarray([-5.0, 0.5, 1.5, 99.0], np.float32)
    h = rel.histogram(x, 2, lo=0.0, hi=2.0)
    assert np.asarray(h.counts).tolist() == [1, 1]


def test_quantiles_are_lower_order_statistics():
    x = np.random.default_rng(9).integers(-1000, 1000, 101
                                          ).astype(np.int32)
    qs = (0.0, 0.25, 0.5, 0.9, 1.0)
    q = rel.quantiles(x, qs)
    s = np.sort(x)
    ref = [s[int(f * (len(x) - 1))] for f in qs]
    np.testing.assert_array_equal(np.asarray(q.values), ref)


# ---------------------------------------------------------------------------
# group_ranks (the MoE dispatch primitive)
# ---------------------------------------------------------------------------

def _ref_ranks(keys, g):
    seen, out = {}, []
    for e in keys:
        out.append(seen.get(int(e), 0))
        seen[int(e)] = out[-1] + 1
    return out, np.bincount(keys, minlength=g)


def test_group_ranks_one_hot_path():
    keys = _col(10, n=64, lo=0, hi=7)
    gr = rel.group_ranks(keys, 7)
    ref_r, ref_c = _ref_ranks(keys, 7)
    np.testing.assert_array_equal(np.asarray(gr.ranks), ref_r)
    np.testing.assert_array_equal(np.asarray(gr.counts), ref_c)


def test_group_ranks_sort_path_matches_one_hot():
    # domain above ONE_HOT_MAX_GROUPS rides the stable sort instead
    keys = _col(11, n=200, lo=0, hi=600)
    gr = rel.group_ranks(keys, 600)
    ref_r, ref_c = _ref_ranks(keys, 600)
    np.testing.assert_array_equal(np.asarray(gr.ranks), ref_r)
    np.testing.assert_array_equal(np.asarray(gr.counts), ref_c)


def test_group_ranks_batched_and_constrained():
    keys = _col(12, n=64, lo=0, hi=5).reshape(4, 16)
    called = []
    gr = rel.group_ranks(keys, 5,
                         constrain=lambda oh: (called.append(oh.shape),
                                               oh)[1])
    assert called == [(4, 16, 5)]
    for b in range(4):
        ref_r, ref_c = _ref_ranks(keys[b], 5)
        np.testing.assert_array_equal(np.asarray(gr.ranks[b]), ref_r)
        np.testing.assert_array_equal(np.asarray(gr.counts[b]), ref_c)


# ---------------------------------------------------------------------------
# RelSpec front door: every invalid combination raises here, not deep in
# an op kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,x,values,match", [
    (RelSpec(op="nope"), np.zeros(3, np.int32), None, "op must be"),
    (RelSpec(op="unique"), np.zeros((2, 3), np.int32), None, "1-D"),
    (RelSpec(op="unique", method="warp"), np.zeros(3, np.int32), None,
     "method must be"),
    (RelSpec(op="histogram", num_bins=4, method="radix"),
     np.zeros(3, np.float32), None, "must be 'auto'"),
    (RelSpec(op="group_by", agg=("sum", "median")), np.zeros(3, np.int32),
     np.zeros(3, np.int32), "unknown aggregates"),
    (RelSpec(op="group_by"), np.zeros(3, np.int32), None,
     "needs a values column"),
    (RelSpec(op="group_by"), np.zeros(3, np.int32), np.zeros(4, np.int32),
     "must match"),
    (RelSpec(op="join"), np.zeros(3, np.int32), np.zeros(3, np.int16),
     "dtypes must match"),
    (RelSpec(op="join", size=0), np.zeros(3, np.int32),
     np.zeros(3, np.int32), "size must be"),
    (RelSpec(op="unique", size=4), np.zeros(3, np.int32), None,
     "join-only"),
    (RelSpec(op="delta"), np.zeros(3, np.float32), None, "integer"),
    (RelSpec(op="unique", assume_sorted=True), np.zeros(3, np.int32),
     None, "rle/delta"),
    (RelSpec(op="unique", num_bins=3), np.zeros(3, np.int32), None,
     "histogram-only"),
    (RelSpec(op="group_by", return_counts=True), np.zeros(3, np.int32),
     np.zeros(3, np.int32), "unique-only"),
    (RelSpec(op="quantile"), np.zeros(3, np.float32), None, "needs qs"),
    (RelSpec(op="quantile", qs=(1.5,)), np.zeros(3, np.float32), None,
     r"\[0, 1\]"),
    (RelSpec(op="quantile", qs=(0.5,)), np.zeros(0, np.float32), None,
     "empty"),
    (RelSpec(op="unique", qs=(0.5,)), np.zeros(3, np.int32), None,
     "quantile-only"),
    (RelSpec(op="group_ranks"), np.zeros(3, np.int32), None,
     "num_groups"),
    (RelSpec(op="group_ranks", num_groups=4), np.zeros(3, np.float32),
     None, "integers"),
    (RelSpec(op="unique", axis_name="data"), np.zeros(3, np.int32), None,
     "requires a mesh"),
])
def test_relspec_validation_errors(spec, x, values, match):
    with pytest.raises(ValueError, match=match):
        spec.canonical(jnp.asarray(x),
                       None if values is None else jnp.asarray(values))


def test_relspec_mesh_rejected_for_non_mesh_ops():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    with pytest.raises(ValueError, match="has none"):
        RelSpec(op="join", mesh=mesh).canonical(
            jnp.zeros(3, jnp.int32), jnp.zeros(3, jnp.int32))


def test_relspec_canonical_is_idempotent_and_static_key_hashable():
    spec = RelSpec(op="group_by", agg="sum").canonical(
        jnp.zeros(4, jnp.int32), jnp.zeros(4, jnp.int32))
    assert spec.agg == ("sum",) and spec.method == "auto"
    spec2 = dataclasses.replace(spec)
    assert hash(spec.static_key((4,), jnp.int32)) == \
        hash(spec2.static_key((4,), jnp.int32))


# ---------------------------------------------------------------------------
# planner: relational pricing
# ---------------------------------------------------------------------------

def test_choose_relational_prices_stable_ops_at_merge_fallback():
    from repro.core import cost_model
    plan = planner.choose_relational("join", 4096, dtype=jnp.int32)
    # bitonic is non-stable: picking it would actually run the stable
    # merge pipeline, so its price must equal merge's, not its raw cost
    assert plan.costs["bitonic"] == pytest.approx(plan.costs["merge"])
    raw = cost_model.relational_cost_ns(
        "join", "bitonic", 4096, pallas_interpreted=True)
    assert raw != pytest.approx(plan.costs["bitonic"])


def test_choose_relational_respects_requested_method():
    plan = planner.choose_relational("unique", 256, dtype=jnp.int32,
                                     requested="radix")
    assert plan.method == "radix"


def test_choose_relational_rejects_sketch_ops():
    with pytest.raises(ValueError, match="sort-backed"):
        planner.choose_relational("histogram", 64)


def test_choose_relational_cached_hits():
    p1 = planner.choose_relational_cached("unique", 512, dtype=jnp.int32)
    p2 = planner.choose_relational_cached("unique", 512, dtype=jnp.int32)
    assert p1 is p2


def test_method_pin_runs_that_backend():
    x = _col(13, n=40, lo=0, hi=9)
    ref = np.unique(x)
    for method in ("xla", "merge", "radix"):
        u = rel.unique(x, method=method)
        np.testing.assert_array_equal(
            np.asarray(u.values[:int(u.n_unique)]), ref, err_msg=method)


# ---------------------------------------------------------------------------
# obs integration
# ---------------------------------------------------------------------------

def test_relational_ops_emit_spans_and_counters():
    from repro.obs import metrics, trace
    trace.enable()
    metrics.reset()
    try:
        rel.unique(_col(14, n=32))
        rel.group_by(_col(15, n=32, lo=0, hi=4), _col(16, n=32))
        assert metrics.counter("relational.unique").value == 1
        assert metrics.counter("relational.group_by").value == 1
        names = [s["name"] for s in trace.spans()]
        assert "relational.unique" in names
        assert "relational.group_by" in names
    finally:
        metrics.reset()
        trace.disable()


# ---------------------------------------------------------------------------
# consumer rewires
# ---------------------------------------------------------------------------

def test_pipeline_dedup_rows_keeps_first_occurrences():
    from repro.data.pipeline import dedup_rows, row_fingerprints
    rows = np.asarray([[1, 2, 3], [4, 5, 6], [1, 2, 3], [7, 8, 9],
                       [4, 5, 6]], np.int32)
    keep = dedup_rows(rows)
    assert keep.tolist() == [True, True, False, True, False]
    h = row_fingerprints(rows)
    assert h.dtype == np.uint32
    assert h[0] == h[2] and h[1] == h[4] and h[0] != h[1]


def test_pipeline_iterate_dedup_hook():
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=16, seq_len=8, global_batch=16, seed=3,
                     motif_len=4, n_motifs=2)
    ds = SyntheticLM(cfg)
    batch = next(ds.iterate(dedup=True))
    fp = {tuple(r) for r in batch["tokens"].tolist()}
    assert len(fp) == batch["tokens"].shape[0]       # no duplicate rows
    assert batch["tokens"].shape == batch["labels"].shape


def test_serve_batch_accounting_groups_by_prompt_length():
    from repro.launch.serve import Request, batch_accounting
    done = [
        Request(rid=0, prompt=np.zeros(4, np.int32),
                out=np.zeros(10, np.int32)),
        Request(rid=1, prompt=np.zeros(9, np.int32),
                out=np.zeros(20, np.int32)),
        Request(rid=2, prompt=np.zeros(4, np.int32),
                out=np.zeros(30, np.int32)),
    ]
    acct = batch_accounting(done)
    assert acct == [(4, 2, 20.0), (9, 1, 20.0)]
    assert batch_accounting([]) == []


def test_moe_forward_uses_group_ranks():
    """The rewired dispatch must reproduce the inline one-hot cumsum it
    replaced — forward parity against a direct reimplementation."""
    from repro.configs.base import MoEConfig
    from repro.models import moe

    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=2.0,
                    d_ff_expert=8)
    key = jax.random.PRNGKey(0)
    params, _ = moe.init(key, 16, cfg, "gelu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16), jnp.float32)
    out, aux = moe.apply(params, x, cfg, "gelu")
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux["moe_lb_loss"]) > 0.0
