"""Out-of-core spill tier: correctness, routing, and the merge machinery.

The spill tier's contract is bit-exactness with the in-core registry
reference at any chunking — the chunk size only changes WHERE the work
happens (device chunks + host merge), never the answer.  Tests force tiny
chunks so a few hundred elements exercise many runs and every block
boundary, then diff against ``np.sort`` / stable ``np.argsort``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import tuning
from repro.engine import planner, spill
from repro.engine.merge import kway_merge, kway_merge_kv

CHUNK_BYTES = 256                     # 64 f32 elements per device chunk


@pytest.fixture(autouse=True)
def _clean_tuning():
    tuning.set_active(None)
    planner.clear_plan_cache()
    yield
    tuning.set_active(None)
    planner.clear_plan_cache()


def _keys(n, dtype="float32", seed=0):
    rng = np.random.default_rng(seed)
    if np.issubdtype(np.dtype(dtype), np.floating):
        return rng.standard_normal(n).astype(dtype)
    info = np.iinfo(dtype)
    return rng.integers(info.min, info.max, n, dtype=dtype,
                        endpoint=True)


# ---------------------------------------------------------------------------
# bit-exactness vs the in-core reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["float32", "int32", "uint16", "float16"])
@pytest.mark.parametrize("descending", [False, True])
def test_spill_sort_bit_matches_reference(dtype, descending):
    n = 4 * spill.chunk_elems(np.dtype(dtype).itemsize, CHUNK_BYTES) + 17
    x = _keys(n, dtype)
    out = spill.spill_sort(x, descending=descending, chunk_bytes=CHUNK_BYTES)
    ref = np.sort(x)
    if descending:
        ref = ref[::-1]
    assert isinstance(out, np.ndarray)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("descending", [False, True])
def test_spill_sort_kv_stable_bit_match(descending):
    # duplicate-heavy keys: stability is the hard part of the contract
    rng = np.random.default_rng(3)
    n = 700
    k = rng.integers(0, 8, n).astype(np.int32)
    v = np.arange(n, dtype=np.int32)
    sk, sv = spill.spill_sort_kv(k, v, descending=descending,
                                 chunk_bytes=CHUNK_BYTES)
    order = np.argsort(-k.astype(np.int64) if descending else k,
                       kind="stable")
    np.testing.assert_array_equal(sk, k[order])
    np.testing.assert_array_equal(sv, order.astype(np.int32))


def test_spill_argsort_is_stable_permutation():
    rng = np.random.default_rng(11)
    x = rng.integers(0, 5, 500).astype(np.int32)
    order = spill.spill_argsort(x, chunk_bytes=CHUNK_BYTES)
    np.testing.assert_array_equal(order, np.argsort(x, kind="stable"))


def test_spill_nan_keys_match_total_order():
    rng = np.random.default_rng(7)
    x = rng.standard_normal(400).astype(np.float32)
    x[rng.integers(0, 400, 30)] = np.nan
    x[rng.integers(0, 400, 10)] = np.inf
    x[rng.integers(0, 400, 10)] = -np.inf
    out = spill.spill_sort(x, chunk_bytes=CHUNK_BYTES)
    np.testing.assert_array_equal(out, np.sort(x))   # NaN last, total order
    v = np.arange(400, dtype=np.int32)
    sk, sv = spill.spill_sort_kv(x, v, chunk_bytes=CHUNK_BYTES)
    np.testing.assert_array_equal(sv, np.argsort(x, kind="stable"))


# ---------------------------------------------------------------------------
# chunk-boundary shapes
# ---------------------------------------------------------------------------

def test_n_not_multiple_of_chunk():
    chunk = spill.chunk_elems(4, CHUNK_BYTES)
    for n in (chunk - 1, chunk + 1, 3 * chunk - 5, 3 * chunk + 5):
        x = _keys(n, "float32", seed=n)
        np.testing.assert_array_equal(
            spill.spill_sort(x, chunk_bytes=CHUNK_BYTES), np.sort(x))


def test_n_smaller_than_one_chunk_passthrough():
    x = _keys(13, "float32")
    np.testing.assert_array_equal(
        spill.spill_sort(x, chunk_bytes=CHUNK_BYTES), np.sort(x))


def test_empty_input():
    out = spill.spill_sort(np.empty((0,), np.float32),
                           chunk_bytes=CHUNK_BYTES)
    assert out.shape == (0,) and out.dtype == np.float32
    sk, sv = spill.spill_sort_kv(np.empty((0,), np.int32),
                                 np.empty((0,), np.int32),
                                 chunk_bytes=CHUNK_BYTES)
    assert sk.shape == sv.shape == (0,)


def test_overlap_off_is_equal_not_just_close():
    x = _keys(777, "float32", seed=5)
    a = spill.spill_sort(x, chunk_bytes=CHUNK_BYTES, overlap=True)
    b = spill.spill_sort(x, chunk_bytes=CHUNK_BYTES, overlap=False)
    np.testing.assert_array_equal(a, b)


def test_rejects_non_1d_and_bad_chunk():
    with pytest.raises(ValueError, match="1-D"):
        spill.spill_sort(np.zeros((2, 3), np.float32))
    with pytest.raises(ValueError, match="chunk_bytes"):
        spill.spill_sort(np.zeros((8,), np.float32), chunk_bytes=4)
    with pytest.raises(ValueError, match="match keys"):
        spill.spill_sort_kv(np.zeros((4,), np.float32),
                            np.zeros((5,), np.int32))


# ---------------------------------------------------------------------------
# planner routing + cache invalidation
# ---------------------------------------------------------------------------

def _install_threshold(threshold):
    tuning.set_active(dataclasses.replace(
        tuning.active(), spill_threshold_bytes=threshold))


def test_planner_routes_oversized_to_spill():
    _install_threshold(1024)
    plan = planner.choose(4096, 1, jnp.float32)
    assert plan.method == "spill"
    assert np.isfinite(plan.costs["spill"])
    assert planner.choose(64, 1, jnp.float32).method != "spill"


def test_spill_never_a_candidate_below_threshold():
    # auto dispatch under the threshold must not even price spill
    plan = planner.choose(512, 1, jnp.float32)
    assert plan.method != "spill"
    assert "spill" not in plan.costs


def test_threshold_change_invalidates_cached_plans():
    assert planner.choose_cached(4096, 1, jnp.float32).method != "spill"
    _install_threshold(1024)             # bumps the tuning generation
    assert planner.choose_cached(4096, 1, jnp.float32).method == "spill"
    tuning.set_active(None)
    assert planner.choose_cached(4096, 1, jnp.float32).method != "spill"


def test_engine_front_door_auto_spills_and_matches():
    _install_threshold(1024)
    x = jnp.asarray(_keys(4096, "float32", seed=9))
    out = engine.sort(x)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_jit_fallback_swaps_spill_for_merge():
    _install_threshold(1024)
    x = jnp.asarray(_keys(4096, "float32", seed=10))
    out = jax.jit(lambda a: engine.sort(a))(x)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


def test_spill_backend_registered_with_honest_caps():
    from repro.core import sortspec
    caps = sortspec.get_backend("spill").capabilities
    assert caps.stable and caps.supports_kv
    assert not caps.supports_topk and not caps.auto_dispatch


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------

def test_int8_codec_output_sorted_and_close():
    x = _keys(600, "float32", seed=2)
    out = spill.spill_sort(x, chunk_bytes=CHUNK_BYTES, codec="int8")
    assert np.all(np.diff(out) >= 0)              # still globally sorted
    # lossy but bounded by one per-run quantization step
    step = np.abs(x).max() / 127.0
    assert np.max(np.abs(out - np.sort(x))) <= 2 * step


def test_int8_codec_rejects_int_keys():
    with pytest.raises(ValueError, match="int8 spill codec"):
        spill.spill_sort(_keys(64, "int32"), chunk_bytes=CHUNK_BYTES,
                         codec="int8")


def test_kv_codec_compresses_payload_keys_exact():
    rng = np.random.default_rng(4)
    k = rng.integers(0, 100, 500).astype(np.int32)
    v = rng.standard_normal(500).astype(np.float32)
    sk, sv = spill.spill_sort_kv(k, v, chunk_bytes=CHUNK_BYTES, codec="int8")
    np.testing.assert_array_equal(sk, np.sort(k))  # keys never quantized
    order = np.argsort(k, kind="stable")
    step = np.abs(v).max() / 127.0
    assert np.max(np.abs(sv - v[order])) <= 2 * step


# ---------------------------------------------------------------------------
# merge padding regressions (NaN / sentinel-valued genuine keys)
# ---------------------------------------------------------------------------

def test_kway_merge_kv_sentinel_valued_genuine_keys():
    # genuine int32 max keys tie with the pad sentinel; pads must lose
    info = np.iinfo(np.int32)
    a = np.array([1, info.max, info.max], np.int32)
    b = np.array([0, info.max], np.int32)
    va = np.array([10, 11, 12], np.int32)
    vb = np.array([20, 21], np.int32)
    mk, mv = kway_merge_kv([jnp.asarray(a), jnp.asarray(b)],
                           [jnp.asarray(va), jnp.asarray(vb)])
    np.testing.assert_array_equal(
        np.asarray(mk), [0, 1, info.max, info.max, info.max])
    np.testing.assert_array_equal(np.asarray(mv), [20, 10, 11, 12, 21])


def test_kway_merge_nan_tail_both_directions():
    a = np.array([1.0, np.inf, np.nan], np.float32)
    b = np.array([-np.inf, 2.0], np.float32)
    got = np.asarray(kway_merge([jnp.asarray(a), jnp.asarray(b)]))
    np.testing.assert_array_equal(got, np.sort(np.concatenate([a, b])))
    d_a, d_b = a[::-1].copy(), b[::-1].copy()    # descending-sorted inputs
    got_d = np.asarray(kway_merge([jnp.asarray(d_a), jnp.asarray(d_b)],
                                  descending=True))
    np.testing.assert_array_equal(
        got_d, np.sort(np.concatenate([a, b]))[::-1])


# ---------------------------------------------------------------------------
# observability contract
# ---------------------------------------------------------------------------

def test_spill_counters_and_overlap_gauge():
    from repro.obs import metrics, trace
    trace.enable()
    metrics.reset()
    try:
        x = _keys(600, "float32", seed=6)
        spill.spill_sort(x, chunk_bytes=CHUNK_BYTES)
        assert metrics.counter("spill.h2d_bytes").value >= x.nbytes
        assert metrics.counter("spill.d2h_bytes").value >= x.nbytes
        frac = metrics.gauge("spill.overlap_fraction").value
        assert 0.0 <= frac <= 1.0
    finally:
        metrics.reset()
        trace.disable()
