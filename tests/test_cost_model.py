"""Every quantitative claim in the paper, checked against the model."""
import pytest

from repro.core import cost_model, sorter


def test_all_paper_claims():
    claims = cost_model.validate_claims()
    failures = [(n, m, p) for (n, m, p, tol) in claims.rows
                if abs(m - p) > tol]
    assert not failures, failures


def test_sort_cycles_scale_with_n():
    prev = 0
    for n in (2, 4, 8, 16, 32):
        c = cost_model.sort_cycles(n)
        assert c > prev
        prev = c


def test_simulator_agrees_with_cost_model():
    import numpy as np
    v = np.random.default_rng(0).integers(0, 16, size=(1, 8))
    res = sorter.sort_in_memory(v, width=4)
    assert res.cycles == cost_model.sort_cycles(8, 4)
    assert res.compute_cycles == 6 * 28
    assert res.movement_cycles == 24


def test_memsort_comparison_ratios():
    assert cost_model.memsort_cycles(8) / cost_model.sort_cycles(8) \
        == pytest.approx(1.45)
    assert cost_model.memsort_latency_ns(8) / cost_model.sort_latency_ns(8) \
        == pytest.approx(3.4)
    assert cost_model.off_memory_latency_ns(8) \
        / cost_model.sort_latency_ns(8) == pytest.approx(5.0)


def test_table1_single_stage_totals():
    totals = cost_model.stage_op_totals(8)
    assert totals == {"NOR": 84, "NOT": 48, "AND": 18, "COPY": 42}
    assert sum(totals.values()) == 192
