"""Plan-cache lifecycle: stale plans must die on every mutation channel.

``planner.choose_cached`` memoizes resolved plans per workload statics; a
serving process then mutates the world in three ways — registering a new
backend, unregistering one, and re-calibrating the measured constants —
and each must transparently invalidate cached plans, or ``method="auto"``
keeps dispatching to yesterday's winner (or worse, to an engine that no
longer exists).
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sort as rsort
from repro.core import sortspec
from repro.engine import planner


class _CheapBackend(sortspec.SortBackend):
    """Claims (falsely) to cost nothing, so auto must pick it once it is
    registered — making stale-plan reuse observable."""
    name = "cheapo-test"
    capabilities = sortspec.Capabilities()

    def cost_ns(self, n, batch, dtype, *, run_len, consts=None,
                interpreted=False):
        return 0.0

    def sort(self, rows, *, descending=False, plan=None, interpret=None):
        out = jnp.sort(rows, axis=-1)
        return jnp.flip(out, -1) if descending else out


@pytest.fixture(autouse=True)
def _clean_cache():
    planner.clear_plan_cache()
    yield
    sortspec.unregister_backend("cheapo-test")
    planner.clear_plan_cache()


def test_register_invalidates_and_auto_repicks():
    """Stale-plan regression: a cached method='auto' plan must not survive
    a registry mutation — the fresh backend has to win the re-plan."""
    before = planner.choose_cached(4096, 2, jnp.float32)
    assert before.method != "cheapo-test"
    # warm the cache through the public front door too
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 100)),
                    jnp.float32)
    rsort.sort(x)                                    # method="auto" default
    sortspec.register_backend(_CheapBackend)
    after = planner.choose_cached(4096, 2, jnp.float32)
    assert after is not before
    assert after.method == "cheapo-test"             # zero-cost claim wins
    # and the front door's auto path actually dispatches to it
    out = rsort.sort(x)                              # still correct output
    np.testing.assert_array_equal(np.asarray(out),
                                  np.sort(np.asarray(x), -1))


def test_unregister_invalidates():
    sortspec.register_backend(_CheapBackend)
    won = planner.choose_cached(4096, 2, jnp.float32)
    assert won.method == "cheapo-test"
    sortspec.unregister_backend("cheapo-test")
    replanned = planner.choose_cached(4096, 2, jnp.float32)
    assert replanned is not won
    assert replanned.method != "cheapo-test"
    assert "cheapo-test" not in replanned.costs


def test_unregister_is_idempotent_but_still_invalidates():
    gen = sortspec.registry_generation()
    sortspec.unregister_backend("never-existed")
    assert sortspec.registry_generation() == gen + 1   # generation bumps
    p1 = planner.choose_cached(1000, 1, jnp.float32)
    sortspec.unregister_backend("never-existed")
    assert planner.choose_cached(1000, 1, jnp.float32) is not p1


def test_calibrate_invalidates_mid_session():
    """calibrate() measures new constants; plans priced with the old ones
    must be dropped even though the registry never changed."""
    stale = planner.choose_cached(100000, 1, jnp.float32)
    try:
        planner.calibrate(tile_n=256, batch=4, reps=1)
        fresh = planner.choose_cached(100000, 1, jnp.float32)
        assert fresh is not stale
        # measured constants actually flowed into the new pricing
        assert fresh.costs != stale.costs
    finally:
        planner.reset_calibration()
    assert planner.choose_cached(100000, 1, jnp.float32) is not fresh


def test_profile_swap_invalidates():
    """Installing a different tuning profile must re-key cached plans: the
    plan cache folds ``tuning.generation()`` into its key, so a swapped
    profile (different run_len here) shows up without an explicit clear."""
    from dataclasses import replace

    from repro.core import tuning
    before = planner.choose_cached(100000, 1, jnp.float32)
    try:
        tuning.set_active(replace(tuning.active(),
                                  run_len=before.run_len // 2))
        after = planner.choose_cached(100000, 1, jnp.float32)
        assert after is not before
        assert after.run_len == before.run_len // 2
    finally:
        tuning.set_active(None)
    assert planner.choose_cached(100000, 1, jnp.float32) is not after


def test_distributed_plans_share_invalidation():
    d1 = planner.choose_distributed_cached(1 << 20, 8)
    assert planner.choose_distributed_cached(1 << 20, 8) is d1   # hit
    sortspec.register_backend(_CheapBackend)
    assert planner.choose_distributed_cached(1 << 20, 8) is not d1


# ---------------------------------------------------------------------------
# k-aware plans: selection vs sort-prefix
# ---------------------------------------------------------------------------

def test_topk_plans_are_keyed_on_k():
    """A top-k plan and a sort plan for the same row shape are different
    cache entries, priced by different models."""
    sort_plan = planner.choose_cached(1 << 20, 1, jnp.float32)
    topk_plan = planner.choose_cached(1 << 20, 1, jnp.float32, k=64)
    assert topk_plan is not sort_plan
    assert planner.choose_cached(1 << 20, 1, jnp.float32, k=64) is topk_plan
    assert planner.choose_cached(1 << 20, 1, jnp.float32, k=128) \
        is not topk_plan


def test_auto_topk_never_loses_to_native_xla():
    """Regression for the ROADMAP-flagged ~90x inversion at n=1M/k=64:
    ``auto`` preferred radix-select (313ms measured) over ``lax.top_k``
    (3.4ms) because the native lowering went unpriced — the xla candidate
    carried the sort-prefix contract.  Off-TPU ``lax.top_k`` is XLA:CPU's
    tuned O(n) selection and is priced as one
    (``cost_model.xla_topk_cost_ns``); on TPU the lowering is sort-based
    and the sort-prefix price stands, so selection keeps winning there.
    Cost-model comparison only — no 1M sort runs in tier-1."""
    big = planner.choose_cached(1 << 20, 1, jnp.float32, k=64)
    # the winner must never be priced above the native-xla candidate
    assert big.costs[big.method] <= big.costs["xla"], big.costs
    if planner.on_tpu():
        assert big.method == "select", big.costs
    else:
        assert big.method == "xla", big.costs
        assert big.costs["xla"] < big.costs["select"]
    # the selection model still scales with key width: int8 keys, 1 pass
    narrow = planner.choose_cached(1 << 20, 1, jnp.int8, k=64)
    assert narrow.costs["select"] < big.costs["select"]
    # other side of the crossover: a tiny row is cheaper to just sort
    small = planner.choose_cached(64, 1, jnp.float32, k=64)
    assert small.method != "select", small.costs


def test_sort_plans_never_pick_the_selection_backend():
    """supports_sort=False removes selection-only engines from every sort
    plan, while explicit top-k requests still route to them."""
    for n in (64, 4096, 1 << 20):
        assert planner.choose_cached(n, 1, jnp.float32).method != "select"
    forced = planner.choose_cached(4096, 1, jnp.float32,
                                   requested="select", k=16)
    assert forced.method == "select"
