"""Perf-trajectory pipeline: emit_bench schema + bench_gate enforcement.

The acceptance path for the whole bench leg: a deliberately mispriced plan
(the 90x top-k inversion class, reconstructed) must produce a BENCH point
whose ``auto`` exceeds the gate factor, and ``scripts/bench_gate.py`` must
turn that into a non-zero exit.  No timing runs here — points are built
through the emitter's own schema helpers with injected measurements, so
the test is deterministic on any CI box.
"""
import importlib.util
import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))  # benchmarks/ is a plain dir, not a package

from benchmarks import emit_bench  # noqa: E402


def _load_bench_gate():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", REPO / "scripts" / "bench_gate.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_gate = _load_bench_gate()


@pytest.fixture(autouse=True)
def _isolated_profiles(tmp_path, monkeypatch):
    """Profile lookups must not see the developer's real cache: a persisted
    profile there would flip ``--warn-only`` into hard-fail mid-suite."""
    from repro.core import tuning
    monkeypatch.setenv(tuning.PROFILE_DIR_ENV, str(tmp_path / "profiles"))
    tuning.set_active(None)
    yield
    tuning.set_active(None)


class _FakePlan:
    """A planner Plan double carrying a cost table the gate never reads —
    the gate judges measurements, not predictions."""

    def __init__(self, method, costs):
        self.method = method
        self.run_len = 2048
        self.run_method = "xla"
        self.merge_backend = "xla"
        self.costs = costs


def _mispriced_point():
    """The reconstructed inversion: the model prices select at 1/10 of
    xla, but the measurement says auto(select) is 90x the best backend."""
    plan = _FakePlan("select", {"select": 1_000.0, "xla": 10_000.0})
    measured = {"xla": {"ns": 3.4e6, "bytes_moved": 1 << 22},
                "select": {"ns": 313e6, "bytes_moved": 1 << 24}}
    return emit_bench._point("topk.n1048576.k64", "topk", 1 << 20, 64,
                             measured, 313e6, plan)


def _healthy_point():
    plan = _FakePlan("xla", {"xla": 3.0e6, "select": 60e6})
    measured = {"xla": {"ns": 3.4e6, "bytes_moved": 1 << 22},
                "select": {"ns": 313e6, "bytes_moved": 1 << 24}}
    return emit_bench._point("topk.n1048576.k64", "topk", 1 << 20, 64,
                             measured, 3.5e6, plan)


def test_gate_fails_on_mispriced_plan(tmp_path):
    doc = emit_bench.document([_mispriced_point()])
    path = tmp_path / "BENCH_sort.json"
    path.write_text(json.dumps(doc))
    violations, checked = bench_gate.check(doc, factor=2.0)
    assert checked == 1 and len(violations) == 1
    v = violations[0]
    assert v["auto_backend"] == "select" and v["best_backend"] == "xla"
    assert v["ratio"] == pytest.approx(313e6 / 3.4e6)
    assert bench_gate.main([str(path)]) == 1
    # warn-only reports but never reddens the build
    assert bench_gate.main([str(path), "--warn-only"]) == 0


def test_gate_passes_healthy_artifact(tmp_path):
    doc = emit_bench.document([_healthy_point(), _mispriced_point()])
    path = tmp_path / "BENCH_sort.json"
    path.write_text(json.dumps(doc))
    # a generous factor admits the mispriced point too
    assert bench_gate.main([str(path), "--factor", "100"]) == 0
    assert bench_gate.main([str(path), "--factor", "1.5"]) == 1


def test_gate_rejects_malformed_artifacts(tmp_path):
    missing = tmp_path / "nope.json"
    assert bench_gate.main([str(missing)]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "something/else", "points": []}))
    assert bench_gate.main([str(bad)]) == 2


def test_point_schema_carries_plan_and_error():
    p = _mispriced_point()
    assert p["auto"]["backend"] == "select"
    assert p["auto"]["predicted_ns"] == 1_000.0
    assert p["auto"]["cost_model_error"] == pytest.approx(313e6 / 1_000.0)
    assert p["auto"]["plan"]["costs"]["xla"] == 10_000.0
    assert p["best"] == {"backend": "xla", "ns": 3.4e6}
    assert p["backends"]["select"]["bytes_moved"] == 1 << 24
    # the document is strict JSON (inf costs become null, never Infinity)
    json.loads(json.dumps(emit_bench.document([p]), allow_nan=False))


def test_write_and_reload(tmp_path):
    path = emit_bench.write([_healthy_point()], tmp_path / "b" / "out.json")
    doc = json.loads(path.read_text())
    assert doc["schema"] == emit_bench.SCHEMA
    assert len(doc["points"]) == 1


# ---------------------------------------------------------------------------
# v2: tuning-profile provenance + baseline trajectory diff
# ---------------------------------------------------------------------------

def test_v2_document_carries_profile_provenance():
    from repro.core import tuning
    doc = emit_bench.document([_healthy_point()])
    prof = doc["profile"]
    assert prof["fingerprint"] == tuning.device_fingerprint()
    assert prof["source"] == "default"        # isolated dir holds nothing
    assert prof["persisted"] is False
    assert prof["digit_bits"] == tuning.active().digit_bits
    assert prof["run_len"] == tuning.active().run_len


def test_v1_documents_still_check():
    doc = emit_bench.document([_mispriced_point()])
    doc["schema"] = "repro.bench.sort/v1"
    doc.pop("profile")
    violations, checked = bench_gate.check(doc, factor=2.0)
    assert checked == 1 and len(violations) == 1


def test_persisted_profile_overrides_warn_only(tmp_path):
    """Satellite invariant: where a persisted profile matches this device's
    fingerprint, the gate hard-fails even under --warn-only — measured
    constants remove the the-defaults-were-guesses excuse."""
    from repro.core import tuning
    tuning.save(tuning.default_profile())     # lands in the isolated dir
    tuning.set_active(None)                   # re-resolve -> persisted
    doc = emit_bench.document([_mispriced_point()])
    assert doc["profile"]["persisted"] is True
    assert doc["profile"]["source"] == "persisted"
    path = tmp_path / "BENCH_sort.json"
    path.write_text(json.dumps(doc))
    assert bench_gate.main([str(path), "--warn-only"]) == 1
    # a healthy document under the same pinned profile still passes
    ok = emit_bench.document([_healthy_point()])
    path.write_text(json.dumps(ok))
    assert bench_gate.main([str(path), "--warn-only"]) == 0


def _named_point(name, auto_ns):
    """A point whose auto/best ratio is auto_ns / 3.4e6 (xla is best)."""
    plan = _FakePlan("select", {"select": 1_000.0, "xla": 10_000.0})
    measured = {"xla": {"ns": 3.4e6, "bytes_moved": 0},
                "select": {"ns": max(auto_ns, 3.4e6), "bytes_moved": 0}}
    return emit_bench._point(name, "topk", 1 << 20, 64,
                             measured, auto_ns, plan)


def test_baseline_bounds_trajectory(tmp_path):
    """--baseline turns the gate into a drift check: a point the committed
    baseline already shows as noisy passes until it drifts past factor x
    its committed ratio; points absent from the baseline keep the absolute
    factor bound."""
    base = emit_bench.document([_named_point("a", 34e6),    # ratio 10
                                _named_point("b", 3.4e6)])  # ratio 1
    basep = tmp_path / "baseline.json"
    basep.write_text(json.dumps(base))
    doc = emit_bench.document([
        _named_point("a", 51e6),     # ratio 15 < 2x10: tolerated drift
        _named_point("b", 10.2e6),   # ratio 3 > 2x1: regression
        _named_point("c", 10.2e6),   # ratio 3, no baseline: factor bound
    ])
    violations, checked = bench_gate.check(doc, 2.0, base)
    assert checked == 3
    assert sorted(v["name"] for v in violations) == ["b", "c"]
    assert {v["name"]: v["why"] for v in violations} == {
        "b": "baseline", "c": "factor"}
    path = tmp_path / "run.json"
    path.write_text(json.dumps(doc))
    assert bench_gate.main([str(path), "--baseline", str(basep)]) == 1
    assert bench_gate.main([str(path), "--baseline", str(basep),
                            "--warn-only"]) == 0
    # without the baseline, "a" fails the absolute bound too
    violations, _ = bench_gate.check(doc, 2.0)
    assert sorted(v["name"] for v in violations) == ["a", "b", "c"]
    # a malformed baseline is a config error, not a silent pass
    badbase = tmp_path / "badbase.json"
    badbase.write_text(json.dumps({"schema": "nope/v9", "points": []}))
    assert bench_gate.main([str(path), "--baseline", str(badbase)]) == 2
