"""MSD radix-select: exact-k guarantees, tie convention, both hist engines.

The selection subsystem's contract is stricter than "same values as
lax.top_k": exactly k survive, ties resolve lowest-index-first (so the
indices match ``jax.lax.top_k`` bit-exactly), the kv variant carries the
payload through the same selection, and the Pallas per-tile histogram
kernel and the host scatter-add path agree element-exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sort as rsort
from repro.core import keycodec, sortspec
from repro.kernels import radix_select as rsel

DTYPES = ("float32", "int32", "uint16", "int8", "float16", "bfloat16")


def _keys(rng, dtype_name, shape, dist="uniform"):
    lo, hi = (0, 100) if dtype_name.startswith("uint") else (-100, 100)
    if dist == "dup_heavy":
        raw = rng.integers(0, 4, size=shape)
    elif dist == "all_equal":
        raw = np.full(shape, rng.integers(lo, hi))
    else:
        raw = rng.integers(lo, hi, size=shape)
    return jnp.asarray(raw).astype(jnp.dtype(dtype_name))


@pytest.mark.parametrize("dtype_name", DTYPES)
def test_select_matches_lax_top_k_bit_exactly(dtype_name):
    """(n, k) matrix kept deliberately lean: select_topk jit-specialises
    per (dtype, n, k), and every distribution reuses the same compiled
    program — broad randomised coverage lives in the fuzz top-k lens."""
    rng = np.random.default_rng(hash(dtype_name) % 2**32)
    for n in (5, 257):
        for k in sorted({1, n // 2, n}):
            for dist in ("uniform", "dup_heavy", "all_equal"):
                x = _keys(rng, dtype_name, (3, n), dist)
                v, i = rsel.select_topk(x, k)
                vr, ir = jax.lax.top_k(x, k)
                msg = f"{dtype_name}/{dist}/n={n}/k={k}"
                np.testing.assert_array_equal(
                    np.asarray(v).astype(np.float64),
                    np.asarray(vr).astype(np.float64), err_msg=msg)
                # indices too: exact-k tie rule == lax's lowest-index-first
                np.testing.assert_array_equal(np.asarray(i), np.asarray(ir),
                                              err_msg=msg)


def test_select_extreme_keys():
    """dtype-max / ±inf / ±0.0 keys: the keycodec's total order keeps the
    selection exact where a float threshold compare would fold -0.0/+0.0
    and saturate at inf."""
    x = jnp.asarray([[np.inf, -np.inf, 0.0, -0.0, 1.0,
                      float(np.finfo(np.float32).max), -1.0, np.inf]],
                    jnp.float32)
    for k in (1, 3, 8):
        v, i = rsel.select_topk(x, k)
        vr, ir = jax.lax.top_k(x, k)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    xi = jnp.asarray([[np.iinfo(np.int32).max, np.iinfo(np.int32).min,
                       0, -1, np.iinfo(np.int32).max]], jnp.int32)
    v, i = rsel.select_topk(xi, 3)
    np.testing.assert_array_equal(np.asarray(i),
                                  np.asarray(jax.lax.top_k(xi, 3)[1]))


def test_select_kv_payload_rides_selection():
    rng = np.random.default_rng(3)
    keys = _keys(rng, "float32", (2, 67), "dup_heavy")
    payload = jnp.asarray(rng.integers(-9, 9, (2, 67)).astype(np.int32))
    v, pv, i = rsel.select_topk_kv(keys, payload, 13)
    vr, ir = jax.lax.top_k(keys, 13)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(
        np.asarray(pv),
        np.take_along_axis(np.asarray(payload), np.asarray(ir), -1))
    with pytest.raises(ValueError, match="must match"):
        rsel.select_topk_kv(keys, payload[:, :5], 3)


def test_kernel_and_host_refinements_agree():
    """The digit-serial Pallas histogram path (interpret mode) and the
    host bit-serial path produce identical selections.  int8 keys keep the
    interpret-mode kernel cheap (one digit pass) while n=300 exercises
    tile padding; int32/n=40 covers the multi-pass single-tile shape."""
    rng = np.random.default_rng(5)
    for dtype_name, n, ks in (("int8", 300, (1, 100)), ("int32", 40, (13,))):
        x = _keys(rng, dtype_name, (2, n), "dup_heavy")
        for k in ks:
            vk, ik = rsel.select_topk(x, k, use_kernel=True, interpret=True)
            vh, ih = rsel.select_topk(x, k, use_kernel=False)
            np.testing.assert_array_equal(np.asarray(vk), np.asarray(vh),
                                          err_msg=f"n={n} k={k}")
            np.testing.assert_array_equal(np.asarray(ik), np.asarray(ih),
                                          err_msg=f"n={n} k={k}")


def test_kth_key_threshold_and_tie_budget():
    """The refinement loop pins the k-th smallest encoded key and the
    residual tie budget r = k - #{enc < T} exactly."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, 5, (4, 50)).astype(np.int32))
    enc = keycodec.encode(x, descending=True)
    for k in (1, 10, 50):
        thresh, r = rsel.kth_key_encoded(enc, k)
        se = np.sort(np.asarray(enc), -1)
        np.testing.assert_array_equal(np.asarray(thresh), se[:, k - 1])
        less = (np.asarray(enc) < np.asarray(thresh)[:, None]).sum(-1)
        np.testing.assert_array_equal(np.asarray(r), k - less)


def test_select_backend_front_door_and_spec_validation():
    """method="select" through repro.sort: top-k runs, plain sorts are a
    clear spec-layer error (selection-only backend)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 100)), jnp.float32)
    v, i = rsort.topk(x, 7, method="select")
    vr, ir = jax.lax.top_k(x, 7)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    with pytest.raises(ValueError, match="selection-only"):
        rsort.sort(x, method="select")
    with pytest.raises(ValueError, match="selection-only"):
        rsort.argsort(x, method="select")
    with pytest.raises(ValueError, match="1 <= k <= n"):
        rsort.topk(x, 0, method="select")
    caps = sortspec.get_backend("select").capabilities
    assert caps.selection and not caps.supports_sort


def test_select_under_jit_and_vs_sort_prefix():
    """jit-compatible (static k) and equal to the registry's sort-prefix
    route on a workload where both are exact."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(-1000, 1000, (1, 4096)).astype(np.int32))
    f = jax.jit(lambda v: rsel.select_topk(v, 32))
    v, i = f(x)
    vs, _ = rsort.topk(x, 32, method="xla")
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vs))
