"""The legacy sort_api surface: warn once, forward bit-exactly.

Migration contract for the v1 call forms: every shim (a) emits exactly one
``DeprecationWarning`` per process — first call warns, repeats stay silent
so a hot serving loop is not spammed — and (b) forwards each kwarg
combination unchanged to the ``repro.sort`` front door, producing
bit-identical arrays.  ``top_p_mask`` and the shared implementation pieces
(``bitonic_sort``, ``_xla_sort``) are deliberately un-deprecated.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest
from jax import tree_util

import repro.sort as rsort
from repro.core import sort_api


@pytest.fixture(autouse=True)
def _fresh_warn_state():
    """Each test sees a process that has never warned yet."""
    sort_api._warned.clear()
    yield
    sort_api._warned.clear()


def _caught(fn):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    return out, dep


X = jnp.asarray(np.random.default_rng(0).standard_normal((3, 41)),
                jnp.float32)


@pytest.mark.parametrize("name,call,equiv", [
    ("sort",
     lambda: sort_api.sort(X, axis=0, method="bitonic", descending=True),
     lambda: rsort.sort(X, axis=0, method="bitonic", descending=True)),
    ("argsort",
     lambda: sort_api.argsort(X, axis=-1, method="radix", descending=True),
     lambda: rsort.argsort(X, axis=-1, method="radix", descending=True)),
    ("topk",
     lambda: sort_api.topk(X, 7, method="pallas"),
     lambda: rsort.topk(X, 7, method="pallas")),
])
def test_shim_warns_once_and_forwards_kwargs_bit_exactly(name, call, equiv):
    out1, dep1 = _caught(call)
    assert len(dep1) == 1, f"{name}: first call must warn exactly once"
    assert f"sort_api.{name} is deprecated" in str(dep1[0].message)
    assert f"repro.sort.{name}" in str(dep1[0].message)
    out2, dep2 = _caught(call)
    assert dep2 == [], f"{name}: repeat calls must stay silent"
    ref = equiv()
    for a, b, c in zip(tree_util.tree_leaves(out1),
                       tree_util.tree_leaves(out2),
                       tree_util.tree_leaves(ref)):
        ra, rb, rc = np.asarray(a), np.asarray(b), np.asarray(c)
        np.testing.assert_array_equal(ra, rc, err_msg=name)
        np.testing.assert_array_equal(rb, rc, err_msg=name)
        assert ra.dtype == rc.dtype


def test_each_shim_warns_independently():
    """The once-latch is per call form, not global: using sort must not
    swallow argsort's warning."""
    _, dep = _caught(lambda: sort_api.sort(X))
    assert len(dep) == 1
    _, dep = _caught(lambda: sort_api.argsort(X))
    assert len(dep) == 1
    _, dep = _caught(lambda: sort_api.topk(X, 3))
    assert len(dep) == 1


def test_shim_defaults_match_v1_not_v2():
    """v1 defaulted to method='xla'; the shims must preserve that even
    though the v2 front door defaults to 'auto'."""
    out, _ = _caught(lambda: sort_api.sort(X))
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(rsort.sort(X, method="xla")))


def test_shim_propagates_spec_validation():
    """Forwarding is exact for errors too: bad k dies at the spec layer
    with the same message the front door raises."""
    with pytest.raises(ValueError, match="1 <= k <= n"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            sort_api.topk(X, 0)


def test_unwarned_helpers_stay_silent():
    _, dep = _caught(lambda: sort_api.bitonic_sort(X))
    assert dep == []
    _, dep = _caught(lambda: sort_api.top_p_mask(X, 0.9))
    assert dep == []
