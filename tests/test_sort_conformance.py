"""Cross-backend conformance sweep — the net for signed/float/tie bugs.

Every backend must agree with the numpy reference on sorted *values* for
every dtype it supports, and every argsort backend must agree on the unified
tie convention (ties keep ascending index order, in both directions).  The
two regression vectors from the signed-int / descending-tie bug reports live
here too, verbatim.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sort_api

# inputs deliberately include negatives, ±0.0, extremes, and heavy ties
_N = 600


def _input(dtype, rng):
    if np.issubdtype(dtype, np.floating):
        x = np.round(rng.standard_normal((2, _N)) * 3).astype(dtype)
        x[0, ::7] = 0.0
        x[0, 1::7] = -0.0
        x[1, ::11] = np.inf
        x[1, 1::11] = -np.inf
        return x
    info = np.iinfo(dtype)
    x = rng.integers(max(info.min, -7), min(info.max, 8),
                     size=(2, _N)).astype(dtype)     # heavy ties
    x[0, 0], x[0, 1] = info.min, info.max
    return x


# imc is deliberately absent from the sweep: the cycle-accurate simulator
# targets N≈8 and would take hours at _N; its signed-key regression tests
# below cover it at the paper's scale
_SWEEP_METHODS = ("xla", "bitonic", "pallas", "merge", "radix", "auto")


def _ref_argsort(x, descending):
    n = x.shape[-1]
    if descending:
        return n - 1 - np.flip(np.argsort(np.flip(x, -1), -1, kind="stable"),
                               -1)
    return np.argsort(x, -1, kind="stable")


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32, np.uint8,
                                   np.uint32, np.float32])
@pytest.mark.parametrize("descending", [False, True])
def test_sort_values_agree_with_numpy(dtype, descending):
    rng = np.random.default_rng(hash((dtype.__name__, descending)) % 2**31)
    x = _input(dtype, rng)
    ref = np.sort(x, -1)
    if descending:
        ref = np.flip(ref, -1)
    for method in _SWEEP_METHODS:
        out = np.asarray(sort_api.sort(jnp.asarray(x), method=method,
                                       descending=descending))
        np.testing.assert_array_equal(out, ref, err_msg=method)


@pytest.mark.parametrize("dtype", [np.int32, np.uint8, np.float32])
@pytest.mark.parametrize("descending", [False, True])
def test_argsort_ties_keep_ascending_index(dtype, descending):
    """The unified tie convention across every argsort backend.

    Integer inputs with heavy ties; float inputs use tie values with a
    single bit pattern (no ±0.0 — the radix codec orders -0.0 < +0.0 while
    comparison backends treat them equal, both value-correct).
    """
    rng = np.random.default_rng(hash((dtype.__name__, descending, 1)) % 2**31)
    if np.issubdtype(dtype, np.floating):
        x = rng.integers(-4, 5, size=(2, _N)).astype(dtype)
    else:
        x = _input(dtype, rng)
    ref = _ref_argsort(x, descending)
    for method in ("xla", "bitonic", "pallas", "merge", "radix", "auto"):
        order = np.asarray(sort_api.argsort(jnp.asarray(x), method=method,
                                            descending=descending))
        np.testing.assert_array_equal(order, ref, err_msg=method)


def test_regression_imc_signed_int_vector():
    """The confirmed bug: imc on int32 with negatives returned
    [[0,1,2,3,7,-5,-2,-1]] (two's-complement bits sorted as unsigned)."""
    x = jnp.asarray([[3, -1, 2, -5, 0, 7, -2, 1]], jnp.int32)
    out = np.asarray(sort_api.sort(x, method="imc"))
    np.testing.assert_array_equal(out, [[-5, -2, -1, 0, 1, 2, 3, 7]])


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
def test_regression_imc_signed_dtypes(dtype):
    rng = np.random.default_rng(41)
    x = rng.integers(np.iinfo(dtype).min, np.iinfo(dtype).max,
                     size=(3, 8), dtype=dtype, endpoint=True)
    out = np.asarray(sort_api.sort(jnp.asarray(x), method="imc"))
    np.testing.assert_array_equal(out, np.sort(x, -1))


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.uint8])
@pytest.mark.parametrize("descending", [False, True])
def test_imc_argsort_conformance_small_n(dtype, descending):
    """The imc argsort gap fix: the bit-serial sorter runs on an encoded
    (key, index) composite, so the (unstable) network still lands on the
    unified tie convention at the paper's N≈8 scale."""
    import zlib
    rng = np.random.default_rng(
        zlib.crc32(f"{dtype.__name__}/{descending}".encode()))
    x = rng.integers(-4, 5, size=(3, 8)).astype(dtype)     # heavy ties
    if np.issubdtype(dtype, np.unsignedinteger):
        x = np.abs(x).astype(dtype)
    order = np.asarray(sort_api.argsort(jnp.asarray(x), method="imc",
                                        descending=descending))
    np.testing.assert_array_equal(order, _ref_argsort(x, descending))


def test_regression_descending_argsort_tie_order():
    """The confirmed bug: xla descending argsort returned ties in reverse
    index order ([[2,1,3,0]]) where the engine returns [[1,2,0,3]]."""
    x = jnp.asarray([[1.0, 5.0, 5.0, 1.0]], jnp.float32)
    for method in ("xla", "bitonic", "pallas", "radix"):
        order = np.asarray(sort_api.argsort(x, method=method,
                                            descending=True))
        np.testing.assert_array_equal(order, [[1, 2, 0, 3]], err_msg=method)
    from repro import engine
    order = np.asarray(engine.argsort(x, descending=True, stable=True,
                                      method="merge", run_len=2))
    np.testing.assert_array_equal(order, [[1, 2, 0, 3]])


def test_all_equal_keys_identity_permutation():
    x = jnp.zeros((1, 257), jnp.float32)
    for method in ("xla", "bitonic", "pallas", "merge", "radix"):
        for descending in (False, True):
            order = np.asarray(sort_api.argsort(x, method=method,
                                                descending=descending))
            np.testing.assert_array_equal(order, np.arange(257)[None, :],
                                          err_msg=f"{method}/{descending}")
