"""API v2: SortSpec validation, backend registry truthfulness, plan cache.

The capability sweep is the drift net: every registered backend's declared
``Capabilities`` are exercised — each claimed dtype must actually sort
correctly, claimed stability must survive a tie-order check, claimed kv /
top-k support must round-trip — so a backend whose declaration rots fails
CI here, not in production dispatch.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sort as rsort
from repro.core import sort_api, sortspec
from repro.core.backends import COMPARABLE_DTYPES
from repro.engine import planner


def _keys(dtype_name: str, shape, rng):
    """Small-integer-valued keys exactly representable in every dtype."""
    raw = rng.integers(-7, 8, size=shape)
    if dtype_name.startswith("uint"):
        raw = np.abs(raw)
    return jnp.asarray(raw).astype(jnp.dtype(dtype_name))


def _n_for(backend) -> int:
    # the bit-serial simulator targets the paper's N≈8; everything else
    # gets a size that exercises padding (non-power-of-two)
    return 8 if backend.capabilities.substrate == "sram" else 33


def _claimed_dtypes(backend):
    caps = backend.capabilities
    return sorted(caps.dtypes) if caps.dtypes is not None \
        else sorted(COMPARABLE_DTYPES)


@pytest.mark.parametrize(
    "name",
    # the interpret-mode pallas sweep is the suite's slowest single case
    # (~30s on CPU); it keeps full coverage under ``-m slow``
    [pytest.param(n, marks=pytest.mark.slow) if n == "pallas" else n
     for n in sorted(sortspec.backend_names())])
def test_capabilities_dtype_claims_are_truthful(name):
    backend = sortspec.get_backend(name)
    n = _n_for(backend)
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    for dtype_name in _claimed_dtypes(backend):
        x = _keys(dtype_name, (2, n), rng)
        if not backend.capabilities.supports_sort:
            # selection-only engines prove their dtype claims through
            # top-k instead (exercised below and in the top-k lens)
            ref = np.flip(np.sort(np.asarray(x).astype(np.float64), -1), -1)
            v, _ = backend.topk(x, n)
            np.testing.assert_array_equal(
                np.asarray(v).astype(np.float64), ref,
                err_msg=f"{name}/{dtype_name}/topk")
            continue
        ref = np.sort(np.asarray(x).astype(np.float64), -1)
        for descending in (False, True):
            out = np.asarray(backend.sort(x, descending=descending)
                             ).astype(np.float64)
            np.testing.assert_array_equal(
                out, np.flip(ref, -1) if descending else ref,
                err_msg=f"{name}/{dtype_name}/desc={descending}")


@pytest.mark.parametrize("name", sorted(sortspec.backend_names()))
def test_capabilities_stability_claims_are_truthful(name):
    backend = sortspec.get_backend(name)
    if not backend.capabilities.stable:
        pytest.skip(f"{name} does not claim stability")
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 4, (2, 65)).astype(np.int32))
    payload = jnp.broadcast_to(jnp.arange(65, dtype=jnp.int32), keys.shape)
    for descending in (False, True):
        _, perm = backend.sort_kv(keys, payload, descending=descending)
        k = np.asarray(keys)
        if descending:
            ref = 65 - 1 - np.flip(np.argsort(np.flip(k, -1), -1,
                                              kind="stable"), -1)
        else:
            ref = np.argsort(k, -1, kind="stable")
        np.testing.assert_array_equal(np.asarray(perm), ref,
                                      err_msg=f"{name}/desc={descending}")


@pytest.mark.parametrize("name", sorted(sortspec.backend_names()))
def test_capabilities_kv_and_topk_claims_are_truthful(name):
    backend = sortspec.get_backend(name)
    caps = backend.capabilities
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 40)).astype(np.float32)) \
        if "float32" in _claimed_dtypes(backend) \
        else _keys(_claimed_dtypes(backend)[0], (2, 40), rng)
    if caps.supports_kv:
        payload = jnp.broadcast_to(jnp.arange(40, dtype=jnp.int32), x.shape)
        sk, sv = backend.sort_kv(x, payload, descending=False)
        np.testing.assert_array_equal(np.sort(np.asarray(x), -1),
                                      np.asarray(sk), err_msg=name)
        np.testing.assert_array_equal(
            np.take_along_axis(np.asarray(x), np.asarray(sv), -1),
            np.asarray(sk), err_msg=name)
    else:
        with pytest.raises(NotImplementedError):
            backend.sort_kv(x, x)
    if caps.supports_topk:
        vr, _ = jax.lax.top_k(x, 7)
        v, i = backend.topk(x, 7)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vr),
                                      err_msg=name)
        np.testing.assert_array_equal(
            np.take_along_axis(np.asarray(x), np.asarray(i), -1),
            np.asarray(vr), err_msg=name)


def test_argsort_tie_convention_every_backend():
    """Ties keep ascending index order in both directions — including the
    imc composite path (narrow keys, paper-scale n)."""
    rng = np.random.default_rng(11)
    for name in sortspec.backend_names():
        backend = sortspec.get_backend(name)
        n = _n_for(backend)
        x = _keys("int8", (2, n), rng)
        for descending in (False, True):
            try:
                order = np.asarray(backend.argsort(x, descending=descending))
            except NotImplementedError:
                continue
            k = np.asarray(x)
            if descending:
                ref = n - 1 - np.flip(np.argsort(np.flip(k, -1), -1,
                                                 kind="stable"), -1)
            else:
                ref = np.argsort(k, -1, kind="stable")
            np.testing.assert_array_equal(
                order, ref, err_msg=f"{name}/desc={descending}")


# ---------------------------------------------------------------------------
# spec validation — every front-door error raised in one place
# ---------------------------------------------------------------------------

def test_topk_k_out_of_range_raises_everywhere():
    """Regression: k < 1 / k > n used to slice silently or die deep inside
    a kernel; now it is one clear ValueError at the spec layer for every
    backend (and the legacy shim)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16)),
                    jnp.float32)
    for method in sortspec.backend_names() + ("auto",):
        for bad_k in (0, -3, 17):
            with pytest.raises(ValueError, match="1 <= k <= n"):
                rsort.topk(x, bad_k, method=method)
    with pytest.raises(ValueError, match="1 <= k <= n"):
        sort_api.topk(x, 999)
    # the boundary values are fine
    v, _ = rsort.topk(x, 16, method="xla")
    assert v.shape == (2, 16)
    v, _ = rsort.topk(x, 1, method="xla")
    assert v.shape == (2, 1)


def test_spec_validation_errors():
    x = jnp.zeros((2, 8), jnp.float32)
    with pytest.raises(ValueError, match="axis"):
        rsort.sort(x, axis=2)
    with pytest.raises(ValueError, match="method must be one of"):
        rsort.sort(x, method="nope")
    with pytest.raises(ValueError, match="not both"):
        sortspec.SortSpec(values=x, indices=True).canonical(x)
    with pytest.raises(ValueError, match="mutually exclusive"):
        sortspec.SortSpec(segment_ids=jnp.zeros(8, jnp.int32),
                          valid_lengths=jnp.ones(2)).canonical(x)
    with pytest.raises(ValueError, match="shape"):
        sortspec.SortSpec(values=jnp.zeros((2, 9))).canonical(x)
    with pytest.raises(ValueError, match="segment_ids or row_splits"):
        rsort.segment_sort(jnp.zeros(8))


def test_sort_kv_payload_survives_sentinel_keys():
    """Regression: bitonic/pallas kv paths padded with (sentinel key, n)
    pairs, so a genuine dtype-max key let the pad marker displace a real
    payload.  The kv front door now argsorts a (key, index) composite and
    gathers, so arbitrary payloads survive on every backend."""
    keys = jnp.asarray([[0, np.iinfo(np.int32).max, 1]], jnp.int32)
    payload = jnp.asarray([[10, 99, 20]], jnp.int32)
    for name in sorted(sortspec.backend_names()):
        be = sortspec.get_backend(name)
        if not be.capabilities.supports_kv:
            continue
        sk, sv = be.sort_kv(keys, payload)
        np.testing.assert_array_equal(np.asarray(sk),
                                      [[0, 1, np.iinfo(np.int32).max]],
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(sv), [[10, 20, 99]],
                                      err_msg=name)
    # float +inf keys through the front door
    fk = jnp.asarray([[0.0, np.inf, 1.0]], jnp.float32)
    for method in ("bitonic", "pallas", "xla", "radix"):
        _, sv = rsort.sort_kv(fk, payload, method=method)
        np.testing.assert_array_equal(np.asarray(sv), [[10, 20, 99]],
                                      err_msg=method)


def test_topk_spec_rejects_payload_and_stable():
    """k returns (values, indices) on its own; combining it with a payload
    or stability flag used to silently drop those fields."""
    x = jnp.zeros((2, 8), jnp.float32)
    with pytest.raises(ValueError, match="do not combine with k"):
        rsort.run(sortspec.SortSpec(k=2, values=jnp.zeros((2, 8))), x)
    with pytest.raises(ValueError, match="do not combine with k"):
        rsort.run(sortspec.SortSpec(k=2, stable=True), x)
    with pytest.raises(ValueError, match="do not combine with k"):
        rsort.run(sortspec.SortSpec(k=2, indices=True), x)
    # and a spec-built top-k is canonically descending
    assert sortspec.SortSpec(k=2).canonical(x).descending is True


def test_unsupported_ops_fail_at_the_spec_layer():
    """Capability gaps surface as one clear ValueError up front, not a
    NotImplementedError deep inside a backend."""
    xi = jnp.asarray(np.arange(8, dtype=np.int8))
    with pytest.raises(ValueError, match="does not support top-k"):
        rsort.topk(xi, 2, method="imc")
    with pytest.raises(ValueError, match="key-value payloads"):
        rsort.sort_kv(xi, jnp.arange(8, dtype=jnp.int32), method="imc")
    with pytest.raises(ValueError, match="segmented"):
        rsort.segment_sort(xi, segment_ids=jnp.zeros(8, jnp.int32),
                           method="imc")


def test_sort_defaults_context():
    x = jnp.zeros((2, 8), jnp.float32)
    assert sortspec.SortSpec().canonical(x).method == "auto"
    with rsort.sort_defaults(method="bitonic", run_len=4096):
        spec = sortspec.SortSpec().canonical(x)
        assert spec.method == "bitonic" and spec.run_len == 4096
        with rsort.sort_defaults(method="xla"):       # nesting shadows
            assert sortspec.SortSpec().canonical(x).method == "xla"
        assert sortspec.SortSpec().canonical(x).method == "bitonic"
    assert sortspec.SortSpec().canonical(x).method == "auto"
    with pytest.raises(ValueError, match="sort_defaults accepts"):
        with rsort.sort_defaults(bogus=1):
            pass


# ---------------------------------------------------------------------------
# registry plumbing
# ---------------------------------------------------------------------------

def test_third_party_backend_is_a_drop_in():
    """The api_redesign acceptance: a new engine registers and is planned,
    priced, and callable with zero planner / front-door edits."""

    class SnailSortBackend(sortspec.SortBackend):
        name = "snail"
        capabilities = sortspec.Capabilities(stable=True, max_n=1 << 10)

        def sort(self, rows, *, descending=False, plan=None, interpret=None):
            out = jnp.sort(rows, axis=-1)
            return jnp.flip(out, -1) if descending else out

        def sort_kv(self, keys, values, *, descending=False, plan=None,
                    interpret=None):
            order = jnp.argsort(keys, axis=-1, stable=True,
                                descending=descending)
            return (jnp.take_along_axis(keys, order, -1),
                    jnp.take_along_axis(values, order, -1))

    sortspec.register_backend(SnailSortBackend)
    try:
        assert "snail" in sortspec.backend_names()
        # generic eligibility from the declared capabilities
        assert planner._eligible("snail", 512, jnp.dtype(jnp.float32), 128)
        assert not planner._eligible("snail", 4096, jnp.dtype(jnp.float32),
                                     128)
        # priced by the planner (default cost: +inf, never beats built-ins)
        plan = planner.choose(512, 1)
        assert plan.costs["snail"] == float("inf")
        assert plan.method != "snail"
        # but explicitly requestable through every front door
        x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 100)),
                        jnp.float32)
        out = np.asarray(rsort.sort(x, method="snail"))
        np.testing.assert_array_equal(out, np.sort(np.asarray(x), -1))
        order = np.asarray(rsort.argsort(x, method="snail", descending=True))
        np.testing.assert_array_equal(
            np.take_along_axis(np.asarray(x), order, -1),
            np.flip(np.sort(np.asarray(x), -1), -1))
    finally:
        sortspec.unregister_backend("snail")
    with pytest.raises(ValueError, match="method must be one of"):
        rsort.sort(jnp.zeros((1, 4)), method="snail")


def test_plan_cache_hits_and_invalidation():
    planner.clear_plan_cache()
    p1 = planner.choose_cached(100000, 1, jnp.float32)
    assert planner.choose_cached(100000, 1, jnp.float32) is p1   # cache hit
    assert planner.choose_cached(100000, 2, jnp.float32) is not p1
    # registering a backend re-plans (the new engine may now win)
    class NopBackend(sortspec.SortBackend):
        name = "nop-test"
    sortspec.register_backend(NopBackend)
    try:
        p2 = planner.choose_cached(100000, 1, jnp.float32)
        assert p2 is not p1 and "nop-test" in p2.costs
    finally:
        sortspec.unregister_backend("nop-test")
    planner.clear_plan_cache()
    assert planner.choose_cached(100000, 1, jnp.float32) is not p1


def test_spec_static_key_is_hashable_and_value_free():
    spec = sortspec.SortSpec(values=jnp.zeros((2, 8)), descending=True)
    k1 = spec.static_key((2, 8), jnp.float32)
    k2 = sortspec.SortSpec(values=jnp.ones((2, 8)),
                           descending=True).static_key((2, 8), jnp.float32)
    assert k1 == k2 and hash(k1) == hash(k2)    # payload values don't plan
    assert k1 != spec.static_key((2, 16), jnp.float32)


# ---------------------------------------------------------------------------
# legacy surface
# ---------------------------------------------------------------------------

def test_sort_api_shims_forward_and_warn():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 50)),
                    jnp.float32)
    sort_api._warned.clear()
    with pytest.deprecated_call():
        out = sort_api.sort(x, method="bitonic", descending=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(rsort.sort(x, method="bitonic",
                                               descending=True)))
    np.testing.assert_array_equal(
        np.asarray(sort_api.argsort(x, method="radix")),
        np.asarray(rsort.argsort(x, method="radix")))
    v1, i1 = sort_api.topk(x, 5, method="pallas")
    v2, i2 = rsort.topk(x, 5, method="pallas")
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_top_p_mask_axis_and_method_passthrough():
    x = jnp.asarray(np.random.default_rng(7).standard_normal((4, 50)) * 3,
                    jnp.float32)
    base = sort_api.top_p_mask(x, 0.9)                       # auto default
    for method in ("xla", "bitonic", "radix"):
        np.testing.assert_array_equal(
            np.asarray(sort_api.top_p_mask(x, 0.9, method=method)),
            np.asarray(base), err_msg=method)
    swapped = sort_api.top_p_mask(x.T, 0.9, axis=0)
    np.testing.assert_array_equal(np.asarray(swapped).T, np.asarray(base))
