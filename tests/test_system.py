"""End-to-end system behaviour: training runs converge, checkpoints resume
bit-exactly, serving schedules and decodes, distributed sort works on a
multi-device mesh (subprocess: needs its own device count)."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import train
    losses = train("deepseek-67b", smoke=True, steps=25, batch=4, seq=64,
                   lr=3e-3, ckpt_dir="", log_every=100)
    assert losses[-1] < losses[0]


def test_train_resume_continues_step_count(tmp_path):
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    train("gemma-2b", smoke=True, steps=6, batch=2, seq=32, ckpt_dir=d,
          ckpt_every=3, log_every=100)
    losses = train("gemma-2b", smoke=True, steps=10, batch=2, seq=32,
                   ckpt_dir=d, ckpt_every=100, log_every=100)
    assert len(losses) == 4      # resumed at step 6, ran 6..9


def test_serve_end_to_end():
    from repro.launch.serve import serve
    done, stats = serve("minitron-4b", smoke=True, n_requests=6,
                        batch_size=3, decode_steps=8, topk=10)
    assert len(done) == 6
    assert all(r.out is not None and len(r.out) == 8 for r in done)
    assert stats["batches"] == 2


def test_scheduler_never_starves_long_prompts():
    """Aging regression: under sustained load of short prompts, a long
    prompt used to sit at the tail of the length-sorted queue forever
    (next_batch always took the k shortest).  Anchoring each batch at the
    oldest queued request bounds the wait: the long prompt must be served
    in the FIRST batch after it becomes the oldest, even though shorter
    fresh arrivals keep overtaking it in length order."""
    from repro.launch.serve import LengthSortedScheduler, Request
    sched = LengthSortedScheduler(batch_size=4)
    sched.submit(Request(rid=0, prompt=np.zeros(500, np.int32)))   # long
    rng = np.random.default_rng(7)
    rid = 1
    for _ in range(4):                          # sustained short traffic
        sched.submit(Request(rid=rid, prompt=np.zeros(
            int(rng.integers(4, 16)), np.int32)))
        rid += 1
    # the long prompt is the oldest -> it anchors the very FIRST batch
    batch = sched.next_batch()
    assert any(r.rid == 0 for r in batch), \
        "long prompt starved: oldest request missing from its batch"
    # the fill is its adjacent-length neighbours (the longest shorts),
    # keeping the batch as length-homogeneous as the anchor allows
    batch_lens = sorted(len(r.prompt) for r in batch if r.rid != 0)
    left_lens = sorted(len(r.prompt) for r in sched.queue)
    assert all(b >= l for b in batch_lens for l in left_lens)
    # steady state: every subsequent batch also serves its then-oldest
    while sched.queue:
        oldest = sched.queue[0].rid
        nxt = sched.next_batch()
        assert any(r.rid == oldest for r in nxt)


def test_microbatched_step_matches_single_batch():
    """Gradient accumulation must not change the training trajectory."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeSpec, get_smoke_config
    from repro.launch import steps as steps_lib
    from repro.models import build

    cfg = get_smoke_config("minitron_4b")
    model = build(cfg, policy=None, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    shape = ShapeSpec("t", 32, 4, "train")
    outs = {}
    for mb in (1, 2):
        fn, opt = steps_lib.make_train_step(model, cfg, shape, None,
                                            microbatch=mb, peak_lr=1e-3)
        st = opt.init(params)
        p2, st2, m = fn(params, st, jnp.asarray(0), batch)
        outs[mb] = (m["loss"], p2)
    assert float(outs[1][0]) == pytest.approx(float(outs[2][0]), rel=1e-4)
    l1 = jax.tree.leaves(outs[1][1])
    l2 = jax.tree.leaves(outs[2][1])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_distributed_sort_multidevice_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import distributed_sort as ds
mesh = jax.make_mesh((8,), ("data",))
x = np.random.default_rng(0).standard_normal(8 * 128).astype(np.float32)
xs = jax.device_put(x, NamedSharding(mesh, P("data")))
out = ds.distributed_sort(xs, mesh)
assert np.allclose(np.array(out), np.sort(x))
print("DIST_SORT_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=300)
    assert "DIST_SORT_OK" in r.stdout, r.stderr[-2000:]


def test_sharded_train_step_multidevice_subprocess():
    """A tiny model trained on a REAL 2x2 (data x model) mesh: the same
    sharding rules the 512-way dry-run uses, executed for real."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ShapeSpec, get_smoke_config
from repro.launch import steps as steps_lib
from repro.models import build
from repro.sharding.partitioning import ShardingPolicy

mesh = jax.make_mesh((2, 2), ("data", "model"))
policy = ShardingPolicy(mesh=mesh, dp_axes=("data",))
cfg = get_smoke_config("deepseek_67b")
model = build(cfg, policy=policy, remat=True)
key = jax.random.PRNGKey(0)
params_abs, specs = steps_lib.abstract_init(model, key)
specs = steps_lib.sanitize_specs(specs, params_abs, mesh)
psh = steps_lib.shardings_of(specs, mesh)
shape = ShapeSpec("t", 32, 4, "train")
fn, opt = steps_lib.make_train_step(model, cfg, shape, policy, microbatch=2,
                                    peak_lr=2e-2, total_steps=30)
params = jax.jit(lambda k: model.init(k)[0], out_shardings=psh)(key)
opt_abs = jax.eval_shape(opt.init, params_abs)
osp = steps_lib.sanitize_specs(opt.state_specs(specs, params_abs), opt_abs, mesh)
osh = steps_lib.shardings_of(osp, mesh)
state = jax.jit(opt.init, out_shardings=osh)(params)
batch = {
  "tokens": jax.device_put(np.random.randint(0, cfg.vocab_size, (4, 32)).astype(np.int32), NamedSharding(mesh, P("data", None))),
  "labels": jax.device_put(np.random.randint(0, cfg.vocab_size, (4, 32)).astype(np.int32), NamedSharding(mesh, P("data", None))),
}
step = jax.jit(fn, in_shardings=(psh, osh, NamedSharding(mesh, P()), None), out_shardings=(psh, osh, None))
losses = []
for i in range(8):
    params, state, m = step(params, state, jnp.asarray(i), batch)
    losses.append(float(m["loss"]))
assert losses[-1] < losses[0] - 0.05, losses
print("SHARDED_TRAIN_OK", losses[0], losses[-1])
"""
    r = subprocess.run([sys.executable, "-c", code], env=ENV,
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_TRAIN_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
