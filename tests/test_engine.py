"""repro.engine: runs + merge-path tree + planner vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import sort_api, tuning
from repro.engine import merge as engine_merge
from repro.engine import planner, runs, segmented


def _rand(rng, shape, dtype):
    if np.issubdtype(dtype, np.floating):
        return (rng.standard_normal(shape) * 100).astype(dtype)
    info = np.iinfo(dtype)
    return rng.integers(info.min, info.max, size=shape, dtype=dtype,
                        endpoint=True)


# ---------------------------------------------------------------------------
# engine.sort — bit-exact vs np.sort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
@pytest.mark.parametrize("shape", [(1, 3000), (4, 5000), (2, 3, 4100),
                                   (1, 65536)])
@pytest.mark.parametrize("descending", [False, True])
def test_engine_sort_bit_exact(dtype, shape, descending):
    x = _rand(np.random.default_rng(hash((str(dtype), shape)) % 2**31),
              shape, dtype)
    out = np.array(engine.sort(jnp.asarray(x), method="merge",
                               descending=descending))
    ref = np.sort(x, -1)
    if descending:
        ref = np.flip(ref, -1)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("dtype", [np.int32, np.uint32, np.float32])
def test_engine_sort_million_elements(dtype):
    n = (1 << 20) + 77                       # > 1M and non-power-of-two
    x = _rand(np.random.default_rng(11), (n,), dtype)
    out = np.array(engine.sort(jnp.asarray(x), method="merge"))
    np.testing.assert_array_equal(out, np.sort(x))


def test_engine_sort_extreme_values_survive_padding():
    """Sentinel-valued data (int max/min, inf) must still sort bit-exactly."""
    rng = np.random.default_rng(3)
    x = rng.integers(0, 100, size=5000).astype(np.int32)
    x[::97] = np.iinfo(np.int32).max
    x[1::97] = np.iinfo(np.int32).min
    out = np.array(engine.sort(jnp.asarray(x), method="merge"))
    np.testing.assert_array_equal(out, np.sort(x))


def test_engine_sort_small_run_len_deep_tree():
    x = np.random.default_rng(5).standard_normal(10000).astype(np.float32)
    out = np.array(engine.sort(jnp.asarray(x), method="merge", run_len=128))
    np.testing.assert_array_equal(out, np.sort(x))


def test_run_layout_rounds_run_len_to_pow2():
    """Regression: a non-power-of-two run_len must not reach the Pallas
    tile sort / merge kernel, which address power-of-two rows."""
    n_tiles, padded = runs.run_layout(10000, 100)
    assert padded // n_tiles == 128
    x = np.random.default_rng(6).standard_normal(10000).astype(np.float32)
    out = np.array(engine.sort(jnp.asarray(x), method="merge", run_len=100))
    np.testing.assert_array_equal(out, np.sort(x))


def test_engine_sort_axis_handling():
    x = np.random.default_rng(7).standard_normal((3000, 4)).astype(np.float32)
    out = np.array(engine.sort(jnp.asarray(x), axis=0, method="merge"))
    np.testing.assert_array_equal(out, np.sort(x, 0))


def test_engine_sort_is_differentiable():
    x = jnp.asarray(np.random.default_rng(9).standard_normal(4096),
                    jnp.float32)
    g = jax.grad(lambda v: engine.sort(v, method="merge")[-16:].sum())(x)
    exp = np.zeros(4096, np.float32)
    exp[np.argsort(np.array(x))[-16:]] = 1.0
    np.testing.assert_allclose(np.array(g), exp)


# ---------------------------------------------------------------------------
# argsort / topk
# ---------------------------------------------------------------------------

def test_engine_argsort_valid_permutation():
    x = np.random.default_rng(13).standard_normal((3, 9000)).astype(np.float32)
    order = np.array(engine.argsort(jnp.asarray(x), method="merge"))
    np.testing.assert_array_equal(np.sort(order, -1),
                                  np.broadcast_to(np.arange(9000), order.shape))
    np.testing.assert_array_equal(np.take_along_axis(x, order, -1),
                                  np.sort(x, -1))


def test_engine_argsort_stable():
    rng = np.random.default_rng(17)
    x = rng.integers(0, 8, size=20000).astype(np.int32)   # heavy ties
    order = np.array(engine.argsort(jnp.asarray(x), method="merge",
                                    stable=True))
    np.testing.assert_array_equal(order, np.argsort(x, kind="stable"))


def test_engine_argsort_stable_descending():
    """Regression: descending merges used to reverse cross-run tie order
    (flip-in/flip-out turned left-wins-ties into right-wins-ties)."""
    x = jnp.zeros(512, jnp.int32)   # all-equal keys: order must be identity
    order = np.array(engine.argsort(x, method="merge", stable=True,
                                    descending=True, run_len=128))
    np.testing.assert_array_equal(order, np.arange(512))
    rng = np.random.default_rng(19)
    y = rng.integers(0, 5, size=4000).astype(np.int32)
    order = np.array(engine.argsort(jnp.asarray(y), method="merge",
                                    stable=True, descending=True,
                                    run_len=256))
    ref = np.argsort(-y.astype(np.int64), kind="stable")
    np.testing.assert_array_equal(order, ref)


@pytest.mark.parametrize("n,k", [(5000, 7), (70000, 64), (152064, 50)])
def test_engine_topk_matches_lax(n, k):
    x = jnp.asarray(np.random.default_rng(n).standard_normal((2, n)),
                    jnp.float32)
    vr, _ = jax.lax.top_k(x, k)
    v, i = engine.topk(x, k, method="merge")
    np.testing.assert_array_equal(np.array(v), np.array(vr))
    np.testing.assert_array_equal(
        np.take_along_axis(np.array(x), np.array(i), -1), np.array(vr))


# ---------------------------------------------------------------------------
# merge primitives (both backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("l", [64, 256, 1024])
def test_merge_pairs_backends_agree_with_np(backend, l):
    rng = np.random.default_rng(l)
    a = np.sort(rng.standard_normal((5, l)).astype(np.float32), -1)
    b = np.sort(rng.standard_normal((5, l)).astype(np.float32), -1)
    out = np.array(engine_merge.merge_pairs(
        jnp.asarray(a), jnp.asarray(b), backend=backend))
    ref = np.sort(np.concatenate([a, b], -1), -1)
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_merge_pairs_kv_payloads_follow_keys(backend):
    rng = np.random.default_rng(23)
    a = np.sort(rng.standard_normal((2, 128)).astype(np.float32), -1)
    b = np.sort(rng.standard_normal((2, 128)).astype(np.float32), -1)
    va = np.arange(128, dtype=np.int32)[None].repeat(2, 0)
    vb = va + 128
    k, v = engine_merge.merge_pairs(
        jnp.asarray(a), jnp.asarray(b), backend=backend,
        values=(jnp.asarray(va), jnp.asarray(vb)))
    k, v = np.array(k), np.array(v)
    np.testing.assert_array_equal(k, np.sort(np.concatenate([a, b], -1), -1))
    both = np.concatenate([a, b], -1)
    np.testing.assert_array_equal(np.take_along_axis(both, v, -1), k)


def test_merge_pairs_pallas_extreme_values():
    """Count-masked windows: dtype-max data must not vanish into padding."""
    a = np.full((1, 64), np.iinfo(np.int32).max, np.int32)
    b = np.sort(np.random.default_rng(1).integers(
        -50, 50, (1, 64)).astype(np.int32), -1)
    out = np.array(engine_merge.merge_pairs(
        jnp.asarray(a), jnp.asarray(b), backend="pallas"))
    np.testing.assert_array_equal(out,
                                  np.sort(np.concatenate([a, b], -1), -1))


def test_kway_merge_ragged_lengths():
    rng = np.random.default_rng(29)
    parts = [np.sort(rng.standard_normal(n).astype(np.float32))
             for n in (100, 257, 64, 1000, 3)]
    out = np.array(engine_merge.kway_merge([jnp.asarray(p) for p in parts]))
    np.testing.assert_array_equal(out, np.sort(np.concatenate(parts)))


# ---------------------------------------------------------------------------
# planner / auto dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 100, 2048, 40000, 1 << 18])
@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.uint32])
def test_auto_never_selects_invalid_backend(n, dtype):
    method = engine.choose_method(n, 2, jnp.dtype(dtype))
    assert method in ("xla", "bitonic", "pallas", "merge", "radix")
    x = _rand(np.random.default_rng(n), (2, min(n, 50000)), dtype)
    out = np.array(sort_api.sort(jnp.asarray(x), method="auto"))
    np.testing.assert_array_equal(out, np.sort(x, -1))


def test_auto_respects_whole_array_caps():
    big = (planner.MAX_PALLAS_N * 4)
    plan = planner.choose(big, 1)
    assert plan.method in ("xla", "merge")
    assert plan.costs["merge"] < plan.costs["bitonic"]


def test_choose_merge_eligibility_uses_resolved_run_len():
    """Regression: _eligible('merge') compared n against DEFAULT_RUN_LEN
    (2048) while the plan ran with the CPU run length (8192), so auto could
    pick a degenerate single-run merge for 2048 < n <= 8192."""
    plan = planner.choose(4096, 1)
    assert plan.method != "merge"
    assert plan.run_len == tuning.active().run_len
    # with an explicit small run_len, 4096 is multiple runs again: merge
    # must be a *candidate* (picked or not is the cost model's call)
    assert planner._eligible("merge", 4096, jnp.dtype(jnp.float32), 1024)
    assert not planner._eligible("merge", 4096, jnp.dtype(jnp.float32), 8192)


def test_plan_is_executable():
    plan = planner.choose(100000, 1)
    assert plan.run_len == tuning.active().run_len
    assert plan.run_method in runs.RUN_METHODS
    assert plan.merge_backend in engine_merge.MERGE_BACKENDS


def test_calibrate_updates_constants():
    try:
        prof = planner.calibrate(tile_n=256, batch=8, reps=1,
                                 include_pallas=False)
        c = prof.constants
        assert c.xla > 0 and c.bitonic > 0 and c.merge_level > 0
        assert c.radix > 0     # analytic default kept off-TPU
        assert prof.source == "calibrated"
        assert planner.constants() is c
        assert tuning.active() is prof
        # post-calibration dispatch still returns an executable method
        assert planner.choose(100000, 1).method in (
            "xla", "bitonic", "pallas", "merge", "radix")
    finally:
        planner.reset_calibration()
    from repro.core import cost_model
    assert planner.constants() == cost_model.DeviceSortConstants()
    assert tuning.active().source == "default"


def test_sort_api_merge_and_auto_methods():
    x = jnp.asarray(np.random.default_rng(31).standard_normal((2, 5000)),
                    jnp.float32)
    ref = np.sort(np.array(x), -1)
    for method in ("merge", "auto"):
        np.testing.assert_array_equal(
            np.array(sort_api.sort(x, method=method)), ref)
        order = np.array(sort_api.argsort(x, method=method))
        np.testing.assert_array_equal(
            np.take_along_axis(np.array(x), order, -1), ref)
    v, i = sort_api.topk(x, 12, method="merge")
    np.testing.assert_array_equal(np.array(v), np.flip(ref, -1)[:, :12])


# ---------------------------------------------------------------------------
# segmented sort
# ---------------------------------------------------------------------------

def test_segmented_sort_groups_sorted():
    rng = np.random.default_rng(37)
    values = rng.standard_normal(5000).astype(np.float32)
    seg = np.sort(rng.integers(0, 17, 5000)).astype(np.int32)
    sv, sseg = segmented.segmented_sort(jnp.asarray(values),
                                        jnp.asarray(seg))
    sv, sseg = np.array(sv), np.array(sseg)
    np.testing.assert_array_equal(sseg, seg)  # contiguous input stays put
    for s in np.unique(seg):
        np.testing.assert_array_equal(sv[sseg == s],
                                      np.sort(values[seg == s]))


def test_segmented_sort_unordered_segments():
    rng = np.random.default_rng(41)
    values = rng.standard_normal(1000).astype(np.float32)
    seg = rng.integers(0, 5, 1000).astype(np.int32)    # interleaved groups
    sv, sseg = segmented.segmented_sort(jnp.asarray(values),
                                        jnp.asarray(seg))
    sv, sseg = np.array(sv), np.array(sseg)
    assert (np.diff(sseg) >= 0).all()
    for s in range(5):
        np.testing.assert_array_equal(sv[sseg == s],
                                      np.sort(values[seg == s]))


def test_segment_ids_from_row_splits():
    splits = jnp.asarray([0, 3, 3, 7, 10])
    ids = np.array(segmented.segment_ids_from_row_splits(splits, 10))
    np.testing.assert_array_equal(ids, [0, 0, 0, 2, 2, 2, 2, 3, 3, 3])


def test_sort_padded_rows_preserves_layout():
    rng = np.random.default_rng(43)
    vals = rng.standard_normal((4, 64)).astype(np.float32)
    lengths = np.array([64, 10, 0, 33])
    out = np.array(segmented.sort_padded_rows(
        jnp.asarray(vals), jnp.asarray(lengths), fill_value=-1.0))
    for r, ln in enumerate(lengths):
        np.testing.assert_array_equal(out[r, :ln], np.sort(vals[r, :ln]))
        np.testing.assert_array_equal(out[r, ln:], -1.0)


def test_group_tokens_by_expert_stable():
    rng = np.random.default_rng(47)
    eids = rng.integers(0, 8, 512).astype(np.int32)
    perm, splits = segmented.group_tokens_by_expert(jnp.asarray(eids), 8)
    perm, splits = np.array(perm), np.array(splits)
    np.testing.assert_array_equal(perm, np.argsort(eids, kind="stable"))
    for e in range(8):
        assert (eids[perm[splits[e]:splits[e + 1]]] == e).all()


# ---------------------------------------------------------------------------
# composition with the mesh path
# ---------------------------------------------------------------------------

def test_distributed_sort_local_method_auto():
    from jax.sharding import Mesh
    from repro.core import distributed_sort
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(-1), ("data",))
    n = devs.size * 4096
    x = jnp.asarray(np.random.default_rng(53).standard_normal(n), jnp.float32)
    out = np.array(distributed_sort.distributed_sort(
        x, mesh, "data", local_method="auto"))
    np.testing.assert_array_equal(out, np.sort(np.array(x)))


@pytest.mark.slow
def test_engine_sort_large_pallas_merge_backend():
    """Full pipeline with the Pallas merge-path kernel at a non-toy size."""
    x = np.random.default_rng(59).standard_normal(1 << 16).astype(np.float32)
    rg = runs.generate_runs(jnp.asarray(x)[None, :], 2048, method="pallas")
    out = np.array(engine_merge.merge_runs(rg, backend="pallas"))[0]
    np.testing.assert_array_equal(out, np.sort(x))
