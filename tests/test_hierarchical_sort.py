"""Two-level (ICI/DCN) hierarchical sample-sort: bit-exactness vs the
flat schedule and ``jnp.sort``, planner flat-vs-hier selection, topology
plumbing through ``distributed_sort``.

The mesh tests need 8 local devices for a real 2x4 (hosts x devices)
grid, so they skip on the single-device tier-1 job — which still runs
the planner/cost-model pins (pure host math) and one subprocess test
that forces 8 simulated devices.  The CI multi-device job executes the
whole file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed_sort as ds, topology
from repro.engine import planner, samplesort

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="2x4 hierarchical mesh needs 8 local devices")


def _mesh2x4():
    return jax.make_mesh((2, 4), ("host", "dev"))


# ---------------------------------------------------------------------------
# planner: flat-vs-hier selection pinned at known bandwidth ratios
# ---------------------------------------------------------------------------

def _topo(dcn_slowdown: float) -> topology.Topology:
    ici_bw, ici_lat = 5e10, 2_000.0
    return topology.Topology(
        fingerprint="test-fixture",
        axes=(
            topology.TopologyAxis(
                name="host", size=2, tier=topology.TIER_DCN,
                bandwidth_bytes_per_s=ici_bw / dcn_slowdown,
                latency_ns=ici_lat * dcn_slowdown),
            topology.TopologyAxis(
                name="dev", size=4, tier=topology.TIER_ICI,
                bandwidth_bytes_per_s=ici_bw, latency_ns=ici_lat),
        ),
        source="default")


def test_choose_distributed_prices_hier_on_two_tier_topology():
    plan = planner.choose_distributed(1 << 22, 8, topology=_topo(10.0))
    assert set(plan.costs) == {"sample", "oddeven", "hier"}
    assert all(np.isfinite(c) and c > 0 for c in plan.costs.values())
    # without a topology the strategy set stays flat-only (back-compat)
    flat = planner.choose_distributed(1 << 22, 8)
    assert set(flat.costs) == {"sample", "oddeven"}


def test_choose_distributed_flat_vs_hier_crossover():
    """The regression pin of the tier-rate decision: at uniform link
    rates the second splitter round buys nothing (three extra intra-tier
    rounds, same total movement) so FLAT must win; once the outer tier is
    10x slower per byte, trading one full-mesh exchange at the blended
    rate for chunked DCN traffic plus fast ICI rounds must flip the
    decision to HIER.  4x skew (a mild but real DCN) must already flip
    it — the crossover lives below realistic tier ratios."""
    n = 1 << 22
    assert planner.choose_distributed(n, 8, topology=_topo(1.0)) \
        .strategy == "sample"
    assert planner.choose_distributed(n, 8, topology=_topo(4.0)) \
        .strategy == "hier"
    assert planner.choose_distributed(n, 8, topology=_topo(10.0)) \
        .strategy == "hier"
    # the hier advantage widens with the skew
    c4 = planner.choose_distributed(n, 8, topology=_topo(4.0)).costs
    c10 = planner.choose_distributed(n, 8, topology=_topo(10.0)).costs
    assert (c10["sample"] - c10["hier"]) > (c4["sample"] - c4["hier"])


def test_choose_distributed_topology_device_mismatch_raises():
    with pytest.raises(ValueError, match="devices"):
        planner.choose_distributed(1 << 20, 16, topology=_topo(10.0))


def test_choose_distributed_cached_keys_on_topology():
    a = planner.choose_distributed_cached(1 << 22, 8, topology=_topo(1.0))
    b = planner.choose_distributed_cached(1 << 22, 8, topology=_topo(10.0))
    assert a.strategy == "sample" and b.strategy == "hier"
    # same signature, same generation -> cache returns the same plan obj
    again = planner.choose_distributed_cached(1 << 22, 8,
                                              topology=_topo(1.0))
    assert again.strategy == "sample"


# ---------------------------------------------------------------------------
# axis plumbing helpers (host-level, any device count)
# ---------------------------------------------------------------------------

def test_axes_tuple_validation():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    assert samplesort._axes_tuple(mesh, None) == ("data",)
    assert samplesort._axes_tuple(mesh, "data") == ("data",)
    with pytest.raises(ValueError):
        samplesort._axes_tuple(mesh, "nope")
    with pytest.raises(ValueError):
        samplesort._axes_tuple(mesh, ("data", "data"))


@needs8
def test_axes_tuple_two_axis():
    mesh = _mesh2x4()
    assert samplesort._axes_tuple(mesh, None) == ("host", "dev")
    assert samplesort._axes_tuple(mesh, ("dev",)) == ("dev",)
    assert samplesort._n_dev(mesh, ("host", "dev")) == 8
    assert samplesort._n_dev(mesh, ("dev",)) == 4


# ---------------------------------------------------------------------------
# 2x4 mesh: hierarchical == flat == jnp.sort, bit for bit
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("n", [4096, 4000, 37])
@pytest.mark.parametrize("descending", [False, True])
def test_hier_matches_flat_and_jnp(n, descending):
    mesh = _mesh2x4()
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    hier = samplesort.sample_sort(x, mesh, None, descending=descending,
                                  hierarchical=True)
    flat = samplesort.sample_sort(x, mesh, None, descending=descending,
                                  hierarchical=False)
    ref = jnp.sort(x)[::-1] if descending else jnp.sort(x)
    np.testing.assert_array_equal(np.asarray(hier), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(hier), np.asarray(flat))


@needs8
@pytest.mark.parametrize("descending", [False, True])
def test_hier_kv_payloads_consistent(descending):
    """Duplicate-heavy keys with a position payload: keys must land in
    exact sorted order and every payload must still sit next to its own
    key with nothing lost — the same consistency contract the flat path
    has always made (neither schedule promises *stable* tie order for
    raw kv; exact tie order is the composite test below)."""
    mesh = _mesh2x4()
    rng = np.random.default_rng(5)
    n = 3001
    k = rng.integers(0, 7, n).astype(np.int32)
    v = np.arange(n, dtype=np.int32)
    hk, hv = samplesort.sample_sort(
        jnp.asarray(k), mesh, None, values=jnp.asarray(v),
        descending=descending, hierarchical=True)
    fk, fv = samplesort.sample_sort(
        jnp.asarray(k), mesh, None, values=jnp.asarray(v),
        descending=descending, hierarchical=False)
    hk, hv = np.asarray(hk), np.asarray(hv)
    ref = np.flip(np.sort(k)) if descending else np.sort(k)
    np.testing.assert_array_equal(hk, ref)
    np.testing.assert_array_equal(hk, np.asarray(fk))
    assert (k[hv] == hk).all()                    # payload rides its key
    assert len(set(hv.tolist())) == n             # a true permutation


@needs8
def test_hier_exact_tie_order_via_composite():
    """The engine's distributed argsort convention: pack (key, index)
    into unique composites, so tie order is part of the key and the
    whole permutation is pinned bit for bit.  Hier, flat, and
    ``jnp.sort`` must agree exactly, and the recovered permutation is
    the stable argsort."""
    mesh = _mesh2x4()
    rng = np.random.default_rng(5)
    n = 3001
    k = rng.integers(0, 7, n).astype(np.int32)
    idx_bits = max(1, (n - 1).bit_length())
    comp = jnp.asarray((k.astype(np.uint32) << idx_bits)
                       | np.arange(n, dtype=np.uint32))
    hs = samplesort.sample_sort(comp, mesh, None, hierarchical=True)
    fs = samplesort.sample_sort(comp, mesh, None, hierarchical=False)
    ref = np.sort(np.asarray(comp))
    np.testing.assert_array_equal(np.asarray(hs), ref)
    np.testing.assert_array_equal(np.asarray(fs), ref)
    perm = np.asarray(hs) & np.uint32((1 << idx_bits) - 1)
    np.testing.assert_array_equal(perm, np.argsort(k, kind="stable"))


@needs8
def test_hier_edge_shapes():
    mesh = _mesh2x4()
    # tiny n (fewer elements than devices) and the all-equal worst case
    out = samplesort.sample_sort(jnp.asarray([3, 1, 2], jnp.int32),
                                 mesh, None, hierarchical=True)
    np.testing.assert_array_equal(np.asarray(out), [1, 2, 3])
    eq = samplesort.sample_sort(jnp.full((977,), 5, jnp.uint32),
                                mesh, None, hierarchical=True)
    np.testing.assert_array_equal(np.asarray(eq), np.full(977, 5))


@needs8
def test_hier_size1_outer_axis_demotes_to_flat():
    """A degenerate (1, 8) mesh has no second tier to split over:
    ``hierarchical=None`` (auto) must demote silently and still sort."""
    mesh = jax.make_mesh((1, 8), ("host", "dev"))
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    out = samplesort.sample_sort(x, mesh, None)
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))


@needs8
def test_hier_pipeline_chunks_and_wire_codec():
    """Chunked DCN exchange and the int8 wire codec change the transport,
    never the keys: keys stay bit-exact, the (lossy, opt-in) payload
    stays within the quantizer's half-step."""
    mesh = _mesh2x4()
    rng = np.random.default_rng(13)
    n = 4096
    # unique keys: with ties the positional payload comparison would mix
    # legitimately-swapped equal-key payloads into the quantization error
    k = rng.permutation(1 << 20)[:n].astype(np.int32)
    v = rng.uniform(-1000, 1000, n).astype(np.float32)
    hk, hv = samplesort.sample_sort(
        jnp.asarray(k), mesh, None, values=jnp.asarray(v),
        hierarchical=True, pipeline_chunks=4, wire_codec="int8")
    perm = np.argsort(k, kind="stable")
    np.testing.assert_array_equal(np.asarray(hk), k[perm])
    # per-bucket absmax int8: error bound is half a quantization step
    assert np.max(np.abs(np.asarray(hv) - v[perm])) <= 1000.0 / 127.0


@needs8
def test_distributed_sort_hier_strategy_and_auto():
    mesh = _mesh2x4()
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal(8192).astype(np.float32))
    out = ds.distributed_sort(x, mesh, strategy="hier")
    np.testing.assert_array_equal(np.asarray(out), np.sort(np.asarray(x)))
    auto = ds.distributed_sort(x, mesh, strategy="auto")
    np.testing.assert_array_equal(np.asarray(auto), np.sort(np.asarray(x)))
    # forcing hier on a flat mesh is a contract error
    flat_mesh = jax.make_mesh((8,), ("data",))
    with pytest.raises(ValueError, match="two-axis"):
        ds.distributed_sort(x, flat_mesh, strategy="hier")


@needs8
def test_distributed_topk_two_axis_mesh():
    mesh = _mesh2x4()
    rng = np.random.default_rng(21)
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    tv, ti = ds.distributed_topk(x, 33, mesh)
    rv, ri = jax.lax.top_k(x, 33)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(ri))


# ---------------------------------------------------------------------------
# forced 8-device run (covers the 2x4 grid even on the single-device job)
# ---------------------------------------------------------------------------

def test_hier_sample_sort_8dev_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.engine import samplesort
mesh = jax.make_mesh((2, 4), ("host", "dev"))
rng = np.random.default_rng(0)
k = rng.integers(0, 9, 1003).astype(np.int32)
v = np.arange(1003, dtype=np.int32)
hk, hv = samplesort.sample_sort(jnp.asarray(k), mesh, None,
                                values=jnp.asarray(v), descending=True,
                                hierarchical=True)
fk, fv = samplesort.sample_sort(jnp.asarray(k), mesh, None,
                                values=jnp.asarray(v), descending=True,
                                hierarchical=False)
hk, hv = np.asarray(hk), np.asarray(hv)
assert (hk == np.flip(np.sort(k))).all()
assert (hk == np.asarray(fk)).all()
assert (k[hv] == hk).all() and len(set(hv.tolist())) == 1003
# unique composites pin the exact permutation across both schedules
comp = ((k.astype(np.uint32) & 0xF) << 10) | np.arange(1003, dtype=np.uint32)
hs = samplesort.sample_sort(jnp.asarray(comp), mesh, None, hierarchical=True)
assert (np.asarray(hs) == np.sort(comp)).all()
print("HIER_8DEV_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    env.pop("XLA_FLAGS", None)        # the subprocess pins its own count
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "HIER_8DEV_OK" in r.stdout, r.stderr[-2000:]
