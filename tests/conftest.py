import os
import sys
import warnings

warnings.filterwarnings("ignore", category=DeprecationWarning)

# Property tests use hypothesis when available; otherwise fall back to a
# deterministic fixed-sample replay shim so the suite still collects and the
# properties still execute (see tests/_hypothesis_stub.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install()
