"""Fault-tolerance runtime units."""
import os
import signal
import time

import pytest

from repro.runtime.fault_tolerance import (ElasticPlan, PreemptionHandler,
                                           StepWatchdog)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(alpha=0.5, threshold=1.5, warmup_steps=2)
    for step in range(8):
        wd.start()
        time.sleep(0.02 if step != 6 else 0.12)
        wd.stop(step)
    assert any(s == 6 for (s, _, _) in wd.flagged)
    assert all(s != 3 for (s, _, _) in wd.flagged)


def test_preemption_handler_catches_sigterm():
    h = PreemptionHandler().install()
    assert not h.preempted
    os.kill(os.getpid(), signal.SIGTERM)
    time.sleep(0.01)
    assert h.preempted
    h.uninstall()


def test_elastic_plan_keeps_model_axis():
    # lose 37 of 512 devices -> largest pow2 data degree with TP=16 intact
    plan = ElasticPlan.plan(512 - 37, model_parallel=16, global_batch=256)
    assert plan.mesh_shape == (16, 16)
    assert plan.usable_devices == 256
    assert plan.dropped_devices == 475 - 256
    assert plan.global_batch == 256          # trajectory unchanged
    assert plan.microbatch_for(512, 8) == 16  # 2x grad accumulation


def test_elastic_plan_multi_pod():
    plan = ElasticPlan.plan(512, model_parallel=16, global_batch=256,
                            want_pods=2)
    assert plan.mesh_shape == (2, 16, 16)
    assert plan.axis_names == ("pod", "data", "model")


def test_elastic_plan_rejects_too_few():
    with pytest.raises(ValueError):
        ElasticPlan.plan(8, model_parallel=16, global_batch=64)
