"""Property tests for the bitonic network + partition planning (Eq. 1-4)."""
from hypothesis import given, settings, strategies as st

from repro.core import network as nw


@given(st.sampled_from([2, 4, 8, 16, 32, 64, 128]))
def test_closed_forms_match_generated_network(n):
    stages = nw.bitonic_stages(n)
    assert len(stages) == nw.n_stages(n)
    assert sum(len(s) for s in stages) == nw.n_cas_blocks(n)
    for stage in stages:
        touched = [i for pair in stage for i in pair[:2]]
        assert sorted(touched) == list(range(n))  # each element exactly once


@given(st.lists(st.integers(0, 255), min_size=2, max_size=64))
@settings(max_examples=200)
def test_network_sorts_any_input(values):
    n = 1
    while n < len(values):
        n *= 2
    padded = values + [255] * (n - len(values))
    out = nw.apply_network(padded, nw.bitonic_stages(n))
    assert out == sorted(padded)


def test_paper_n8_constants():
    assert nw.n_cas_blocks(8) == 24
    assert nw.n_stages(8) == 6
    assert nw.n_temp_rows(8) == 2
    assert nw.movement_cycles(8) == 6
    plan = nw.plan_partitions(8)
    assert plan.moving_transitions == 4          # 4 x 6 = 24 extra cycles
    assert plan.extra_cycles == 24
    assert plan.n_partitions == 4


@given(st.sampled_from([4, 8, 16, 32, 64]))
def test_partition_plan_is_consistent(n):
    plan = nw.plan_partitions(n)
    assert 0 <= plan.moving_transitions < nw.n_stages(n)
    # every stage's residency maps each element to a partition < n/2
    for residency in plan.residency:
        assert set(residency) == set(range(n))
        assert all(0 <= p < n // 2 for p in residency.values())
