"""launch/report.py: explicit results dir + honest mesh filtering.

The module used to hard-code its results directory from ``__file__`` and
``markdown()`` ignored its ``mesh`` argument on the way into ``rows()`` —
every mesh rendered the same table.  Both entry points now take an
explicit ``results_dir`` and the mesh filter actually filters.
"""
import json

import pytest

from repro.launch import report


@pytest.fixture
def results_dir(tmp_path):
    recs = [
        {"arch": "gemma-2b", "shape": "decode", "mesh": "16x16", "ok": True,
         "memory": {"temp_bytes": 2.0e9, "argument_bytes": 1.0e9},
         "hlo_analysis": {"flops": 1e12, "collective_total_bytes": 3e8},
         "compile_s": 12},
        {"arch": "gemma-2b", "shape": "decode", "mesh": "8x8", "ok": False},
        {"arch": "moe-8x1b", "shape": "prefill", "skipped": True,
         "reason": "host RAM exceeded while building the dry-run params"},
    ]
    for i, r in enumerate(recs):
        (tmp_path / f"r{i}.json").write_text(json.dumps(r))
    # suffix-filtered variants must never show up
    (tmp_path / "r9_flash.json").write_text(json.dumps(recs[0]))
    return tmp_path


def test_rows_filters_by_mesh(results_dir):
    all_rows = report.rows(results_dir=results_dir)
    assert len(all_rows) == 3                        # _flash variant dropped
    r16 = report.rows("16x16", results_dir=results_dir)
    meshes = {r.get("mesh") for r in r16 if not r.get("skipped")}
    assert meshes == {"16x16"}
    # skips carry no mesh and survive every filter
    assert any(r.get("skipped") for r in r16)
    r8 = report.rows("8x8", results_dir=results_dir)
    assert {r.get("mesh") for r in r8 if not r.get("skipped")} == {"8x8"}


def test_markdown_respects_mesh(results_dir):
    md16 = report.markdown("16x16", results_dir=results_dir)
    assert "| gemma-2b | decode | ok |" in md16
    assert "**FAIL**" not in md16                    # the 8x8 failure
    assert "SKIP" in md16                            # skips print once
    md8 = report.markdown("8x8", results_dir=results_dir)
    assert "**FAIL**" in md8
    assert "| ok |" not in md8
    assert "SKIP" not in md8


def test_status_counts(results_dir):
    assert report.status_counts(results_dir=results_dir) == (1, 1, 1)
    assert report.status_counts("8x8", results_dir=results_dir) == (0, 1, 1)


def test_default_results_dir_unchanged():
    assert report.RESULTS.parts[-2:] == ("results", "dryrun")
