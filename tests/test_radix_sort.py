"""Pallas LSD radix sort: kernel-level + sort_api wiring + engine run backend."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sort_api, tuning
from repro.kernels import radix_sort


def _rand(rng, shape, dtype):
    if np.issubdtype(dtype, np.floating):
        return (rng.standard_normal(shape) * 100).astype(dtype)
    info = np.iinfo(dtype)
    return rng.integers(info.min, info.max, size=shape, dtype=dtype,
                        endpoint=True)


# ---------------------------------------------------------------------------
# kernel level: unsigned encoded keys
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.uint32])
@pytest.mark.parametrize("n", [8, 200, 256, 1000])
def test_sort_blocks_matches_np(dtype, n):
    rng = np.random.default_rng(n)
    x = _rand(rng, (3, n), dtype)
    out = np.asarray(radix_sort.sort_blocks(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x, -1))


def test_sort_blocks_multi_tile_rows():
    """n spanning many tiles exercises the cross-tile prefix-sum."""
    rng = np.random.default_rng(5)
    tile = tuning.active().radix_tile
    x = _rand(rng, (2, 5 * tile + 17), np.uint32)
    out = np.asarray(radix_sort.sort_blocks(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x, -1))


def test_sort_kv_blocks_is_stable():
    """Heavy ties: payload order within equal keys must be input order."""
    rng = np.random.default_rng(7)
    k = rng.integers(0, 4, (2, 3000)).astype(np.uint32)
    v = np.broadcast_to(np.arange(3000, dtype=np.int32), k.shape).copy()
    sk, sv = radix_sort.sort_kv_blocks(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(sk), np.sort(k, -1))
    for r in range(k.shape[0]):
        np.testing.assert_array_equal(np.asarray(sv)[r],
                                      np.argsort(k[r], kind="stable"))


def test_padding_survives_max_keys():
    """Genuine all-ones keys collide with the pad key; stability must keep
    the real elements (earlier positions) and drop the pads."""
    n = 300                                # pads to 2 tiles of 256
    k = np.full((1, n), np.uint32(0xFFFFFFFF))
    v = np.arange(n, dtype=np.int32)[None, :]
    sk, sv = radix_sort.sort_kv_blocks(jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_array_equal(np.asarray(sk), k)
    np.testing.assert_array_equal(np.asarray(sv), v)


# ---------------------------------------------------------------------------
# sort_api method="radix": codec + kernel end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32, np.uint16,
                                   np.uint32, np.float16, np.float32])
@pytest.mark.parametrize("descending", [False, True])
def test_radix_sort_all_dtypes(dtype, descending):
    rng = np.random.default_rng(11)
    x = _rand(rng, (2, 777), dtype)
    out = np.asarray(sort_api.sort(jnp.asarray(x), method="radix",
                                   descending=descending))
    ref = np.sort(x, -1)
    if descending:
        ref = np.flip(ref, -1)
    np.testing.assert_array_equal(out, ref)


def test_radix_sort_bfloat16():
    x = jnp.asarray(np.random.default_rng(13).standard_normal((2, 300)),
                    jnp.bfloat16)
    out = sort_api.sort(x, method="radix")
    ref = jnp.sort(x, axis=-1)
    assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()


def test_radix_sort_negative_extremes():
    x = np.array([[3, -1, 2, -5, 0, 7, -2, 1,
                   np.iinfo(np.int32).min, np.iinfo(np.int32).max]], np.int32)
    out = np.asarray(sort_api.sort(jnp.asarray(x), method="radix"))
    np.testing.assert_array_equal(out, np.sort(x, -1))


def test_radix_sort_axis_and_lead_dims():
    x = _rand(np.random.default_rng(17), (300, 2, 3), np.float32)
    out = np.asarray(sort_api.sort(jnp.asarray(x), axis=0, method="radix"))
    np.testing.assert_array_equal(out, np.sort(x, 0))


def test_radix_sort_orders_signed_zero():
    """The codec's total order: every -0.0 lands before every +0.0."""
    x = jnp.asarray([0.0, 1.0, -0.0, 0.0, -0.0, -1.0], jnp.float32)
    out = np.asarray(sort_api.sort(x, method="radix")).view(np.uint32)
    np.testing.assert_array_equal(
        out, np.array([-1.0, -0.0, -0.0, 0.0, 0.0, 1.0],
                      np.float32).view(np.uint32))


@pytest.mark.parametrize("descending", [False, True])
def test_radix_argsort_stable_ties(descending):
    rng = np.random.default_rng(19)
    x = rng.integers(0, 5, (2, 1500)).astype(np.int32)
    order = np.asarray(sort_api.argsort(jnp.asarray(x), method="radix",
                                        descending=descending))
    n = x.shape[-1]
    if descending:
        ref = n - 1 - np.flip(np.argsort(np.flip(x, -1), -1, kind="stable"),
                              -1)
    else:
        ref = np.argsort(x, -1, kind="stable")
    np.testing.assert_array_equal(order, ref)


def test_radix_topk_matches_lax():
    import jax
    x = jnp.asarray(np.random.default_rng(23).standard_normal((2, 400)),
                    jnp.float32)
    vr, _ = jax.lax.top_k(x, 9)
    v, i = sort_api.topk(x, 9, method="radix")
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(
        np.take_along_axis(np.asarray(x), np.asarray(i), -1), np.asarray(vr))


def test_radix_rejects_uncodable_dtype():
    with pytest.raises(ValueError, match="radix method supports"):
        sort_api.sort(jnp.zeros(8, jnp.bool_), method="radix")


# ---------------------------------------------------------------------------
# engine integration: radix as a run backend + planner wiring
# ---------------------------------------------------------------------------

def test_runs_radix_backend():
    from repro.engine import runs
    rng = np.random.default_rng(29)
    x = rng.integers(-1000, 1000, (2, 2000)).astype(np.int32)
    rg = np.asarray(runs.generate_runs(jnp.asarray(x), 512, method="radix"))
    assert rg.shape == (2, 4, 512)
    pad = np.full((2, 48), np.iinfo(np.int32).max, np.int32)
    ref = np.concatenate([x, pad], -1).reshape(2, 4, 512)
    np.testing.assert_array_equal(rg, np.sort(ref, -1))


def test_engine_merge_with_radix_runs():
    from repro.engine import merge as engine_merge
    from repro.engine import runs
    rng = np.random.default_rng(31)
    x = rng.integers(-1000, 1000, (1, 4000)).astype(np.int32)
    rg = runs.generate_runs(jnp.asarray(x), 1024, method="radix")
    out = np.asarray(engine_merge.merge_runs(rg))[0, :4000]
    np.testing.assert_array_equal(out, np.sort(x[0]))


def test_planner_prices_radix_and_can_select_it():
    from repro.core import cost_model
    from repro.engine import planner
    plan = planner.choose(1 << 20, 1, jnp.float32)
    assert "radix" in plan.costs and plan.costs["radix"] > 0
    # 8-bit keys cost a quarter of the passes of 32-bit keys
    assert planner.choose(1 << 20, 1, jnp.uint8).costs["radix"] == \
        pytest.approx(plan.costs["radix"] / 4)
    # with kernel-speed constants (no interpret penalty), the O(n·b) path
    # must win at sizes where log2(n) dwarfs the pass count — i.e. auto
    # CAN dispatch to radix when it is the cheapest valid backend
    c = {m: cost_model.device_sort_cost_ns(
            m, 1 << 20, run_len=2048, pallas_interpreted=False)
         for m in ("xla", "bitonic", "pallas", "merge", "radix")}
    assert min(c, key=c.get) == "radix"
    assert planner._eligible("radix", 1 << 20, jnp.dtype(jnp.float32), 2048)
    assert not planner._eligible("radix", 1 << 20, jnp.dtype(jnp.float64),
                                 2048)


def test_sort_api_auto_still_valid_with_radix_candidate():
    x = jnp.asarray(np.random.default_rng(37).integers(-50, 50, (2, 3000)),
                    jnp.int32)
    out = np.asarray(sort_api.sort(x, method="auto"))
    np.testing.assert_array_equal(out, np.sort(np.asarray(x), -1))
