"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(1, 8), (4, 64), (3, 100), (2, 5, 128), (8, 256)]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("descending", [False, True])
def test_bitonic_sort_sweep(shape, dtype, descending):
    rng = np.random.default_rng(hash((shape, str(dtype), descending)) % 2**31)
    x = jnp.asarray(rng.standard_normal(shape) * 50, dtype=dtype)
    out = ops.bitonic_sort(x, -1, descending)
    exp = ref.bitonic_sort(x, descending)
    np.testing.assert_allclose(np.array(out, np.float64),
                               np.array(exp, np.float64))


@pytest.mark.parametrize("n,k", [(16, 4), (64, 8), (100, 10), (2048, 16),
                                 (5000, 32), (51865, 50)])
def test_bitonic_topk_sweep(n, k):
    rng = np.random.default_rng(n * 31 + k)
    x = jnp.asarray(rng.standard_normal((2, n)), dtype=jnp.float32)
    v, i = ops.bitonic_topk(x, k)
    vr, _ = ref.bitonic_topk(x, k)
    np.testing.assert_allclose(np.array(v), np.array(vr))
    np.testing.assert_allclose(
        np.take_along_axis(np.array(x), np.array(i), -1), np.array(vr))


@pytest.mark.parametrize("width", [2, 4, 8])
def test_bitserial_cas_sweep(width):
    rng = np.random.default_rng(width)
    a = rng.integers(0, 2**width, 700)
    b = rng.integers(0, 2**width, 700)
    lo, hi = ops.bitserial_cas(jnp.asarray(a), jnp.asarray(b), width=width)
    elo, ehi = ref.bitserial_cas(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.array(lo), np.array(elo))
    np.testing.assert_array_equal(np.array(hi), np.array(ehi))


def test_sort_vjp_matches_reference():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32)),
                    dtype=jnp.float32)
    g1 = jax.grad(lambda v: ops.bitonic_sort(v, -1, False)[..., -4:].sum())(x)
    # reference gradient: indicator of top-4 positions
    exp = np.zeros(x.shape, np.float32)
    xi = np.array(x)
    for r in range(2):
        exp[r, np.argsort(xi[r])[-4:]] = 1.0
    np.testing.assert_allclose(np.array(g1), exp)


def test_topk_vjp_scatter():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((3, 40)),
                    dtype=jnp.float32)
    gv = jax.grad(lambda v: ops.bitonic_topk(v, 5)[0].sum())(x)
    gr = jax.grad(lambda v: jax.lax.top_k(v, 5)[0].sum())(x)
    np.testing.assert_allclose(np.array(gv), np.array(gr))


def test_kv_sort_stability_on_ties():
    """Equal keys: payload order within the CAS keeps the a-side first."""
    from repro.kernels.bitonic_sort import sort_kv_blocks
    keys = jnp.zeros((1, 8), jnp.float32)
    vals = jnp.arange(8, dtype=jnp.int32)[None]
    sk, sv = sort_kv_blocks(keys, vals, interpret=True)
    assert sorted(np.array(sv)[0].tolist()) == list(range(8))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def _ref_attn(q, k, v, causal, window=0):
    b, s, n, h = q.shape
    t, r = k.shape[1], k.shape[2]
    g = n // r
    q5 = q.reshape(b, s, r, g, h)
    lg = jnp.einsum("bsrgh,btrh->brgst", q5, k) / np.sqrt(h)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    m = jnp.ones((s, t), bool)
    if causal:
        m = m & (kpos <= qpos)
    if window:
        m = m & (kpos > qpos - window)
    lg = jnp.where(m[None, None, None], lg, -1e30)
    p = jax.nn.softmax(lg, -1)
    o = jnp.einsum("brgst,btrh->bsrgh", p, v)
    return o.reshape(b, s, n, h)


@pytest.mark.parametrize("b,s,n,r,h,causal,win", [
    (2, 128, 4, 2, 32, True, 0),
    (1, 100, 6, 6, 16, True, 0),     # MHA, ragged length
    (2, 64, 4, 1, 32, True, 24),     # MQA + sliding window
    (1, 96, 8, 2, 16, False, 0),     # non-causal
])
def test_flash_attention_vs_reference(b, s, n, r, h, causal, win):
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(s * 7 + n)
    q = jnp.asarray(rng.standard_normal((b, s, n, h)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, r, h)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, r, h)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          q_block=32, k_block=32)
    exp = _ref_attn(q, k, v, causal, win)
    np.testing.assert_allclose(np.array(out), np.array(exp), atol=3e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    out = flash_attention(q, k, v, q_block=32, k_block=32)
    exp = _ref_attn(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), True)
    np.testing.assert_allclose(np.array(out, np.float32), np.array(exp),
                               atol=3e-2)
