"""Registry-wide differential fuzzing: every backend vs the jnp oracle.

Property: for ANY workload a backend's Capabilities claim to handle —
random shapes, axes, dtypes, direction, stability, k, and adversarial
value distributions (duplicate-heavy, all-equal) — the front door must
return element-exactly what ``jnp.sort`` / ``jnp.argsort`` return, with
argsort ties following the documented convention (ties keep *ascending*
index order in both directions).

The sweep is capability-driven: backends are pulled from the live
registry, so a newly registered engine is fuzzed with zero edits here,
and a backend is only exercised on workloads its declaration admits
(dtype claims, the bit-serial simulator's paper-scale n, the packed
(key, index) width limits of the imc/distributed argsort composites).

Runs on real hypothesis when installed, else on the deterministic replay
shim (tests/_hypothesis_stub.py) — the ``floats``/``tuples``/``composite``
strategies below are exactly the surface the shim grew for this suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

import repro.sort as rsort
from repro.core import keycodec, sortspec

# dtypes spanning every codec kind (unsigned / signed / float) and width
DTYPES = ("float32", "int32", "uint16", "int8", "float16", "bfloat16")
DISTRIBUTIONS = ("uniform", "dup_heavy", "all_equal")

# the bit-serial SRAM simulator targets the paper's N=8 macro (and its
# reconstructed bitonic network only addresses power-of-two n >= 2);
# fuzzing it at engine sizes would be all simulation time for no coverage
SRAM_MAX_N = 8


def _values(seed: int, shape, dtype_name: str, dist: str) -> jnp.ndarray:
    """Integer-valued keys exactly representable in every fuzzed dtype."""
    rng = np.random.default_rng(seed)
    lo, hi = (0, 100) if dtype_name.startswith("uint") else (-100, 100)
    if dist == "uniform":
        raw = rng.integers(lo, hi, size=shape)
    elif dist == "dup_heavy":
        raw = rng.integers(0, 4, size=shape)
    else:                                    # all_equal — splitter/tie worst case
        raw = np.full(shape, rng.integers(lo, hi))
    return jnp.asarray(raw).astype(jnp.dtype(dtype_name))


@st.composite
def sort_cases(draw):
    shape = draw(st.tuples(st.integers(1, 2),
                           st.sampled_from([1, 2, 5, 8, 17, 33])))
    return {
        "seed": draw(st.integers(0, 2**31 - 1)),
        "shape": shape,
        "dtype": draw(st.sampled_from(DTYPES)),
        "dist": draw(st.sampled_from(DISTRIBUTIONS)),
        "descending": draw(st.booleans()),
        "axis": draw(st.sampled_from([-1, 0])),
        # top-k fraction of n (resolved against the sorted axis length)
        "k_frac": draw(st.floats(0.0, 1.0)),
        "stable": draw(st.booleans()),
    }


def _backends_for(dtype_name: str, n: int, *, sorts: bool = False):
    for name in sorted(sortspec.backend_names()):
        caps = sortspec.get_backend(name).capabilities
        if caps.dtypes is not None and dtype_name not in caps.dtypes:
            continue
        if caps.substrate == "sram" and (n > SRAM_MAX_N or n < 2
                                         or n & (n - 1)):
            continue
        if sorts and not caps.supports_sort:
            continue        # selection-only engines run no full sorts
        yield name, caps


def _composite_argsort_fits(name: str, dtype_name: str, n: int) -> bool:
    """imc / distributed argsort pack through keycodec.argsort_composite;
    combinations beyond its 32-bit word raise by contract — skipped here."""
    if name not in ("imc", "distributed"):
        return True
    return keycodec.composite_fits(dtype_name, n)


def _f64(a) -> np.ndarray:
    return np.asarray(a).astype(np.float64)


def _ref_argsort(x, axis, descending):
    return np.asarray(jnp.argsort(x, axis=axis, stable=True,
                                  descending=descending))


@given(sort_cases())
@settings(max_examples=5, deadline=None)
def test_fuzz_sort_matches_jnp(case):
    x = _values(case["seed"], case["shape"], case["dtype"], case["dist"])
    axis, desc = case["axis"], case["descending"]
    n = x.shape[axis]
    ref = _f64(jnp.sort(x, axis=axis))
    if desc:
        ref = np.flip(ref, axis)
    for name, _caps in _backends_for(case["dtype"], n, sorts=True):
        out = rsort.sort(x, axis=axis, descending=desc, method=name)
        np.testing.assert_array_equal(
            _f64(out), ref,
            err_msg=f"{name}/{case['dtype']}/{case['dist']}/n={n}/"
                    f"axis={axis}/desc={desc}")


@given(sort_cases())
@settings(max_examples=5, deadline=None)
def test_fuzz_argsort_tie_convention(case):
    """Element-exact vs the stable jnp.argsort in BOTH directions — the
    documented ties-keep-ascending convention.  ``stable=True`` adds the
    engine's forced-stable pipeline on top of each backend request."""
    x = _values(case["seed"], case["shape"], case["dtype"], case["dist"])
    axis, desc = case["axis"], case["descending"]
    n = x.shape[axis]
    ref = _ref_argsort(x, axis, desc)
    for name, _caps in _backends_for(case["dtype"], n, sorts=True):
        if not _composite_argsort_fits(name, case["dtype"], n):
            continue
        order = rsort.argsort(x, axis=axis, descending=desc, method=name,
                              stable=case["stable"])
        np.testing.assert_array_equal(
            np.asarray(order), ref,
            err_msg=f"{name}/{case['dtype']}/{case['dist']}/n={n}/"
                    f"axis={axis}/desc={desc}/stable={case['stable']}")


@given(sort_cases())
@settings(max_examples=5, deadline=None)
def test_fuzz_sort_kv_payload_follows_keys(case):
    """kv claims: sorted keys match the oracle and the payload is the
    applied permutation; stable backends must reproduce it exactly."""
    x = _values(case["seed"], case["shape"], case["dtype"], case["dist"])
    axis, desc = case["axis"], case["descending"]
    n = x.shape[axis]
    payload = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32).reshape(
            [n if a == axis % x.ndim else 1 for a in range(x.ndim)]),
        x.shape)
    key_ref = _f64(jnp.sort(x, axis=axis))
    if desc:
        key_ref = np.flip(key_ref, axis)
    for name, caps in _backends_for(case["dtype"], n, sorts=True):
        if not caps.supports_kv:
            continue
        sk, sv = rsort.sort_kv(x, payload, axis=axis, descending=desc,
                               method=name)
        msg = f"{name}/{case['dtype']}/{case['dist']}/n={n}/axis={axis}"
        np.testing.assert_array_equal(_f64(sk), key_ref, err_msg=msg)
        # payload is the permutation that produces the sorted keys
        np.testing.assert_array_equal(
            _f64(np.take_along_axis(np.asarray(x), np.asarray(sv),
                                    axis % x.ndim)),
            _f64(sk), err_msg=msg)
        if caps.stable:
            np.testing.assert_array_equal(
                np.asarray(sv), _ref_argsort(x, axis, desc), err_msg=msg)


@given(sort_cases())
@settings(max_examples=5, deadline=None)
def test_fuzz_topk_matches_lax(case):
    x = _values(case["seed"], case["shape"], case["dtype"], case["dist"])
    axis = case["axis"]
    n = x.shape[axis]
    k = max(1, min(n, round(case["k_frac"] * n)))
    xl = jnp.moveaxis(x, axis, -1)
    vr, _ = jax.lax.top_k(xl, k)
    for name, caps in _backends_for(case["dtype"], n):
        if not caps.supports_topk:
            continue
        v, i = rsort.topk(x, k, axis=axis, method=name)
        v = jnp.moveaxis(v, axis, -1)
        i = jnp.moveaxis(i, axis, -1)
        msg = f"{name}/{case['dtype']}/{case['dist']}/n={n}/k={k}"
        np.testing.assert_array_equal(_f64(v), _f64(vr), err_msg=msg)
        # indices may differ on ties, but must gather the same values
        np.testing.assert_array_equal(
            _f64(np.take_along_axis(np.asarray(xl), np.asarray(i), -1)),
            _f64(vr), err_msg=msg)


# ---------------------------------------------------------------------------
# top-k lens: exact-k everywhere (k extremes, tie floods, extreme keys, kv)
# ---------------------------------------------------------------------------

def _extreme_values(seed: int, shape, dtype_name: str) -> jnp.ndarray:
    """Keys stacked with the dtype's own extremes: max/min (and ±inf, ±0.0
    for floats) mixed into a duplicate-heavy body — the exact regime the
    threshold-mask top-k bugs lived in."""
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype_name) if dtype_name != "bfloat16" else np.float32
    if np.issubdtype(dt, np.integer):
        info = np.iinfo(dt)
        pool = np.asarray([info.max, info.min, 0, 1, info.max, info.min],
                          dtype=np.int64)
    else:
        pool = np.asarray([np.inf, -np.inf, 0.0, -0.0,
                           float(np.finfo(np.float32).max), 1.0])
    body = rng.integers(0, 3, size=np.prod(shape))
    x = pool[rng.integers(0, len(pool), size=np.prod(shape))]
    use_body = rng.random(np.prod(shape)) < 0.5
    x = np.where(use_body, body.astype(x.dtype), x)
    return jnp.asarray(x.reshape(shape)).astype(jnp.dtype(dtype_name))


@st.composite
def topk_lens_cases(draw):
    return {
        "seed": draw(st.integers(0, 2**31 - 1)),
        "n": draw(st.sampled_from([1, 2, 7, 33])),
        "dtype": draw(st.sampled_from(DTYPES)),
        "dist": draw(st.sampled_from(("dup_heavy", "all_equal", "extreme"))),
        "k_mode": draw(st.sampled_from(("one", "half", "all"))),
    }


@given(topk_lens_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_topk_lens_exact_k(case):
    """Every backend claiming topk (selection engines included) vs
    ``jax.lax.top_k`` at the k extremes over adversarial keys.  Exactly k
    come back, values element-exact; selection backends must also match
    lax's tie rule (lowest index first) index-exactly."""
    n = case["n"]
    k = {"one": 1, "half": max(1, n // 2), "all": n}[case["k_mode"]]
    if case["dist"] == "extreme":
        x = _extreme_values(case["seed"], (2, n), case["dtype"])
    else:
        x = _values(case["seed"], (2, n), case["dtype"], case["dist"])
    vr, ir = jax.lax.top_k(x, k)
    for name, caps in _backends_for(case["dtype"], n):
        if not caps.supports_topk:
            continue
        v, i = rsort.topk(x, k, method=name)
        msg = f"{name}/{case['dtype']}/{case['dist']}/n={n}/k={k}"
        assert v.shape == (2, k) and i.shape == (2, k), msg
        np.testing.assert_array_equal(_f64(v), _f64(vr), err_msg=msg)
        np.testing.assert_array_equal(
            _f64(np.take_along_axis(np.asarray(x), np.asarray(i), -1)),
            _f64(vr), err_msg=msg)
        if caps.selection:
            # exact-k tie convention: the selection subsystem reproduces
            # lax.top_k's lowest-index-first rule bit-exactly
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ir),
                                          err_msg=msg)


@given(topk_lens_cases())
@settings(max_examples=5, deadline=None)
def test_fuzz_topk_lens_kv_payload(case):
    """The selection kernel's kv variant: the payload rides the exact-k
    selection — gathering the payload through the returned indices equals
    the kv output, under tie floods and extreme keys."""
    from repro.kernels import radix_select as _sel
    n = case["n"]
    k = {"one": 1, "half": max(1, n // 2), "all": n}[case["k_mode"]]
    if case["dist"] == "extreme":
        x = _extreme_values(case["seed"], (2, n), case["dtype"])
    else:
        x = _values(case["seed"], (2, n), case["dtype"], case["dist"])
    payload = jnp.asarray(
        np.random.default_rng(case["seed"] ^ 0xABC).integers(
            -999, 999, (2, n)).astype(np.int32))
    v, pv, i = _sel.select_topk_kv(x, payload, k)
    vr, ir = jax.lax.top_k(x, k)
    msg = f"{case['dtype']}/{case['dist']}/n={n}/k={k}"
    np.testing.assert_array_equal(_f64(v), _f64(vr), err_msg=msg)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir), err_msg=msg)
    np.testing.assert_array_equal(
        np.asarray(pv),
        np.take_along_axis(np.asarray(payload), np.asarray(ir), -1),
        err_msg=msg)


# ---------------------------------------------------------------------------
# spill lens: the out-of-core tier vs the jnp oracle at forced tiny chunks
# ---------------------------------------------------------------------------

from repro.engine import spill as _spill  # noqa: E402

# small enough that every fuzzed n spans several chunks (f32: 16 elems),
# large enough to clear tuning.MIN_SPILL_THRESHOLD_BYTES
SPILL_CHUNK_BYTES = 64
# bfloat16 rides the pipeline as its uint16 keycodec encoding — fuzzing
# it here pins the host-side encode/decode mirror bit-exactly
SPILL_DTYPES = ("float32", "int32", "uint16", "int8", "float16",
                "bfloat16")


@st.composite
def spill_cases(draw):
    return {
        "seed": draw(st.integers(0, 2**31 - 1)),
        # uneven tails on purpose: primes and off-by-ones around the
        # 16/32/64-element chunk sizes the forced threshold produces
        "n": draw(st.sampled_from([1, 15, 16, 17, 33, 100, 257])),
        "dtype": draw(st.sampled_from(SPILL_DTYPES)),
        "dist": draw(st.sampled_from(DISTRIBUTIONS)),
        "descending": draw(st.booleans()),
    }


@given(spill_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_spill_sort_matches_jnp(case):
    x = _values(case["seed"], (case["n"],), case["dtype"], case["dist"])
    desc = case["descending"]
    ref = _f64(jnp.sort(x))
    if desc:
        ref = ref[::-1]
    out = _spill.spill_sort(np.asarray(x), descending=desc,
                            chunk_bytes=SPILL_CHUNK_BYTES)
    np.testing.assert_array_equal(
        _f64(out), ref,
        err_msg=f"spill/{case['dtype']}/{case['dist']}/n={case['n']}/"
                f"desc={desc}")


@given(spill_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_spill_argsort_is_stable(case):
    """The kv spill path claims stability: the permutation must be
    element-exact against the stable jnp.argsort in both directions —
    across chunk boundaries, where a tie between runs is decided by the
    host merge's cursor arithmetic rather than one device sort."""
    x = _values(case["seed"], (case["n"],), case["dtype"], case["dist"])
    desc = case["descending"]
    order = _spill.spill_argsort(np.asarray(x), descending=desc,
                                 chunk_bytes=SPILL_CHUNK_BYTES)
    np.testing.assert_array_equal(
        np.asarray(order), _ref_argsort(x, -1, desc),
        err_msg=f"spill/{case['dtype']}/{case['dist']}/n={case['n']}/"
                f"desc={desc}")


# ---------------------------------------------------------------------------
# hierarchical lens: the two-level (ICI/DCN) schedule vs flat vs the
# jnp oracle on a 2x4 mesh (runs on the multi-device CI job; skipped
# below 8 local devices, where no two-tier grid is expressible)
# ---------------------------------------------------------------------------

import pytest  # noqa: E402

from repro.engine import samplesort as _samplesort  # noqa: E402

HIER_DTYPES = ("float32", "int32", "uint16")


@st.composite
def hier_cases(draw):
    return {
        "seed": draw(st.integers(0, 2**31 - 1)),
        # uneven shard tails, sub-device-count n, and pow2 shapes
        "n": draw(st.sampled_from([5, 64, 257, 1003, 2048])),
        "dtype": draw(st.sampled_from(HIER_DTYPES)),
        "dist": draw(st.sampled_from(DISTRIBUTIONS)),
        "descending": draw(st.booleans()),
    }


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="2x4 hierarchical mesh needs 8 local devices")
@given(hier_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_hier_sample_sort_matches_flat_and_jnp(case):
    mesh = jax.make_mesh((2, 4), ("host", "dev"))
    x = _values(case["seed"], (case["n"],), case["dtype"], case["dist"])
    desc = case["descending"]
    hier = _samplesort.sample_sort(x, mesh, None, descending=desc,
                                   hierarchical=True)
    flat = _samplesort.sample_sort(x, mesh, None, descending=desc,
                                   hierarchical=False)
    ref = _f64(jnp.sort(x))
    if desc:
        ref = ref[::-1]
    msg = f"hier/{case['dtype']}/{case['dist']}/n={case['n']}/desc={desc}"
    np.testing.assert_array_equal(_f64(hier), ref, err_msg=msg)
    np.testing.assert_array_equal(_f64(hier), _f64(flat), err_msg=msg)


# ---------------------------------------------------------------------------
# relational lens: every repro.relational op vs its numpy reference
# ---------------------------------------------------------------------------

import repro.relational as rel  # noqa: E402

REL_DTYPES = ("float32", "int32", "uint16", "int8")
# empty-group / dup-heavy / all-equal / signed-zero distributions are the
# regimes where a compaction or boundary-mask bug would hide
REL_DISTRIBUTIONS = ("uniform", "dup_heavy", "all_equal", "signed_zero")


def _rel_values(seed: int, n: int, dtype_name: str, dist: str):
    if dist == "signed_zero":
        if not dtype_name.startswith("float"):
            dist = "dup_heavy"              # ints have one zero
        else:
            rng = np.random.default_rng(seed)
            x = np.where(rng.random(n) < 0.5, -0.0,
                         rng.integers(0, 3, n).astype(np.float64))
            return jnp.asarray(x).astype(jnp.dtype(dtype_name))
    return _values(seed, (n,), dtype_name, dist)


@st.composite
def rel_cases(draw):
    return {
        "seed": draw(st.integers(0, 2**31 - 1)),
        "n": draw(st.sampled_from([0, 1, 2, 7, 33])),
        "dtype": draw(st.sampled_from(REL_DTYPES)),
        "dist": draw(st.sampled_from(REL_DISTRIBUTIONS)),
    }


@given(rel_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_relational_unique_matches_numpy(case):
    x = np.asarray(_rel_values(case["seed"], case["n"], case["dtype"],
                               case["dist"]))
    ref_v, ref_inv, ref_c = np.unique(x, return_inverse=True,
                                      return_counts=True)
    u = rel.unique(x, return_inverse=True, return_counts=True)
    m = int(u.n_unique)
    msg = f"{case['dtype']}/{case['dist']}/n={case['n']}"
    assert m == len(ref_v), msg
    np.testing.assert_array_equal(_f64(u.values[:m]), _f64(ref_v),
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(u.inverse), ref_inv,
                                  err_msg=msg)
    np.testing.assert_array_equal(np.asarray(u.counts[:m]), ref_c,
                                  err_msg=msg)


@given(rel_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_relational_group_by_matches_scatter_reference(case):
    k = np.asarray(_rel_values(case["seed"], case["n"], case["dtype"],
                               case["dist"]))
    v = np.random.default_rng(case["seed"] ^ 0x5EED).integers(
        0, 50, case["n"]).astype(np.int32)          # the kv payload
    gb = rel.group_by(k, v, agg=("sum", "min", "max", "count", "mean"))
    ref_k, inv = np.unique(k, return_inverse=True)
    g = len(ref_k)
    msg = f"{case['dtype']}/{case['dist']}/n={case['n']}"
    assert int(gb.n_groups) == g, msg
    np.testing.assert_array_equal(_f64(gb.keys[:g]), _f64(ref_k),
                                  err_msg=msg)
    rsum = np.zeros(g, np.int64)
    np.add.at(rsum, inv, v)
    rmin = np.full(g, np.iinfo(np.int32).max)
    np.minimum.at(rmin, inv, v)
    rmax = np.full(g, np.iinfo(np.int32).min)
    np.maximum.at(rmax, inv, v)
    rcnt = np.bincount(inv, minlength=g)
    refs = (rsum.astype(np.int32), rmin, rmax, rcnt,
            rsum.astype(np.float32)
            / np.maximum(rcnt, 1).astype(np.float32))
    for got, want in zip(gb.aggregates, refs):
        np.testing.assert_array_equal(np.asarray(got[:g]), want[:g]
                                      if g else want, err_msg=msg)


@given(rel_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_relational_join_matches_searchsorted_reference(case):
    lk = np.asarray(_rel_values(case["seed"], case["n"], case["dtype"],
                                case["dist"]))
    rk = np.asarray(_rel_values(case["seed"] ^ 0xA5A5, max(1, case["n"]),
                                case["dtype"], case["dist"]))
    j = rel.join(lk, rk)
    p = int(j.n_pairs)
    # reference via stable sorts + searchsorted runs (the documented pair
    # order: ascending key, left input order, right input order)
    ol = np.argsort(lk, kind="stable")
    orr = np.argsort(rk, kind="stable")
    sl, sr = lk[ol], rk[orr]
    pairs = []
    for pos, key in enumerate(sl):
        a, b = np.searchsorted(sr, key, "left"), \
            np.searchsorted(sr, key, "right")
        pairs.extend((int(ol[pos]), int(orr[t])) for t in range(a, b))
    got = list(zip(np.asarray(j.left_idx[:p]).tolist(),
                   np.asarray(j.right_idx[:p]).tolist()))
    assert got == pairs, f"{case['dtype']}/{case['dist']}/n={case['n']}"


@given(rel_cases())
@settings(max_examples=6, deadline=None)
def test_fuzz_relational_rle_and_histogram(case):
    x = np.asarray(_rel_values(case["seed"], case["n"], case["dtype"],
                               case["dist"]))
    msg = f"{case['dtype']}/{case['dist']}/n={case['n']}"
    r = rel.run_length_encode(x)
    dec = rel.rle_decode(r.values, r.run_lengths, case["n"])
    np.testing.assert_array_equal(_f64(dec), _f64(np.sort(x)),
                                  err_msg=msg)
    assert int(np.asarray(r.run_lengths).sum()) == case["n"], msg
    h = rel.histogram(x, 8)
    ref, _ = np.histogram(x.astype(np.float32),
                          bins=np.asarray(h.edges))
    np.testing.assert_array_equal(np.asarray(h.counts), ref, err_msg=msg)
