"""sort_api: all backends agree; gradients are safe in this jax build."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import sort_api


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 7, 32, 100]),
       st.booleans())
@settings(max_examples=25, deadline=None)
def test_backends_agree(seed, n, descending):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((3, n)), dtype=jnp.float32)
    ref = sort_api.sort(x, method="xla", descending=descending)
    for m in ("bitonic", "pallas"):
        out = sort_api.sort(x, method=m, descending=descending)
        np.testing.assert_allclose(np.array(out), np.array(ref), rtol=0,
                                   atol=0)


def test_imc_backend_sorts_ints():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 16, size=(4, 8)).astype(np.uint32)
    out = sort_api.sort(jnp.asarray(x), method="imc")
    np.testing.assert_array_equal(np.array(out), np.sort(x, -1))


@given(st.integers(0, 2**31 - 1), st.sampled_from([(64, 4), (100, 7)]))
@settings(max_examples=15, deadline=None)
def test_topk_matches_lax(seed, nk):
    n, k = nk
    x = jnp.asarray(np.random.default_rng(seed).standard_normal((2, n)),
                    dtype=jnp.float32)
    vr, ir = jax.lax.top_k(x, k)
    for m in ("bitonic", "pallas"):
        v, i = sort_api.topk(x, k, method=m)
        np.testing.assert_allclose(np.array(v), np.array(vr), atol=0)
        # indices may differ on ties; values gathered must match
        np.testing.assert_allclose(
            np.take_along_axis(np.array(x), np.array(i), -1), np.array(vr))


def test_argsort_is_valid_permutation():
    x = jnp.asarray(np.random.default_rng(5).standard_normal((2, 33)),
                    dtype=jnp.float32)
    order = sort_api.argsort(x, method="bitonic")
    out = np.take_along_axis(np.array(x), np.array(order), -1)
    np.testing.assert_allclose(out, np.sort(np.array(x), -1))


def test_sort_gradients_all_backends():
    """This environment's lax.sort JVP is broken; our custom VJPs bypass."""
    x = jnp.asarray(np.random.default_rng(6).standard_normal((2, 16)),
                    dtype=jnp.float32)
    expected = None
    for m in ("xla", "bitonic", "pallas"):
        g = jax.grad(lambda v: sort_api.sort(v, method=m)[..., -4:].sum())(x)
        if expected is None:
            expected = np.array(g)
        np.testing.assert_allclose(np.array(g), expected)


def test_top_p_mask_mass():
    x = jnp.asarray(np.random.default_rng(7).standard_normal((4, 50)) * 3,
                    dtype=jnp.float32)
    mask = sort_api.top_p_mask(x, 0.9)
    probs = np.array(jax.nn.softmax(x, -1))
    mass = (probs * np.array(mask)).sum(-1)
    assert (mass >= 0.9 - 1e-5).all()
    # minimality: removing the smallest kept prob drops below p
    for row in range(4):
        kept = probs[row][np.array(mask)[row]]
        assert mass[row] - kept.min() < 0.9 + 1e-5
