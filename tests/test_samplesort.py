"""Single-round distributed sample-sort: unit pieces, mesh runs, dispatch.

Runs correctly at any local device count: on the tier-1 single-device job
the mesh degenerates to D=1 (plus one subprocess test that forces 8
simulated devices), while the CI multi-device job executes this whole file
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every
collective (bucket all-to-all, rank rebalance, splitter all-gather) runs
at real D>1 on every push.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sort as rsort
from repro.core import cost_model, distributed_sort as ds, keycodec
from repro.engine import planner, samplesort


def _mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


# ---------------------------------------------------------------------------
# host-level unit pieces
# ---------------------------------------------------------------------------

def test_select_splitters_regular_quantiles():
    pooled = jnp.arange(64, dtype=jnp.uint32)
    sp = np.asarray(samplesort.select_splitters(pooled, 4))
    np.testing.assert_array_equal(sp, [16, 32, 48])
    assert samplesort.select_splitters(pooled, 1).shape == (0,)


@pytest.mark.parametrize("use_histogram", [False, True])
def test_bucket_bounds_partition_sorted_shard(use_histogram):
    """Both partition routes (binary search / radix one-hot histogram
    kernel) must cut identical contiguous buckets: elements equal to a
    splitter go to the lower bucket."""
    ks = jnp.asarray(np.sort(np.array([0, 1, 1, 3, 3, 3, 7, 9, 9, 12],
                                      np.uint32)))
    splitters = jnp.asarray([1, 3, 9], jnp.uint32)
    b = np.asarray(samplesort.bucket_bounds(
        ks, splitters, use_histogram=use_histogram))
    np.testing.assert_array_equal(b, [0, 3, 6, 9, 10])
    k = np.asarray(ks)
    for d in range(4):
        seg = k[b[d]:b[d + 1]]
        lo = -1 if d == 0 else int(splitters[d - 1])
        hi = np.inf if d == 3 else int(splitters[d])
        assert ((seg > lo) & (seg <= hi)).all()


def test_bucket_bounds_all_equal_worst_case():
    ks = jnp.full((16,), 5, jnp.uint32)
    b = np.asarray(samplesort.bucket_bounds(
        ks, jnp.full((3,), 5, jnp.uint32)))
    np.testing.assert_array_equal(b, [0, 16, 16, 16, 16])  # all to bucket 0


def test_bucket_bounds_routes_agree_random():
    rng = np.random.default_rng(3)
    ks = jnp.asarray(np.sort(rng.integers(0, 1000, 257)).astype(np.uint32))
    sp = jnp.asarray(np.sort(rng.integers(0, 1000, 7)).astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(samplesort.bucket_bounds(ks, sp, use_histogram=False)),
        np.asarray(samplesort.bucket_bounds(ks, sp, use_histogram=True)))


# ---------------------------------------------------------------------------
# end-to-end over the local mesh (D=1 on tier-1, D=8 on the multidev job)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,dist", [
    (1024, "uniform"),        # evenly divisible by any CI device count
    (1234, "uniform"),        # uneven shards
    (333, "dup_heavy"),       # splitter ties everywhere
    (1000, "all_equal"),      # worst-case skew: one bucket takes all
    (3, "uniform"),           # n < D on the multidev job
])
def test_sample_sort_matches_np(n, dist):
    rng = np.random.default_rng(n)
    if dist == "uniform":
        x = rng.standard_normal(n).astype(np.float32)
    elif dist == "dup_heavy":
        x = rng.integers(0, 4, n).astype(np.float32)
    else:
        x = np.full(n, 2.5, np.float32)
    out = np.asarray(samplesort.sample_sort(jnp.asarray(x), _mesh()))
    np.testing.assert_array_equal(out, np.sort(x))


@pytest.mark.parametrize("descending", [False, True])
def test_sample_sort_kv_uneven_extreme_keys(descending):
    """Payloads survive the bucket exchange even when genuine keys equal
    the capacity/pad fill (dtype max) — validity is explicit, never
    inferred from sentinels."""
    rng = np.random.default_rng(17)
    k = rng.integers(0, 4, 333).astype(np.int32)
    k[k == 3] = np.iinfo(np.int32).max
    v = np.arange(333, dtype=np.int32)
    sk, sv = samplesort.sample_sort(jnp.asarray(k), _mesh(),
                                    values=jnp.asarray(v),
                                    descending=descending)
    sk, sv = np.asarray(sk), np.asarray(sv)
    ref = np.sort(k)
    np.testing.assert_array_equal(sk, np.flip(ref) if descending else ref)
    np.testing.assert_array_equal(k[sv], sk)     # payload matches its key
    assert len(set(sv.tolist())) == v.size       # a true permutation


@pytest.mark.parametrize("dtype", sorted(keycodec.SUPPORTED))
def test_sample_sort_every_codec_dtype(dtype):
    rng = np.random.default_rng(29)
    raw = rng.integers(0, 100, 200) if dtype.startswith("uint") \
        else rng.integers(-100, 100, 200)
    x = jnp.asarray(raw).astype(jnp.dtype(dtype))
    out = np.asarray(samplesort.sample_sort(x, _mesh())).astype(np.float64)
    np.testing.assert_array_equal(
        out, np.sort(np.asarray(x).astype(np.float64)))


def test_sample_sort_histogram_partition_path():
    rng = np.random.default_rng(31)
    x = jnp.asarray(rng.standard_normal(640), jnp.float32)
    out = np.asarray(samplesort.sample_sort(x, _mesh(), use_histogram=True))
    np.testing.assert_array_equal(out, np.sort(np.asarray(x)))


def test_sample_sort_rejects_bad_inputs():
    with pytest.raises(ValueError, match="1-D"):
        samplesort.sample_sort(jnp.zeros((2, 8), jnp.float32), _mesh())
    with pytest.raises(ValueError, match="keycodec dtype"):
        samplesort.sample_sort(jnp.zeros(8, jnp.complex64), _mesh())
    with pytest.raises(ValueError, match="values shape"):
        samplesort.sample_sort(jnp.zeros(8, jnp.float32), _mesh(),
                               values=jnp.zeros(9, jnp.int32))


# ---------------------------------------------------------------------------
# the unified entry point + planner dispatch
# ---------------------------------------------------------------------------

def test_entry_point_strategies_agree():
    mesh = _mesh()
    n_dev = mesh.shape["data"]
    x = jnp.asarray(np.random.default_rng(5).standard_normal(n_dev * 256),
                    jnp.float32)
    ref = np.sort(np.asarray(x))
    for strategy in ("auto", "sample", "oddeven"):
        out = np.asarray(ds.distributed_sort(x, mesh, strategy=strategy))
        np.testing.assert_array_equal(out, ref, err_msg=strategy)


def test_entry_point_routes_inexpressible_requests_to_sample():
    """descending / payload / uneven length cannot run on odd-even: auto
    must route to sample-sort, and forcing oddeven must refuse."""
    mesh = _mesh()
    n = mesh.shape["data"] * 16 + 1                  # uneven
    x = jnp.asarray(np.random.default_rng(7).standard_normal(n), jnp.float32)
    out = np.asarray(ds.distributed_sort(x, mesh, strategy="auto",
                                         descending=True))
    np.testing.assert_array_equal(out, np.flip(np.sort(np.asarray(x))))
    sk, sv = ds.distributed_sort(x, mesh, strategy="auto",
                                 values=jnp.arange(n, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(sk), np.sort(np.asarray(x)))
    for bad in (dict(descending=True), dict(values=jnp.zeros(n))):
        with pytest.raises(ValueError, match="oddeven strategy needs"):
            ds.distributed_sort(x, mesh, strategy="oddeven", **bad)
    with pytest.raises(ValueError, match="strategy must be"):
        ds.distributed_sort(x, mesh, strategy="bogus")


def test_choose_distributed_crossover():
    """Odd-even keeps tiny workloads (fewer collective launches); the
    single-round exchange wins once per-round merge work dominates — and
    the crossover moves with D, since odd-even pays D rounds."""
    small = planner.choose_distributed(4096, 8)
    large = planner.choose_distributed(1 << 20, 8)
    assert set(small.costs) == {"sample", "oddeven"}
    assert small.strategy == "oddeven"
    assert large.strategy == "sample"
    assert all(np.isfinite(c) for c in large.costs.values())
    # the sample advantage widens with n at fixed D: odd-even's per-round
    # merge carries the growing log factor, the exchange bill does not
    adv = [planner.choose_distributed(n, 8).costs
           for n in (1 << 18, 1 << 20, 1 << 22)]
    ratios = [c["oddeven"] / c["sample"] for c in adv]
    assert ratios == sorted(ratios)


def test_collective_cost_ns_terms():
    c = cost_model.DeviceSortConstants()
    base = cost_model.collective_cost_ns(1, 0, 4, c)
    assert base == c.collective_alpha                 # pure launch latency
    one = cost_model.collective_cost_ns(1, 1000, 4, c)
    eight = cost_model.collective_cost_ns(8, 1000, 4, c)
    assert eight - base == pytest.approx(8 * (one - base))
    with pytest.raises(ValueError, match="no distributed cost model"):
        cost_model.distributed_sort_cost_ns("bogus", 100, 2)


# ---------------------------------------------------------------------------
# SortSpec mesh fields through the front door
# ---------------------------------------------------------------------------

def test_spec_mesh_front_door():
    mesh = _mesh()
    x = jnp.asarray(np.random.default_rng(9).standard_normal(777),
                    jnp.float32)
    out = np.asarray(rsort.sort(x, mesh=mesh, axis_name="data"))
    np.testing.assert_array_equal(out, np.sort(np.asarray(x)))
    # axis_name defaults to the mesh's first axis
    out = np.asarray(rsort.sort(x, mesh=mesh, descending=True))
    np.testing.assert_array_equal(out, np.flip(np.sort(np.asarray(x))))
    sk, sv = rsort.sort_kv(x, jnp.arange(777, dtype=jnp.int32), mesh=mesh)
    np.testing.assert_array_equal(np.asarray(x)[np.asarray(sv)],
                                  np.asarray(sk))


def test_spec_mesh_validation():
    mesh = _mesh()
    x1 = jnp.zeros(8, jnp.float32)
    with pytest.raises(ValueError, match="axis_name requires a mesh"):
        rsort.sort(x1, axis_name="data")
    with pytest.raises(ValueError, match="not in mesh axes"):
        rsort.sort(x1, mesh=mesh, axis_name="model")
    with pytest.raises(ValueError, match="flat 1-D"):
        rsort.sort(jnp.zeros((2, 8), jnp.float32), mesh=mesh)
    from repro.core.sortspec import SortSpec
    with pytest.raises(ValueError, match="plain and key-value"):
        rsort.run(SortSpec(indices=True, mesh=mesh), x1)
    with pytest.raises(ValueError, match="method must be 'auto'"):
        rsort.sort(x1, mesh=mesh, method="bitonic")
    # spec statics fold the mesh identity into external cache keys
    k1 = SortSpec(mesh=mesh).static_key((8,), jnp.float32)
    k2 = SortSpec().static_key((8,), jnp.float32)
    assert k1 != k2 and hash(k1) != hash(k2)


def test_scheduler_distributed_queue_orders_by_length():
    """serve.py's backlog sort over the mesh: the (length, position)
    composite value-sort must reproduce the local argsort schedule (on a
    1-device mesh it falls back to exactly that path).  Batches are
    anchored at the oldest queued request and filled with adjacent-length
    neighbours, so the check is: nothing dropped, every batch contains
    the then-oldest request, and each batch is a contiguous slice of the
    length-sorted backlog."""
    from repro.launch.serve import LengthSortedScheduler, Request
    # distributed_min lowered so the mesh path runs at test-sized backlogs
    sched = LengthSortedScheduler(4, mesh=_mesh(), distributed_min=2)
    rng = np.random.default_rng(41)
    lens = [int(v) for v in rng.integers(4, 64, 13)]
    for rid, ln in enumerate(lens):
        sched.submit(Request(rid=rid, prompt=np.zeros(ln, np.int32)))
    seen = []
    while sched.queue:
        oldest = sched.queue[0].rid
        backlog = sorted(len(r.prompt) for r in sched.queue)
        batch = sched.next_batch()
        assert any(r.rid == oldest for r in batch)       # anchor present
        got = sorted(len(r.prompt) for r in batch)
        # contiguous window of the sorted backlog lengths
        assert any(backlog[s:s + len(got)] == got
                   for s in range(len(backlog) - len(got) + 1))
        seen.extend(len(r.prompt) for r in batch)
    assert sorted(seen) == sorted(lens)          # nothing dropped


# ---------------------------------------------------------------------------
# forced 8-device run (covers real D>1 even on the single-device CI job)
# ---------------------------------------------------------------------------

def test_sample_sort_8dev_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.engine import samplesort
from repro.core import distributed_sort as ds
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
# sharded, uneven, duplicate-heavy kv descending — the full contract
k = rng.integers(0, 9, 1003).astype(np.int32)
v = np.arange(1003, dtype=np.int32)
sk, sv = samplesort.sample_sort(jnp.asarray(k), mesh,
                                values=jnp.asarray(v), descending=True)
sk, sv = np.asarray(sk), np.asarray(sv)
assert (sk == np.flip(np.sort(k))).all()
assert (k[sv] == sk).all() and len(set(sv.tolist())) == 1003
# explicitly sharded value sort through the unified entry point
x = rng.standard_normal(8 * 512).astype(np.float32)
xs = jax.device_put(x, NamedSharding(mesh, P("data")))
out = ds.distributed_sort(xs, mesh, strategy="sample")
assert (np.asarray(out) == np.sort(x)).all()
print("SAMPLESORT_8DEV_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    env.pop("XLA_FLAGS", None)        # the subprocess pins its own count
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "SAMPLESORT_8DEV_OK" in r.stdout, r.stderr[-2000:]
