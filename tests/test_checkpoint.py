"""Checkpointer: atomic async save, bf16 roundtrip, retention, resume."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
                   "b": jnp.arange(16, dtype=jnp.float32)},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.asarray(7)},
    }


def test_roundtrip_including_bf16(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree, extra={"next_step": 10}, blocking=True)
    restored, extra = ck.restore(10, tree)
    assert extra["next_step"] == 10
    for (p1, a), (p2, b) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t, blocking=True)
    assert ck.latest_step() == 4
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2 and steps[-1] == "step_00000004"


def test_async_save_overlaps_then_waits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(5, t)            # non-blocking
    ck.wait()
    assert ck.latest_step() == 5


def test_restore_with_shardings(tmp_path):
    """Elastic path: restore under explicit shardings on the current mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, t, blocking=True)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ck.restore(1, t, sh)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["b"]), np.asarray(t["params"]["b"]))
