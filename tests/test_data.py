"""Data pipeline determinism + sharding invariance."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_in_step():
    d1, d2 = SyntheticLM(_cfg()), SyntheticLM(_cfg())
    b1, b2 = d1.global_batch_at(5), d2.global_batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], d1.global_batch_at(6)["tokens"])


def test_shards_tile_the_global_batch():
    d = SyntheticLM(_cfg())
    g = d.global_batch_at(3)
    parts = [d.shard_at(3, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), g["tokens"])


def test_shard_invariant_to_host_count():
    """Elasticity: global sample order does not depend on dp degree."""
    d = SyntheticLM(_cfg())
    two = [d.shard_at(0, i, 2)["tokens"] for i in range(2)]
    four = [d.shard_at(0, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(two, 0),
                                  np.concatenate(four, 0))


def test_labels_are_shifted_tokens():
    d = SyntheticLM(_cfg())
    b = d.global_batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()
