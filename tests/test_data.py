"""Data pipeline determinism + sharding invariance + dedup correctness."""
import numpy as np
import pytest

from repro.data import pipeline
from repro.data.pipeline import DataConfig, SyntheticLM


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_in_step():
    d1, d2 = SyntheticLM(_cfg()), SyntheticLM(_cfg())
    b1, b2 = d1.global_batch_at(5), d2.global_batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], d1.global_batch_at(6)["tokens"])


def test_shards_tile_the_global_batch():
    d = SyntheticLM(_cfg())
    g = d.global_batch_at(3)
    parts = [d.shard_at(3, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), g["tokens"])


def test_shard_invariant_to_host_count():
    """Elasticity: global sample order does not depend on dp degree."""
    d = SyntheticLM(_cfg())
    two = [d.shard_at(0, i, 2)["tokens"] for i in range(2)]
    four = [d.shard_at(0, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(two, 0),
                                  np.concatenate(four, 0))


def test_labels_are_shifted_tokens():
    d = SyntheticLM(_cfg())
    b = d.global_batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -100).all()


def test_shard_at_rejects_bad_layouts_with_valueerror():
    d = SyntheticLM(_cfg())
    with pytest.raises(ValueError, match="global_batch=8.*n_shards=3"):
        d.shard_at(0, 0, 3)
    with pytest.raises(ValueError, match="n_shards"):
        d.shard_at(0, 0, 0)
    with pytest.raises(ValueError, match="shard index 4"):
        d.shard_at(0, 4, 4)
    with pytest.raises(ValueError, match="shard index -1"):
        d.shard_at(0, -1, 4)


# ---------------------------------------------------------------------------
# dedup: fingerprint collisions must not lose data
# ---------------------------------------------------------------------------

def _colliding_rows():
    """Two DIFFERENT length-2 rows with equal fingerprints: the hash is
    ``row[0] * 1000003 + row[1] (mod 2^32)``, so [0, 1000003] and [1, 0]
    both map to 1000003."""
    return (np.array([0, 1000003], np.int32), np.array([1, 0], np.int32))


def test_row_fingerprints_collide_on_crafted_pair():
    a, b = _colliding_rows()
    h = pipeline.row_fingerprints(np.stack([a, b]))
    assert h[0] == h[1]
    assert not np.array_equal(a, b)


@pytest.mark.parametrize("dedup_fn", [
    pipeline.dedup_rows,
    lambda t: pipeline.global_dedup(t, chunk_bytes=64),
], ids=["dedup_rows", "global_dedup"])
def test_dedup_keeps_both_rows_of_a_fingerprint_collision(dedup_fn):
    """Regression: dedup used to drop rows on fingerprint equality alone,
    silently losing one of every colliding pair.  Both colliding rows must
    survive; genuine duplicates must still be dropped (first kept)."""
    a, b = _colliding_rows()
    tokens = np.stack([a, b, a, b, np.array([5, 6], np.int32)])
    keep = dedup_fn(tokens)
    np.testing.assert_array_equal(keep, [True, True, False, False, True])


def test_global_dedup_matches_dedup_rows_and_brute_force():
    rng = np.random.default_rng(0)
    t = rng.integers(0, 4, size=(200, 3)).astype(np.int32)
    seen, ref = [], np.zeros(len(t), bool)
    for i, row in enumerate(t):
        if not any(np.array_equal(row, s) for s in seen):
            ref[i] = True
            seen.append(row)
    np.testing.assert_array_equal(pipeline.dedup_rows(t), ref)
    # forced tiny chunks: the fingerprint column spills over many runs
    np.testing.assert_array_equal(
        pipeline.global_dedup(t, chunk_bytes=128), ref)


def test_dedup_empty():
    empty = np.zeros((0, 4), np.int32)
    assert pipeline.dedup_rows(empty).shape == (0,)
    assert pipeline.global_dedup(empty).shape == (0,)
