"""Observability contract (repro.obs): the design rules trace.py promises.

  * spans nest via the contextvar stack and record depth/parent;
  * histograms answer percentiles within one bucket width of numpy;
  * disabled mode allocates nothing, records nothing, and leaves traced
    function outputs bit-identical;
  * the planner emits exactly one ``plan_decision`` event per cache miss
    and zero per cache hit.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sort as rsort
from repro.engine import planner
from repro.obs import metrics, report, trace


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends disabled with empty stores — obs state
    is process-global and must not leak between tests (or into the rest
    of the suite)."""
    trace.disable()
    trace.clear()
    metrics.reset()
    planner.clear_plan_cache()
    yield
    trace.disable()
    trace.clear()
    metrics.reset()
    planner.clear_plan_cache()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_parent():
    with trace.tracing():
        with trace.trace("outer", n=4):
            with trace.trace("inner"):
                with trace.trace("leaf"):
                    pass
            with trace.trace("sibling"):
                pass
    by_name = {s["name"]: s for s in trace.spans()}
    assert by_name["outer"]["depth"] == 0
    assert by_name["outer"]["parent"] is None
    assert by_name["inner"]["depth"] == 1
    assert by_name["inner"]["parent"] == "outer"
    assert by_name["leaf"]["depth"] == 2
    assert by_name["leaf"]["parent"] == "inner"
    assert by_name["sibling"]["parent"] == "outer"
    # completion order: children land before their parents
    names = [s["name"] for s in trace.spans()]
    assert names.index("leaf") < names.index("inner") < names.index("outer")
    assert by_name["outer"]["attrs"] == {"n": 4}


def test_span_fence_records_device_time_eagerly():
    x = jnp.arange(1024, dtype=jnp.float32)
    with trace.tracing():
        with trace.trace("eager") as sp:
            sp.fence(jnp.sort(x))
    (rec,) = trace.spans()
    assert rec["device_ms"] is not None
    assert rec["wall_ms"] >= rec["device_ms"] >= 0.0


def test_span_fence_is_jit_safe():
    """Under jit the fence sees tracers: it must not block (device_ms
    stays None) and the traced function must stay compilable."""
    x = jnp.arange(1024, dtype=jnp.float32)

    def fn(v):
        with trace.trace("traced") as sp:
            return sp.fence(jnp.sort(v))

    with trace.tracing():
        out = jax.jit(fn)(x)
        out.block_until_ready()
    recs = [s for s in trace.spans() if s["name"] == "traced"]
    assert recs and all(r["device_ms"] is None for r in recs)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.sort(np.arange(1024, dtype=np.float32)))


def test_span_set_attaches_mid_span_attrs():
    with trace.tracing():
        with trace.trace("s") as sp:
            sp.set(buckets=7)
    (rec,) = trace.spans()
    assert rec["attrs"]["buckets"] == 7


def test_to_json_round_trips():
    with trace.tracing():
        with trace.trace("j", dtype=jnp.float32, arr=np.int32(3)):
            pass
        trace.record_event("k", value=np.float64(1.5))
    doc = json.loads(trace.to_json())
    assert doc["spans"][0]["name"] == "j"
    assert doc["events"][0]["kind"] == "k"


# ---------------------------------------------------------------------------
# histograms
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    """Log-interpolated bucket percentiles vs numpy on lognormal samples:
    accurate to roughly one bucket width (~7% with 32 buckets/decade)."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=2.0, sigma=1.5, size=20_000)
    h = metrics.Histogram("t")
    with trace.tracing():
        for v in samples:
            h.observe(v)
    assert h.count == len(samples)
    np.testing.assert_allclose(h.sum, samples.sum(), rtol=1e-9)
    for p in (50, 90, 99):
        est, ref = h.percentile(p), np.percentile(samples, p)
        assert abs(est - ref) / ref < 0.1, (p, est, ref)
    assert h.min == samples.min() and h.max == samples.max()
    assert h.percentile(0) == h.min and h.percentile(100) == h.max


def test_histogram_snapshot_and_registry():
    with trace.tracing():
        metrics.counter("c").inc(3)
        metrics.gauge("g").set(2.5)
        metrics.histogram("h").observe(1.0)
    snap = metrics.snapshot()
    assert snap["c"] == {"type": "counter", "value": 3.0}
    assert snap["g"]["value"] == 2.5
    assert snap["h"]["count"] == 1
    with pytest.raises(TypeError):
        metrics.gauge("c")        # name already taken by another type
    json.loads(metrics.to_json())


# ---------------------------------------------------------------------------
# disabled mode
# ---------------------------------------------------------------------------

def test_disabled_is_allocation_free_and_records_nothing():
    assert not trace.enabled()
    # one shared no-op singleton: no per-call span allocation
    assert trace.trace("a", n=1) is trace.trace("b", k=2)
    with trace.trace("x") as sp:
        assert sp.fence(jnp.arange(4)) is not None
    trace.record_event("kind", field=1)
    metrics.counter("dead").inc(5)
    metrics.histogram("dead_h").observe(1.0)
    assert trace.spans() == [] and trace.events() == []
    assert metrics.snapshot()["dead"]["value"] == 0.0
    assert metrics.snapshot()["dead_h"]["count"] == 0


def test_disabled_output_bit_identical():
    """Instrumented entry points must return bit-identical outputs with
    observability off vs on — tracing observes, never perturbs."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 512)),
                    jnp.float32)
    off = rsort.sort(x)
    off_v, off_i = rsort.topk(x, 16)
    with trace.tracing():
        on = rsort.sort(x)
        on_v, on_i = rsort.topk(x, 16)
    np.testing.assert_array_equal(np.asarray(off), np.asarray(on))
    np.testing.assert_array_equal(np.asarray(off_v), np.asarray(on_v))
    np.testing.assert_array_equal(np.asarray(off_i), np.asarray(on_i))
    # the enabled run recorded; the disabled one did not
    assert any(s["name"] == "engine.sort" for s in trace.spans())


def test_disabled_overhead_is_noise():
    """The acceptance bound: with tracing disabled the entire per-call
    instrumentation is one module-flag check returning the shared
    singleton plus a no-op context manager.  Bound the primitive hard —
    at < 5us per span even a hot path crossing several spans per sort
    adds microseconds to a millisecond-scale n=64K sort (well inside
    run-to-run noise)."""
    assert not trace.enabled()
    reps = 50_000
    t0 = time.perf_counter()
    for _ in range(reps):
        with trace.trace("hot", n=65536) as sp:
            sp.fence(None)
    per_call = (time.perf_counter() - t0) / reps
    assert per_call < 5e-6, f"{per_call * 1e6:.2f}us per disabled span"


# ---------------------------------------------------------------------------
# planner decision events
# ---------------------------------------------------------------------------

def test_planner_decision_event_once_per_miss_zero_per_hit():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 2048)),
                    jnp.float32)
    with trace.tracing():
        rsort.sort(x)                       # miss: plans + records
        assert len(trace.events("plan_decision")) == 1
        rsort.sort(x)                       # hit: no new decision
        assert len(trace.events("plan_decision")) == 1
        rsort.topk(x, 8)                    # different workload: new miss
        decisions = trace.events("plan_decision")
        assert len(decisions) == 2
    d0 = decisions[0]
    assert d0["n"] == 2048 and d0["method"] in d0["costs"]
    assert d0["predicted_ns"] == d0["costs"][d0["method"]] > 0
    assert decisions[1]["k"] == 8
    assert metrics.counter("planner.decisions").value == 2
    assert metrics.counter("planner.plan_cache_hits").value == 1


def test_cost_observation_pairs_predicted_with_measured():
    x = jnp.asarray(np.random.default_rng(2).standard_normal((1, 4096)),
                    jnp.float32)
    with trace.tracing():
        rsort.sort(x)
    (obs,) = trace.events("cost_observation")
    assert obs["op"] == "sort" and obs["measured_ns"] > 0
    assert obs["error"] == pytest.approx(
        obs["measured_ns"] / obs["predicted_ns"])
    assert metrics.histogram("planner.cost_model_error").count == 1


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

def test_reports_render():
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 2048)),
                    jnp.float32)
    with trace.tracing():
        rsort.sort(x)
        metrics.histogram("serve.e2e_ms").observe(12.0)
    md = report.render_markdown()
    assert "planner.decisions" in md and "engine.sort" in md
    assert "serve.e2e_ms" in report.slo_report()
    cm = report.cost_model_report()
    assert "cost_model_error" in cm
