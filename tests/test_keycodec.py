"""keycodec: order-preserving encode/decode round-trip + monotonicity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import keycodec


def _samples(dtype, rng, n=512):
    """Random values plus every adversarial corner the dtype has."""
    d = jnp.dtype(dtype)
    if d.name == "bfloat16":
        x = jnp.asarray(rng.standard_normal(n) * 100, jnp.bfloat16)
        extra = jnp.asarray([0.0, -0.0, jnp.inf, -jnp.inf, 1e-30, -1e-30],
                            jnp.bfloat16)
        return jnp.concatenate([x, extra])
    if jnp.issubdtype(d, jnp.floating):
        vals = np.concatenate([
            (rng.standard_normal(n) * 100).astype(d.name),
            np.array([0.0, -0.0, np.inf, -np.inf, 1e-4, -1e-4], d.name)])
        return jnp.asarray(vals)
    info = np.iinfo(d.name)
    vals = np.concatenate([
        rng.integers(info.min, info.max, n, dtype=d.name, endpoint=True),
        np.array([info.min, info.max, 0], d.name)])
    return jnp.asarray(vals)


@pytest.mark.parametrize("dtype", keycodec.SUPPORTED)
@pytest.mark.parametrize("descending", [False, True])
def test_roundtrip_bit_exact(dtype, descending):
    x = _samples(dtype, np.random.default_rng(1))
    enc = keycodec.encode(x, descending=descending)
    assert enc.dtype == keycodec.key_dtype(dtype)
    back = keycodec.decode(enc, dtype, descending=descending)
    assert back.dtype == x.dtype
    assert np.asarray(back).tobytes() == np.asarray(x).tobytes()


@pytest.mark.parametrize("dtype", keycodec.SUPPORTED)
@pytest.mark.parametrize("descending", [False, True])
def test_encoding_is_monotone(dtype, descending):
    """x < y in source order <=> encode(x) < encode(y) as unsigned ints
    (strictly reversed for descending)."""
    x = _samples(dtype, np.random.default_rng(2))
    enc = np.asarray(keycodec.encode(x, descending=descending)
                     ).astype(np.int64)
    # sort by source value through a wider dtype on the host (jnp's astype
    # would truncate to 32 bits with x64 disabled; ml_dtypes handles bf16)
    as_f = np.asarray(x).astype(
        np.float64 if jnp.issubdtype(x.dtype, jnp.floating) else np.int64)
    order = np.argsort(as_f, kind="stable")
    es = enc[order]
    # equal source values must map to equal keys except the documented
    # -0.0 < +0.0 refinement, so compare through the strictly-increasing
    # source values only
    src = as_f[order]
    strict = np.diff(src) > 0
    steps = np.diff(es)[strict]
    assert (steps < 0).all() if descending else (steps > 0).all()


def test_float_total_order_refines_ieee_zero():
    """-0.0 encodes strictly below +0.0 (documented total-order refinement)."""
    for dt in (jnp.float16, jnp.bfloat16, jnp.float32):
        neg = int(keycodec.encode(jnp.array(-0.0, dt)))
        pos = int(keycodec.encode(jnp.array(0.0, dt)))
        assert neg + 1 == pos


def test_signed_encode_is_bias_flip():
    """int encoding is the excess-2^(b-1) code: min -> 0, -1 -> 2^(b-1)-1."""
    x = jnp.asarray([-128, -1, 0, 127], jnp.int8)
    enc = np.asarray(keycodec.encode(x))
    np.testing.assert_array_equal(enc, [0, 127, 128, 255])


def test_unsupported_dtype_raises():
    with pytest.raises(ValueError, match="keycodec supports"):
        keycodec.encode(jnp.zeros(4, jnp.bool_))
    with pytest.raises(ValueError, match="must be uint32"):
        keycodec.decode(jnp.zeros(4, jnp.uint16), jnp.float32)


def test_key_bits_match_storage_width():
    assert keycodec.key_bits(jnp.int8) == 8
    assert keycodec.key_bits(jnp.bfloat16) == 16
    assert keycodec.key_bits(jnp.float32) == 32
    assert not keycodec.supports(jnp.bool_)
    assert keycodec.supports(jnp.float16)
