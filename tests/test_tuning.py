"""Tuning-profile layer (repro.core.tuning): persistence, resolution,
parameter threading, autotuner round-trip, and the obs feedback loop.

The subsystem's contract in one line: every kernel shape constant and cost
constant the stack dispatches on comes from one measured, persisted,
fingerprint-keyed object — so these tests check the *wiring* (kernels,
planner, cost model, sample-sort all read the active profile) as much as
the object itself.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model, tuning
from repro.engine import planner
from repro.kernels import radix_select, radix_sort


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Each test gets an empty profile dir and a fresh ambient: no test can
    see the developer's cache or another test's installed profile."""
    monkeypatch.setenv(tuning.PROFILE_DIR_ENV, str(tmp_path / "profiles"))
    tuning.set_active(None)
    planner.clear_plan_cache()
    yield
    tuning.set_active(None)
    planner.clear_plan_cache()


# ---------------------------------------------------------------------------
# profile object: round-trip + validation
# ---------------------------------------------------------------------------

def test_json_round_trip_preserves_everything():
    prof = tuning.TuningProfile(
        fingerprint="cpu/test/jax-0",
        constants=tuning.DeviceSortConstants(xla=7.5, select=11.0),
        digit_bits=4, radix_tile=128, run_len=4096,
        capacity_slack=1.25, select_min_n=512, source="calibrated",
        probe_ns={"xla.sort.n256": 123.0},
        sweeps={"digit_bits": {"4": 100.0, "8": 200.0}})
    again = tuning.TuningProfile.from_dict(
        json.loads(json.dumps(prof.to_dict())))
    assert again == prof


def test_save_load_round_trip_on_disk(tmp_path):
    prof = tuning.TuningProfile(fingerprint="cpu/test/jax-0", run_len=4096)
    path = tuning.save(prof, tmp_path / "p.json")
    assert tuning.load(path) == prof


@pytest.mark.parametrize("mutation", [
    {"schema": "repro.tuning.profile/v999"},
    {"schema": None},
    {"digit_bits": 3},
    {"digit_bits": 0},
    {"radix_tile": 4},
    {"run_len": 1},
    {"capacity_slack": 0.5},
    {"select_min_n": -1},
    {"not_a_field": 1},
    {"constants": {"warp_speed": 9.0}},
])
def test_from_dict_rejects_bad_documents(mutation):
    doc = tuning.TuningProfile(fingerprint="cpu/test/jax-0").to_dict()
    doc.update(mutation)
    with pytest.raises(tuning.ProfileError):
        tuning.TuningProfile.from_dict(doc)


def test_from_dict_rejects_missing_fingerprint():
    doc = tuning.TuningProfile(fingerprint="cpu/test/jax-0").to_dict()
    del doc["fingerprint"]
    with pytest.raises(tuning.ProfileError):
        tuning.TuningProfile.from_dict(doc)


def test_load_rejects_malformed_file(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(tuning.ProfileError):
        tuning.load(bad)
    with pytest.raises(tuning.ProfileError):
        tuning.load(tmp_path / "missing.json")


# ---------------------------------------------------------------------------
# resolution: persisted wins, mismatches fall back to defaults
# ---------------------------------------------------------------------------

def test_active_resolves_defaults_when_nothing_persisted():
    prof = tuning.active()
    assert prof.source == "default"
    assert prof.fingerprint == tuning.device_fingerprint()
    assert prof == tuning.default_profile()


def test_persisted_profile_wins_resolution():
    mine = dataclasses.replace(tuning.default_profile(), run_len=4096)
    tuning.save(mine)                       # default path = isolated dir
    tuning.set_active(None)
    prof = tuning.active()
    assert prof.source == "persisted"
    assert prof.run_len == 4096
    assert tuning.persisted_path() is not None


def test_foreign_fingerprint_is_rejected(tmp_path, monkeypatch):
    """A profile copied from another machine (fingerprint mismatch with its
    filename slot) must not be trusted: resolution falls back to defaults."""
    other = tuning.TuningProfile(fingerprint="tpu/v5e/jax-9.9", run_len=64)
    # write it into this device's filename slot, simulating a bad copy
    tuning.save(other, tuning.profile_path(tuning.device_fingerprint()))
    assert tuning.load_for_device() is None
    assert tuning.persisted_path() is None
    assert tuning.active().source == "default"


def test_corrupt_persisted_file_falls_back(tmp_path):
    p = tuning.profile_path(tuning.device_fingerprint())
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text("{broken")
    assert tuning.load_for_device() is None
    assert tuning.active().source == "default"


def test_generation_bumps_on_swap():
    g0 = tuning.generation()
    tuning.set_active(dataclasses.replace(tuning.active(), run_len=4096))
    assert tuning.generation() > g0


# ---------------------------------------------------------------------------
# parameter threading: kernels / cost model / planner read the profile
# ---------------------------------------------------------------------------

def test_kernels_consume_profile_digit_bits():
    """Swap in digit_bits=4 and the radix kernels must run 8 passes (visible
    via pass_tile_counts) and still sort correctly."""
    tuning.set_active(dataclasses.replace(tuning.active(), digit_bits=4,
                                          radix_tile=64))
    passes, tiles = radix_sort.pass_tile_counts(1000, np.uint32)
    assert passes == 8                      # 32 bits / 4 per pass
    assert tiles == -(-1000 // 64)
    x = np.random.default_rng(0).integers(0, 2**32, (2, 500),
                                          dtype=np.uint32)
    out = np.asarray(radix_sort.sort_blocks(jnp.asarray(x)))
    np.testing.assert_array_equal(out, np.sort(x, -1))


def test_explicit_digit_bits_overrides_profile():
    x = np.random.default_rng(1).integers(0, 2**32, (1, 300),
                                          dtype=np.uint32)
    out = np.asarray(radix_sort.sort_blocks(jnp.asarray(x), digit_bits=2,
                                            tile=64))
    np.testing.assert_array_equal(out, np.sort(x, -1))


def test_selection_consumes_profile_digit_bits():
    x = np.random.default_rng(2).standard_normal((1, 400)).astype(np.float32)
    tuning.set_active(dataclasses.replace(tuning.active(), digit_bits=4,
                                          radix_tile=64))
    v, _ = radix_select.select_topk(jnp.asarray(x), 16, use_kernel=True,
                                    interpret=True)
    ref = np.sort(x, -1)[:, ::-1][:, :16]
    np.testing.assert_array_equal(np.asarray(v), ref)


def test_cost_model_prices_from_profile():
    """Halving digit_bits doubles the pass count, so the radix price must
    rise — the model reads the active profile, not a module constant."""
    n = 1 << 16
    c8 = cost_model.device_sort_cost_ns("radix", n)
    tuning.set_active(dataclasses.replace(tuning.active(), digit_bits=4))
    c4 = cost_model.device_sort_cost_ns("radix", n)
    assert c4 > c8
    # explicit digit_bits bypasses the ambient
    assert cost_model.device_sort_cost_ns("radix", n, digit_bits=8) \
        == pytest.approx(c8)


def test_planner_reads_run_len_and_select_floor():
    tuning.set_active(dataclasses.replace(tuning.active(), run_len=1024,
                                          select_min_n=1 << 30))
    assert planner.choose(100000, 1).run_len == 1024
    # the selection floor removes "select" from auto top-k plans below it
    plan = planner.choose(1 << 20, 1, k=64)
    assert plan.method != "select"
    # explicit requests still route to the selection engine
    forced = planner.choose(4096, 1, requested="select", k=16)
    assert forced.method == "select"


# ---------------------------------------------------------------------------
# autotuner: calibrate -> persist -> fresh process -> identical plans
# ---------------------------------------------------------------------------

def test_calibrate_persists_and_fresh_process_loads(tmp_path):
    prof = planner.calibrate(tile_n=256, batch=4, reps=1, persist=True,
                             sweep_params=False)
    path = tuning.persisted_path()
    assert path is not None
    plan = planner.choose(100000, 1, jnp.dtype(jnp.float32))
    code = (
        "import json, sys\n"
        "import jax.numpy as jnp\n"
        "from repro.core import tuning\n"
        "from repro.engine import planner\n"
        "prof = tuning.active()\n"
        "plan = planner.choose(100000, 1, jnp.dtype(jnp.float32))\n"
        "print(json.dumps({'source': prof.source,\n"
        "                  'fingerprint': prof.fingerprint,\n"
        "                  'xla': prof.constants.xla,\n"
        "                  'method': plan.method,\n"
        "                  'run_len': plan.run_len}))\n")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        check=True, env={**os.environ, "PYTHONPATH": "src",
                         tuning.PROFILE_DIR_ENV: str(path.parent)},
        cwd=str(tuning._repo_profile_dir().parents[1]))
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["source"] == "persisted"
    assert got["fingerprint"] == prof.fingerprint
    assert got["xla"] == pytest.approx(prof.constants.xla)
    # the loaded profile reproduces this process's plan
    assert got["method"] == plan.method
    assert got["run_len"] == plan.run_len


def test_calibrate_records_audit_trail():
    prof = planner.calibrate(tile_n=256, batch=4, reps=1,
                             include_pallas=False)
    assert prof.source == "calibrated"
    assert prof.probe_ns and all(v > 0 for v in prof.probe_ns.values())
    assert prof.sweeps is not None          # sweep_params defaults True
    assert "run_len" in prof.sweeps
    # the digit-width sweep needs the radix kernel: gated on include_pallas
    # (interpret mode prices it dishonestly off-TPU)
    assert "digit_bits" not in prof.sweeps


@pytest.mark.slow          # ~30s: interpret-mode pallas probe grid
def test_calibrate_sweeps_digit_bits_with_pallas():
    prof = planner.calibrate(tile_n=128, batch=2, reps=1,
                             include_pallas=True)
    assert "digit_bits" in prof.sweeps
    assert set(prof.sweeps["digit_bits"]) == {"digit_bits=4", "digit_bits=8"}
    assert prof.digit_bits in (4, 8)


# ---------------------------------------------------------------------------
# obs feedback loop: drift -> re-probe -> clean slate
# ---------------------------------------------------------------------------

@pytest.fixture()
def _obs_on():
    from repro.obs import metrics, trace
    trace.enable()
    metrics.reset()
    tuning._last_refresh_t = None           # cooldown slate per test
    yield metrics
    tuning._last_refresh_t = None
    metrics.reset()
    trace.disable()


def test_refresh_needs_enough_signal(_obs_on):
    h = _obs_on.histogram("planner.cost_model_error")
    for _ in range(tuning.REFRESH_MIN_OBSERVATIONS - 1):
        h.observe(100.0)                    # wildly drifted but too few
    assert tuning.refresh_if_stale() is None


def test_refresh_in_band_is_a_noop(_obs_on):
    h = _obs_on.histogram("planner.cost_model_error")
    for _ in range(tuning.REFRESH_MIN_OBSERVATIONS):
        h.observe(1.1)                      # healthy model
    assert tuning.refresh_if_stale() is None
    assert h.count == tuning.REFRESH_MIN_OBSERVATIONS   # kept, not cleared


def test_refresh_on_drift_recalibrates_and_clears(_obs_on, monkeypatch):
    h = _obs_on.histogram("planner.cost_model_error")
    for _ in range(tuning.REFRESH_MIN_OBSERVATIONS):
        h.observe(50.0)                     # p90 far above threshold
    fresh = dataclasses.replace(tuning.default_profile(),
                                source="calibrated")
    calls = {}

    def _fake_calibrate(**kw):
        calls.update(kw)
        tuning.set_active(fresh)
        return fresh

    monkeypatch.setattr(planner, "calibrate", _fake_calibrate)
    got = tuning.refresh_if_stale(persist=False, tile_n=256)
    assert got is fresh
    assert calls == {"persist": False, "tile_n": 256}
    assert h.count == 0                     # slate cleared for next window
    assert _obs_on.counter("tuning.refreshes").value == 1


def _drift(h, ratio=50.0):
    for _ in range(tuning.REFRESH_MIN_OBSERVATIONS):
        h.observe(ratio)


def test_refresh_cooldown_rate_limits(_obs_on, monkeypatch):
    h = _obs_on.histogram("planner.cost_model_error")
    fresh = dataclasses.replace(tuning.default_profile(),
                                source="calibrated")
    calls = []
    monkeypatch.setattr(planner, "calibrate",
                        lambda **kw: (calls.append(kw), fresh)[1])
    clock = {"t": 1000.0}

    _drift(h)
    assert tuning.refresh_if_stale(persist=False,
                                   now_fn=lambda: clock["t"]) is fresh
    assert len(calls) == 1 and h.count == 0

    # drifts again inside the cooldown: refused, evidence kept
    _drift(h)
    assert tuning.refresh_if_stale(persist=False,
                                   now_fn=lambda: clock["t"]) is None
    assert len(calls) == 1
    assert h.count == tuning.REFRESH_MIN_OBSERVATIONS    # NOT cleared
    assert _obs_on.counter(
        "tuning.refreshes_rate_limited").value == 1

    # clock lapses past the cooldown: the held-back refresh fires
    clock["t"] += tuning.REFRESH_COOLDOWN_S + 1.0
    assert tuning.refresh_if_stale(persist=False,
                                   now_fn=lambda: clock["t"]) is fresh
    assert len(calls) == 2 and h.count == 0
    assert _obs_on.counter("tuning.refreshes").value == 2


def test_refresh_cooldown_checked_after_signal(_obs_on, monkeypatch):
    # a healthy in-band signal inside the cooldown is a plain no-op: the
    # rate-limited counter only counts refreshes that WOULD have fired
    h = _obs_on.histogram("planner.cost_model_error")
    monkeypatch.setattr(tuning, "_last_refresh_t", 1000.0)
    for _ in range(tuning.REFRESH_MIN_OBSERVATIONS):
        h.observe(1.1)
    assert tuning.refresh_if_stale(now_fn=lambda: 1001.0) is None
    assert _obs_on.counter(
        "tuning.refreshes_rate_limited").value == 0


def test_profile_reset_clears_refresh_cooldown(_obs_on, monkeypatch):
    """Regression: ``_last_refresh_t`` used to survive ``set_active`` —
    after a profile reset/reinstall the stale stamp rate-limited the first
    refresh of the NEW profile epoch for a full cooldown, even though the
    timestamp described a calibration of a profile that no longer exists.
    Installing or clearing a profile must start a fresh refresh epoch."""
    h = _obs_on.histogram("planner.cost_model_error")
    fresh = dataclasses.replace(tuning.default_profile(),
                                source="calibrated")
    calls = []
    monkeypatch.setattr(planner, "calibrate",
                        lambda **kw: (calls.append(kw),
                                      tuning.set_active(fresh), fresh)[2])
    clock = {"t": 1000.0}
    _drift(h)
    assert tuning.refresh_if_stale(persist=False,
                                   now_fn=lambda: clock["t"]) is fresh
    assert len(calls) == 1
    # the refresh stamp survives its own calibrate()'s set_active ...
    assert tuning._last_refresh_t == clock["t"]
    # ... but an explicit reset/reinstall clears it
    tuning.set_active(None)
    assert tuning._last_refresh_t is None
    # still inside the OLD cooldown window on the fake clock: the fresh
    # epoch must refresh immediately instead of being rate-limited
    clock["t"] += 1.0
    _drift(h)
    assert tuning.refresh_if_stale(persist=False,
                                   now_fn=lambda: clock["t"]) is fresh
    assert len(calls) == 2
    assert _obs_on.counter("tuning.refreshes_rate_limited").value == 0


def test_refresh_cooldown_zero_disables(_obs_on, monkeypatch):
    h = _obs_on.histogram("planner.cost_model_error")
    fresh = dataclasses.replace(tuning.default_profile(),
                                source="calibrated")
    calls = []
    monkeypatch.setattr(planner, "calibrate",
                        lambda **kw: (calls.append(kw), fresh)[1])
    for _ in range(2):
        _drift(h)
        assert tuning.refresh_if_stale(persist=False, cooldown_s=0.0,
                                       now_fn=lambda: 1000.0) is fresh
    assert len(calls) == 2


def test_maybe_refresh_is_gated_by_env(monkeypatch, _obs_on):
    h = _obs_on.histogram("planner.cost_model_error")
    for _ in range(tuning.REFRESH_MIN_OBSERVATIONS):
        h.observe(50.0)
    monkeypatch.setattr(tuning, "_autotune_live", False)
    tuning.maybe_refresh()                  # opt-out: must not calibrate
    assert h.count == tuning.REFRESH_MIN_OBSERVATIONS
