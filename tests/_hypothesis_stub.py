"""Minimal, dependency-free stand-in for the `hypothesis` API this suite uses.

The real hypothesis package is not available in every CI image.  Rather than
skip the property tests outright, this shim replays each `@given` test over a
fixed, deterministically-seeded sample of the declared strategies, so the
properties still run (as seeded example tests) without the dependency.

Installed by ``conftest.py`` only when ``import hypothesis`` fails; when the
real package is present it is used untouched.

Supported surface (what the tests import):
  given, settings,
  strategies.{integers, booleans, sampled_from, lists, floats, tuples,
              composite}
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    """A strategy is just a draw function over a seeded ``random.Random``."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements: _Strategy, min_size: int = 0, max_size: int = None
          ) -> _Strategy:
    if max_size is None:
        max_size = min_size + 16

    def draw(rng: random.Random):
        size = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(size)]

    return _Strategy(draw)


def floats(min_value: float = 0.0, max_value: float = 1.0, *,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64) -> _Strategy:
    """Uniform floats on [min_value, max_value]; the nan/infinity/width
    knobs exist for signature compatibility (finite draws only)."""
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def composite(fn):
    """``@composite def case(draw, *args): ...`` — calling ``case(*args)``
    yields a strategy that runs ``fn`` with a ``draw`` callable resolving
    sub-strategies against the replay RNG (the real-hypothesis contract)."""

    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def draw_fn(rng: random.Random):
            return fn(lambda strategy: strategy.draw(rng), *args, **kwargs)

        return _Strategy(draw_fn)

    return builder


def settings(**kwargs):
    """Record the settings on the (possibly already-wrapped) test function."""

    def deco(fn):
        fn._stub_settings = kwargs
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", {})
            n_examples = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n_examples):
                drawn = tuple(s.draw(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)

        # hide the strategy parameters from pytest's fixture resolution
        # (functools.wraps exposes them via __wrapped__ / inspect.signature)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def install() -> None:
    """Register fake ``hypothesis`` / ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "lists", "floats",
                 "tuples", "composite"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
