"""Mixer numerics: chunked SSD vs naive recurrence; RG-LRU scan vs loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RGLRUConfig, SSMConfig
from repro.models import rglru, ssm


def test_ssd_chunked_equals_naive_recurrence():
    dims = ssm.SSMDims(d_model=32, d_inner=64, n_heads=4, head_dim=16,
                       d_state=8, conv_width=4, chunk=8)
    rng = np.random.default_rng(0)
    b, s = 2, 32
    xh = jnp.asarray(rng.standard_normal((b, s, 4, 16)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, 4)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, 8)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, 8)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (4,)), jnp.float32)

    y, final = ssm._ssd_chunked(xh, dt, bm, cm, a, dims)

    # naive O(S) recurrence oracle
    state = np.zeros((b, 4, 16, 8), np.float64)
    ys = np.zeros((b, s, 4, 16), np.float64)
    for t in range(s):
        decay = np.exp(np.array(dt[:, t]) * np.array(a)[None, :])
        upd = np.einsum("bh,bhp,bn->bhpn", np.array(dt[:, t]),
                        np.array(xh[:, t]), np.array(bm[:, t]))
        state = state * decay[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.array(cm[:, t]), state)
    np.testing.assert_allclose(np.array(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(final), state, rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_prefill():
    cfg = SSMConfig(d_state=8, head_dim=16, expand=2, conv_width=4, chunk=8)
    dims = ssm.SSMDims.from_config(32, cfg)
    params, _ = ssm.init(jax.random.PRNGKey(0), dims, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, 32)) * 0.3
    full, _ = ssm.apply(params, x, dims)
    out16, st = ssm.apply(params, x[:, :16], dims)
    step, _ = ssm.decode_step(params, x[:, 16:17], dims, st)
    np.testing.assert_allclose(np.array(step[:, 0]), np.array(full[:, 16]),
                               rtol=2e-2, atol=2e-2)


def test_rglru_scan_equals_loop():
    cfg = RGLRUConfig(lru_width=16, conv_width=4)
    params, _ = rglru.init(jax.random.PRNGKey(0), 24, 16, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 24)) * 0.5
    full, final = rglru.apply(params, x, 16, cfg)
    st = rglru.init_state(16, cfg, 2, jnp.float32)
    outs = []
    for t in range(12):
        o, st = rglru.decode_step(params, x[:, t:t + 1], 16, cfg, st)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.array(seq), np.array(full), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.array(st.h), np.array(final.h), rtol=2e-3,
                               atol=2e-3)
