"""The hypothesis replay shim itself, under test.

The fuzz-conformance suite leans on ``tests/_hypothesis_stub.py`` whenever
the real hypothesis package is absent (most CI images), so the shim's
strategy surface — including the ``floats`` / ``tuples`` / ``composite``
additions — needs its own coverage: a silently broken draw would hollow
out every property test downstream.  These tests target the stub module
directly, so they run identically whether or not real hypothesis is
installed.
"""
import random

import _hypothesis_stub as stub


def _draws(strategy, n=200, seed=7):
    rng = random.Random(seed)
    return [strategy.draw(rng) for _ in range(n)]


def test_integers_bounds_and_determinism():
    vals = _draws(stub.integers(-3, 5))
    assert all(-3 <= v <= 5 for v in vals)
    assert set(vals) == set(range(-3, 6))           # full support reached
    assert vals == _draws(stub.integers(-3, 5))     # seeded replay


def test_floats_bounds():
    vals = _draws(stub.floats(-1.5, 2.5))
    assert all(isinstance(v, float) and -1.5 <= v <= 2.5 for v in vals)
    assert len(set(vals)) > 100                     # actually varies
    # compatibility knobs accepted (finite draws regardless)
    assert all(v <= 1.0 for v in
               _draws(stub.floats(0.0, 1.0, allow_nan=True, width=32)))


def test_sampled_from_and_booleans():
    vals = _draws(stub.sampled_from("abc"))
    assert set(vals) == {"a", "b", "c"}
    assert set(_draws(stub.booleans())) == {True, False}


def test_lists_sizes():
    vals = _draws(stub.lists(stub.integers(0, 9), min_size=2, max_size=4))
    assert all(2 <= len(v) <= 4 for v in vals)
    assert all(0 <= x <= 9 for v in vals for x in v)


def test_tuples_composes_strategies():
    vals = _draws(stub.tuples(stub.integers(0, 1), stub.sampled_from("xy")))
    assert all(isinstance(v, tuple) and len(v) == 2 for v in vals)
    assert {v[0] for v in vals} == {0, 1}
    assert {v[1] for v in vals} == {"x", "y"}


def test_composite_passes_draw_and_args():
    @stub.composite
    def pair(draw, hi):
        a = draw(stub.integers(0, hi))
        return (a, draw(stub.integers(a, hi)))      # dependent second draw

    vals = _draws(pair(9))
    assert all(0 <= a <= b <= 9 for a, b in vals)


def test_given_replays_and_settings_cap_examples():
    calls = []

    @stub.settings(max_examples=7)
    @stub.given(stub.integers(0, 100), stub.booleans())
    def prop(n, flag):
        calls.append((n, flag))

    prop()
    assert len(calls) == 7
    replay = list(calls)
    calls.clear()
    prop()                                          # same seeded sequence
    assert calls == replay


def test_given_hides_strategy_params_from_pytest():
    @stub.given(stub.integers(0, 1))
    def prop(n):
        pass

    import inspect
    assert inspect.signature(prop).parameters == {}


def test_install_registers_module(monkeypatch):
    import sys
    monkeypatch.delitem(sys.modules, "hypothesis", raising=False)
    monkeypatch.delitem(sys.modules, "hypothesis.strategies", raising=False)
    stub.install()
    import hypothesis
    import hypothesis.strategies as st
    assert getattr(hypothesis, "__stub__", False)
    for name in ("integers", "booleans", "sampled_from", "lists", "floats",
                 "tuples", "composite"):
        assert callable(getattr(st, name))
    # monkeypatch restores whatever was installed before
